"""ISAAC-TPU core: the paper's contribution as a composable library.

Layout (paper section -> module):
  §3  space.py        X vs X-hat: parameter spaces + legality predicates
  §4  generative.py   categorical generative sampler (Dirichlet prior)
      dataset.py      benchmark-data synthesis
      backend.py      measurement oracles (simulated TPU / wall-clock / interpret)
  §5  features.py     log2 feature transform
      mlp.py          pure-JAX MLP regressor
  §6  search.py       runtime exhaustive inference + top-k re-measure
      tuner.py        facade: train once, cached input-aware kernel selection
  §2/7 heuristics.py  vendor-library baseline (fixed menu + handcrafted select)
"""

from .backend import (InterpretBackend, SimulatedTPUBackend, WallClockBackend,
                      PEAK_BF16_TFLOPS, HBM_GBPS, ICI_GBPS)
from .dataset import Dataset, generate_dataset
from .features import Featurizer, target_transform, target_untransform
from .generative import CategoricalSampler, workload_inputs
from .heuristics import VendorHeuristicLibrary
from .mlp import MLP, TABLE2_ARCHS
from .search import SearchResult, enumerate_legal, exhaustive_search, oracle_search
from .space import (ATTENTION_SPACE, CONV_SPACE, GEMM_SPACE, SSD_SPACE, SPACES,
                    ParamSpace, conv_input, gemm_input)
from .tuner import InputAwareTuner, clear_tuners, get_tuner, install_tuner
