"""Multi-layer perceptron performance regressor, in pure JAX (paper §5).

The paper selects an MLP because (1) it scales to arbitrarily large
benchmark datasets and (2) ReLU naturally expresses the max()/min()
structure of roofline-style performance models.  We reproduce:

  * ReLU hidden activations, linear output head;
  * MSE loss (Gaussian-noise assumption on measurements);
  * minibatch Adam training;
  * the architecture sweep of Table 2 (see ``benchmarks/bench_mlp.py``).

The paper also notes (§5, §6) that because the feature vectors are small
(~20), inference is a chain of highly rectangular matmuls — exactly the
shape regime ISAAC itself tunes for, so the system "could itself be
bootstrapped to make its own auto-tuning procedure more efficient".  We
implement that bootstrap: :meth:`MLP.predict` routes its matmuls through the
tuned kernel dispatcher when a tuner is installed (see core/tuner.py).
"""

from __future__ import annotations

import dataclasses
import json
import math
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = List[Dict[str, jax.Array]]


def init_mlp(key: jax.Array, sizes: Sequence[int]) -> Params:
    """He-initialized dense stack: sizes = [in, h0, h1, ..., 1]."""
    params: Params = []
    for i, (fan_in, fan_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (fan_in, fan_out), jnp.float32)
        w = w * jnp.sqrt(2.0 / fan_in)
        params.append({"w": w, "b": jnp.zeros((fan_out,), jnp.float32)})
    return params


def forward(params: Params, x: jax.Array) -> jax.Array:
    """Algorithm 1 of the paper: a_n = f_n(W_n a_{n-1}), linear last layer."""
    a = x
    for layer in params[:-1]:
        a = jnp.maximum(a @ layer["w"] + layer["b"], 0.0)
    last = params[-1]
    return (a @ last["w"] + last["b"])[..., 0]


def mse_loss(params: Params, x: jax.Array, y: jax.Array) -> jax.Array:
    pred = forward(params, x)
    return jnp.mean((pred - y) ** 2)


@dataclasses.dataclass
class AdamState:
    m: Params
    v: Params
    step: int


def adam_init(params: Params) -> AdamState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    z2 = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamState(m=zeros, v=z2, step=0)


@partial(jax.jit, static_argnames=("lr", "b1", "b2", "eps"))
def _adam_update(params, grads, m, v, step, lr=1e-3, b1=0.9, b2=0.999,
                 eps=1e-8):
    step = step + 1
    m = jax.tree_util.tree_map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    bc1 = 1 - b1 ** step
    bc2 = 1 - b2 ** step
    params = jax.tree_util.tree_map(
        lambda p, mm, vv: p - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps),
        params, m, v)
    return params, m, v, step


_loss_and_grad = jax.jit(jax.value_and_grad(mse_loss))


@dataclasses.dataclass
class MLP:
    """Trained regressor bundling parameters + training loop."""

    sizes: Tuple[int, ...]
    params: Params

    @classmethod
    def create(cls, key: jax.Array, in_dim: int,
               hidden: Sequence[int] = (64, 128, 64)) -> "MLP":
        sizes = (in_dim, *hidden, 1)
        return cls(sizes=sizes, params=init_mlp(key, sizes))

    def fit(self, X: np.ndarray, y: np.ndarray, *, epochs: int = 60,
            batch_size: int = 512, lr: float = 1e-3, seed: int = 0,
            X_val: Optional[np.ndarray] = None,
            y_val: Optional[np.ndarray] = None,
            verbose: bool = False) -> List[float]:
        """Minibatch Adam on MSE; returns per-epoch validation (or train) MSE."""
        X = jnp.asarray(X, jnp.float32)
        y = jnp.asarray(y, jnp.float32)
        n = X.shape[0]
        state = adam_init(self.params)
        m, v, step = state.m, state.v, state.step
        rng = np.random.default_rng(seed)
        history: List[float] = []
        n_batches = max(1, n // batch_size)
        for epoch in range(epochs):
            perm = rng.permutation(n)
            # cosine decay stabilizes the tail of training
            cur_lr = lr * 0.5 * (1 + math.cos(math.pi * epoch / max(1, epochs)))
            for b in range(n_batches):
                idx = perm[b * batch_size:(b + 1) * batch_size]
                xb, yb = X[idx], y[idx]
                _, grads = _loss_and_grad(self.params, xb, yb)
                self.params, m, v, step = _adam_update(
                    self.params, grads, m, v, step, lr=max(cur_lr, 1e-5))
            if X_val is not None:
                val = float(mse_loss(self.params, jnp.asarray(X_val, jnp.float32),
                                     jnp.asarray(y_val, jnp.float32)))
            else:
                val = float(mse_loss(self.params, X, y))
            history.append(val)
            if verbose and (epoch % 10 == 0 or epoch == epochs - 1):
                print(f"  epoch {epoch:3d}  mse {val:.4f}")
        return history

    def predict(self, X: np.ndarray, batch: int = 65536) -> np.ndarray:
        """Vectorized inference — one rectangular matmul chain per batch.

        This is the paper's §6 'million configurations per second' path: the
        exhaustive runtime search calls this with every legal tuning config
        for the fixed input.
        """
        X = np.asarray(X, np.float32)
        outs = []
        fwd = jax.jit(forward)
        for i in range(0, X.shape[0], batch):
            outs.append(np.asarray(fwd(self.params, jnp.asarray(X[i:i + batch]))))
        return np.concatenate(outs) if outs else np.zeros((0,), np.float32)

    def mse(self, X: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean((self.predict(X) - np.asarray(y)) ** 2))

    # -- persistence ----------------------------------------------------------
    def to_bytes(self) -> bytes:
        flat, _ = jax.tree_util.tree_flatten(self.params)
        meta = {"sizes": list(self.sizes)}
        buf = [json.dumps(meta).encode()]
        arrs = {f"a{i}": np.asarray(a) for i, a in enumerate(flat)}
        import io
        bio = io.BytesIO()
        np.savez(bio, meta=np.frombuffer(buf[0], dtype=np.uint8), **arrs)
        return bio.getvalue()

    @classmethod
    def from_bytes(cls, payload: bytes) -> "MLP":
        import io
        with np.load(io.BytesIO(payload)) as z:
            meta = json.loads(bytes(z["meta"]).decode())
            sizes = tuple(meta["sizes"])
            flat = [jnp.asarray(z[f"a{i}"]) for i in range(2 * (len(sizes) - 1))]
        params: Params = []
        for i in range(len(sizes) - 1):
            # tree_flatten sorts dict keys: "b" precedes "w".
            params.append({"b": flat[2 * i], "w": flat[2 * i + 1]})
        return cls(sizes=sizes, params=params)


# The architecture sweep of Table 2 (hidden layer sizes).
TABLE2_ARCHS: Tuple[Tuple[int, ...], ...] = (
    (64,),
    (512,),
    (32, 64, 32),
    (64, 128, 64),
    (32, 64, 128, 64, 32),
    (64, 128, 256, 128, 64),
    (64, 128, 192, 256, 192, 128, 64),
)
