"""Training-set synthesis for the performance regressor (paper §4).

Pipeline:  fit CategoricalSampler on a short uniform phase  ->  draw legal
(config, inputs) pairs from it  ->  label each with the measurement backend
->  (featurize, split, persist).  The paper benchmarks 50k kernels in <2h;
our simulated oracle labels ~100k/s so dataset size is bounded by MLP
training time instead.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from .backend import SimulatedTPUBackend
from .features import Featurizer, target_transform
from .generative import CategoricalSampler, workload_inputs
from .space import Config, ParamSpace


@dataclasses.dataclass
class Dataset:
    """Labeled benchmarking data for one parameter space."""

    space: ParamSpace
    inputs: List[Dict[str, int]]
    configs: List[Config]
    tflops: np.ndarray                    # shape (n,)

    def __len__(self) -> int:
        return len(self.configs)

    def featurize(self, featurizer: Optional[Featurizer] = None
                  ) -> Tuple[Featurizer, np.ndarray, np.ndarray]:
        """Returns (featurizer, X, y_log)."""
        f = featurizer or Featurizer(self.space)
        X_raw = f.raw_batch(list(zip(self.inputs, self.configs)))
        if f.mean is None:
            f.fit(X_raw)
        return f, f.transform(X_raw), target_transform(self.tflops)

    def split(self, val_frac: float = 0.05, seed: int = 0
              ) -> Tuple["Dataset", "Dataset"]:
        rng = np.random.default_rng(seed)
        perm = rng.permutation(len(self))
        n_val = max(1, int(len(self) * val_frac))
        val_idx, tr_idx = perm[:n_val], perm[n_val:]
        pick = lambda idx: Dataset(
            space=self.space,
            inputs=[self.inputs[i] for i in idx],
            configs=[self.configs[i] for i in idx],
            tflops=self.tflops[idx])
        return pick(tr_idx), pick(val_idx)

    def subset(self, n: int, seed: int = 0) -> "Dataset":
        rng = np.random.default_rng(seed)
        idx = rng.permutation(len(self))[:n]
        return Dataset(space=self.space,
                       inputs=[self.inputs[i] for i in idx],
                       configs=[self.configs[i] for i in idx],
                       tflops=self.tflops[idx])


def generate_dataset(space: ParamSpace, n_samples: int, *,
                     backend: Optional[SimulatedTPUBackend] = None,
                     sampler: Optional[CategoricalSampler] = None,
                     n_uniform_fit: int = 4000,
                     n_workloads: int = 512,
                     seed: int = 0,
                     verbose: bool = False) -> Tuple[Dataset, CategoricalSampler]:
    """End-to-end §4: fit the generative model, draw legal pairs, label them."""
    rng = np.random.default_rng(seed)
    backend = backend or SimulatedTPUBackend()
    inputs_pool = workload_inputs(space, n_workloads, rng)

    if sampler is None:
        sampler = CategoricalSampler(space=space)
        sampler.fit(inputs_pool, n_uniform_fit, rng)

    inputs_out: List[Dict[str, int]] = []
    configs_out: List[Config] = []
    y: List[float] = []
    t0 = time.time()
    tries = 0
    while len(configs_out) < n_samples:
        tries += 1
        inputs = inputs_pool[rng.integers(len(inputs_pool))]
        cfg = sampler.sample(rng)
        if not space.is_legal(cfg, inputs):
            continue
        inputs_out.append(dict(inputs))
        configs_out.append(cfg)
        y.append(backend.measure(space.name, cfg, inputs))
    if verbose:
        dt = time.time() - t0
        print(f"[dataset] {n_samples} legal samples from {tries} draws "
              f"({n_samples / max(tries, 1):.1%} acceptance) in {dt:.1f}s")
    return (Dataset(space=space, inputs=inputs_out, configs=configs_out,
                    tflops=np.asarray(y, np.float64)),
            sampler)
