"""Parameter spaces for input-aware auto-tuning (paper §3).

The paper distinguishes the space of *possible* configurations X-hat (every
combination of per-parameter choices) from the space of *legal* configurations
X (those that compile and run within hardware resource limits).  For GEMM the
paper has 10 tuning + 6 input parameters; our TPU adaptation has 8 tuning + 6
input parameters (see DESIGN.md §3 for the PTX->Pallas mapping).

A :class:`ParamSpace` is a small declarative object: an ordered mapping of
parameter name -> tuple of admissible values, plus a legality predicate over a
fully instantiated configuration.  Everything downstream (the generative
sampler, the featurizer, the exhaustive runtime search) is generic over a
ParamSpace - this genericity is the "more flexible front-end" the paper lists
as future work (§9).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, Iterable, List, Mapping, Tuple

# ---------------------------------------------------------------------------
# Hardware constants for legality checks (TPU v5e target; see DESIGN.md §2).
# ---------------------------------------------------------------------------
VMEM_BYTES = 128 * 1024 * 1024          # v5e VMEM per TensorCore
VMEM_USABLE = int(VMEM_BYTES * 0.75)    # leave headroom for spills/semaphores
SUBLANE = 8                             # fp32 sublane tile
LANE = 128                              # lane tile
MXU = 128                               # systolic array dimension

Config = Dict[str, int]


@dataclasses.dataclass(frozen=True)
class ParamSpace:
    """Declarative tuning-parameter space with a legality predicate."""

    name: str
    params: Mapping[str, Tuple[int, ...]]            # tuning parameters
    input_params: Tuple[str, ...]                    # names of input features
    is_legal: Callable[[Mapping[str, int], Mapping[str, int]], bool]

    @property
    def param_names(self) -> Tuple[str, ...]:
        return tuple(self.params.keys())

    def cardinality(self) -> int:
        n = 1
        for v in self.params.values():
            n *= len(v)
        return n

    def enumerate(self) -> Iterable[Config]:
        """Yield every configuration in X-hat (legal or not)."""
        names = self.param_names
        for combo in itertools.product(*(self.params[n] for n in names)):
            yield dict(zip(names, combo))

    def enumerate_legal(self, inputs: Mapping[str, int]) -> List[Config]:
        """Materialize X for a fixed input (used by runtime inference, §6)."""
        return [c for c in self.enumerate() if self.is_legal(c, inputs)]

    def contains(self, cfg: Mapping[str, int]) -> bool:
        return all(cfg.get(k) in v for k, v in self.params.items())


# ---------------------------------------------------------------------------
# GEMM: C[M, N] = A[M, K] @ B[K, N]
#
# Tuning parameters (TPU adaptation of the paper's {M_S,N_S,M_L,N_L,U,K_S,K_L,K_G}):
#   bm, bn      VMEM output-block shape            (paper: M_L x N_L)
#   bk          K-extent of A/B slabs per grid step (paper: U, prefetch width)
#   k_unroll    in-kernel unroll of the bk loop     (paper: K_S)
#   k_split     parallel split-K partial outputs    (paper: K_G; no atomics on
#               TPU so partials are materialized and reduced - pays the same
#               "diminished write bandwidth" cost the paper describes)
#   order       grid iteration order (0: m-major, 1: n-major) - HBM reuse
#   acc32       accumulate in fp32 (1) or io dtype (0)
#   prefetch    DMA pipeline depth (1 = no double buffering)
#
# Input parameters: M, N, K, dtype_bits, trans_a, trans_b.
# The sequential K revisits of one output block (paper's K_L) are derived:
# k_grid = ceil(K / (k_split * bk)).
# ---------------------------------------------------------------------------

GEMM_PARAMS: Dict[str, Tuple[int, ...]] = {
    "bm": (8, 16, 32, 64, 128, 256, 512),
    "bn": (128, 256, 512, 1024),
    "bk": (32, 64, 128, 256, 512, 1024, 2048),
    "k_unroll": (1, 2, 4, 8),
    "k_split": (1, 2, 4, 8, 16, 32, 64),
    "order": (0, 1),
    "acc32": (0, 1),
    "prefetch": (1, 2, 3),
}

GEMM_INPUTS = ("M", "N", "K", "dtype_bits", "trans_a", "trans_b")


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _round_up(a: int, b: int) -> int:
    return _ceil_div(a, b) * b


def gemm_vmem_bytes(cfg: Mapping[str, int], dtype_bits: int) -> int:
    """VMEM working set of the Pallas GEMM for a configuration."""
    bpe = dtype_bits // 8
    nbuf = 2 if cfg["prefetch"] >= 2 else 1      # double-buffered input slabs
    a_slab = cfg["bm"] * cfg["bk"] * bpe
    b_slab = cfg["bk"] * cfg["bn"] * bpe
    acc_bpe = 4 if cfg["acc32"] else bpe
    out = cfg["bm"] * cfg["bn"] * acc_bpe
    return nbuf * (a_slab + b_slab) + out


def gemm_is_legal(cfg: Mapping[str, int], inputs: Mapping[str, int]) -> bool:
    """Membership test for X (paper §4: >99.9% of X-hat is illegal on GPU;
    our TPU space is less hostile but still majority-illegal for small inputs)."""
    M, N, K = inputs["M"], inputs["N"], inputs["K"]
    bits = inputs["dtype_bits"]
    bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
    # -- resource limits ----------------------------------------------------
    if gemm_vmem_bytes(cfg, bits) > VMEM_USABLE:
        return False
    # -- alignment: lane/sublane tiles must be respected by the block shape --
    if bm % SUBLANE or bn % LANE:
        return False
    # bk must pack whole (sublane x lane) input tiles for both operands.
    if bk % LANE:
        return False
    # -- reduction splitting must have something to split --------------------
    k_steps = _ceil_div(K, bk)
    if cfg["k_split"] > k_steps:
        return False
    # unroll must not exceed the per-split sequential step count
    if cfg["k_unroll"] > max(1, _ceil_div(k_steps, cfg["k_split"])):
        return False
    # fp32 IO requires fp32 accumulation on the MXU
    if bits == 32 and not cfg["acc32"]:
        return False
    # -- gross-waste guards: a block larger than the (tile-padded) problem
    #    allocates VMEM and MXU passes for pure padding.  The paper's X
    #    likewise excludes configs that cannot execute safely/meaningfully. --
    if bm > _round_up(M, SUBLANE) or bn > _round_up(N, LANE) \
            or bk > _round_up(K, LANE):
        return False
    return True


GEMM_SPACE = ParamSpace(
    name="gemm",
    params=GEMM_PARAMS,
    input_params=GEMM_INPUTS,
    is_legal=gemm_is_legal,
)


# ---------------------------------------------------------------------------
# CONV: O[K,P,Q,N] = sum_c I[C,H,W,N] * F[C,R,S,K]   (paper §3.3)
#
# Implicit-GEMM view: (M', N', K') = (N*P*Q, K, C*R*S).  Tiling follows the
# shifted-window formulation (DESIGN.md §3): the kernel iterates over (r, s)
# filter offsets with statically shifted VMEM slices, so the tunables are the
# implicit-GEMM blocks plus the C-reduction split (paper's C_S, C_L, C_G).
# ---------------------------------------------------------------------------

CONV_PARAMS: Dict[str, Tuple[int, ...]] = {
    "b_npq": (8, 16, 32, 64, 128, 256, 512),
    "b_k": (128, 256, 512),
    "b_c": (32, 64, 128, 256, 512),
    "rs_unroll": (1, 2, 4),
    "c_split": (1, 2, 4, 8, 16),
    "order": (0, 1),
    "acc32": (0, 1),
    "prefetch": (1, 2, 3),
}

CONV_INPUTS = ("N", "H", "W", "C", "K", "R", "S", "dtype_bits")


def conv_out_shape(inputs: Mapping[str, int]) -> Tuple[int, int]:
    """'SAME'-padded unit-stride output spatial shape (DeepBench convention)."""
    return inputs["H"], inputs["W"]


def conv_vmem_bytes(cfg: Mapping[str, int], dtype_bits: int) -> int:
    bpe = dtype_bits // 8
    nbuf = 2 if cfg["prefetch"] >= 2 else 1
    # I slab: b_npq spatial elements x b_c channels, F slab: b_c*rs x b_k.
    i_slab = cfg["b_npq"] * cfg["b_c"] * bpe * cfg["rs_unroll"]
    f_slab = cfg["b_c"] * cfg["rs_unroll"] * cfg["b_k"] * bpe
    acc_bpe = 4 if cfg["acc32"] else bpe
    out = cfg["b_npq"] * cfg["b_k"] * acc_bpe
    return nbuf * (i_slab + f_slab) + out


def conv_is_legal(cfg: Mapping[str, int], inputs: Mapping[str, int]) -> bool:
    bits = inputs["dtype_bits"]
    P, Q = conv_out_shape(inputs)
    npq = inputs["N"] * P * Q
    C, K, R, S = inputs["C"], inputs["K"], inputs["R"], inputs["S"]
    if conv_vmem_bytes(cfg, bits) > VMEM_USABLE:
        return False
    if cfg["b_npq"] % SUBLANE or cfg["b_k"] % LANE:
        return False
    c_steps = _ceil_div(C, cfg["b_c"])
    if cfg["c_split"] > c_steps:
        return False
    if cfg["rs_unroll"] > R * S:
        return False
    if bits == 32 and not cfg["acc32"]:
        return False
    if cfg["b_npq"] > _round_up(npq, SUBLANE) or cfg["b_k"] > _round_up(K, LANE) \
            or cfg["b_c"] > _round_up(C, LANE):
        return False
    return True


CONV_SPACE = ParamSpace(
    name="conv",
    params=CONV_PARAMS,
    input_params=CONV_INPUTS,
    is_legal=conv_is_legal,
)


# ---------------------------------------------------------------------------
# Beyond-paper tunable ops (paper §9 future work: "problems beyond GEMM and
# CONV").  Flash attention and the Mamba-2 SSD chunk scan expose block sizes
# through the same machinery.
# ---------------------------------------------------------------------------

ATTENTION_PARAMS: Dict[str, Tuple[int, ...]] = {
    "b_q": (128, 256, 512, 1024),
    "b_kv": (128, 256, 512, 1024, 2048),
    "acc32": (0, 1),
    "prefetch": (1, 2, 3),
}

ATTENTION_INPUTS = ("B", "Hq", "Hkv", "Lq", "Lkv", "D", "dtype_bits", "causal")


def attention_is_legal(cfg: Mapping[str, int], inputs: Mapping[str, int]) -> bool:
    bits = inputs["dtype_bits"]
    bpe = bits // 8
    d = inputs["D"]
    nbuf = 2 if cfg["prefetch"] >= 2 else 1
    q = cfg["b_q"] * d * bpe
    kv = 2 * cfg["b_kv"] * d * bpe * nbuf
    scores = cfg["b_q"] * cfg["b_kv"] * 4
    acc = cfg["b_q"] * d * 4 + 2 * cfg["b_q"] * 4
    if q + kv + scores + acc > VMEM_USABLE:
        return False
    if bits == 32 and not cfg["acc32"]:
        return False
    if cfg["b_q"] > _round_up(inputs["Lq"], LANE) \
            or cfg["b_kv"] > _round_up(inputs["Lkv"], LANE):
        return False
    return True


ATTENTION_SPACE = ParamSpace(
    name="attention",
    params=ATTENTION_PARAMS,
    input_params=ATTENTION_INPUTS,
    is_legal=attention_is_legal,
)


SSD_PARAMS: Dict[str, Tuple[int, ...]] = {
    "chunk": (32, 64, 128, 256, 512),
    "b_heads": (1, 2, 4, 8),
    "acc32": (0, 1),
    "prefetch": (1, 2, 3),
}

SSD_INPUTS = ("B", "L", "H", "P", "S", "dtype_bits")   # P=head dim, S=state dim


def ssd_is_legal(cfg: Mapping[str, int], inputs: Mapping[str, int]) -> bool:
    bits = inputs["dtype_bits"]
    bpe = bits // 8
    c, bh = cfg["chunk"], cfg["b_heads"]
    p, s = inputs["P"], inputs["S"]
    nbuf = 2 if cfg["prefetch"] >= 2 else 1
    x = bh * c * p * bpe * nbuf
    bc = 2 * bh * c * s * bpe * nbuf
    state = bh * p * s * 4
    intra = bh * c * c * 4
    if x + bc + state + intra + bh * c * p * 4 > VMEM_USABLE:
        return False
    if c > _round_up(inputs["L"], LANE):
        return False
    if bits == 32 and not cfg["acc32"]:
        return False
    return True


SSD_SPACE = ParamSpace(
    name="ssd",
    params=SSD_PARAMS,
    input_params=SSD_INPUTS,
    is_legal=ssd_is_legal,
)


SPACES: Dict[str, ParamSpace] = {
    "gemm": GEMM_SPACE,
    "conv": CONV_SPACE,
    "attention": ATTENTION_SPACE,
    "ssd": SSD_SPACE,
}


def gemm_input(M: int, N: int, K: int, dtype_bits: int = 16,
               trans_a: bool = False, trans_b: bool = False) -> Dict[str, int]:
    return {"M": int(M), "N": int(N), "K": int(K), "dtype_bits": int(dtype_bits),
            "trans_a": int(trans_a), "trans_b": int(trans_b)}


def conv_input(N: int, H: int, W: int, C: int, K: int, R: int, S: int,
               dtype_bits: int = 16) -> Dict[str, int]:
    return {"N": int(N), "H": int(H), "W": int(W), "C": int(C), "K": int(K),
            "R": int(R), "S": int(S), "dtype_bits": int(dtype_bits)}
