"""Runtime kernel inference (paper §6).

At runtime the input parameters are fixed; the trained regressor is optimized
over tuning parameters only.  The paper picks exhaustive search because (a)
it finds the global optimum of the model within the search range, (b) it is
embarrassingly parallel — the whole candidate set is scored by ONE batched
MLP forward pass (a chain of rectangular matmuls: the self-bootstrap), and
(c) the top-k survivors can be re-measured on hardware to wash out model
noise.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Mapping, Optional, Tuple

import numpy as np

from .features import Featurizer, target_untransform
from .mlp import MLP
from .space import Config, ParamSpace


@dataclasses.dataclass
class SearchResult:
    best: Config
    predicted_tflops: float
    measured_tflops: Optional[float]
    top_k: List[Tuple[Config, float]]           # (config, predicted)
    n_candidates: int
    # every (config, measured) pair from the top-k re-measurement pass: each
    # one is a labeled training point for the performance model (model.py),
    # so sessions commit them to the store as source="sample" records.
    measured: Optional[List[Tuple[Config, float]]] = None


def enumerate_legal(space: ParamSpace, inputs: Mapping[str, int],
                    cap: Optional[int] = None) -> List[Config]:
    """Materialize X(inputs) — the legal slice of the space at fixed input."""
    out: List[Config] = []
    for cfg in space.enumerate():
        if space.is_legal(cfg, inputs):
            out.append(cfg)
            if cap is not None and len(out) >= cap:
                break
    return out


def exhaustive_search(space: ParamSpace, inputs: Mapping[str, int], *,
                      model: MLP, featurizer: Featurizer,
                      top_k: int = 10,
                      measure: Optional[Callable[[Config], float]] = None,
                      candidates: Optional[List[Config]] = None
                      ) -> SearchResult:
    """Score every legal config with one batched forward pass; optionally
    re-measure the top-k on the backend and return the measured argmax."""
    cands = candidates if candidates is not None else \
        enumerate_legal(space, inputs)
    if not cands:
        raise ValueError(f"no legal configuration for inputs {inputs}")

    X_raw = featurizer.raw_batch([(inputs, c) for c in cands])
    X = featurizer.transform(X_raw)
    pred_log = model.predict(X)
    pred = target_untransform(pred_log)

    order = np.argsort(-pred)
    k = min(top_k, len(cands))
    top = [(cands[i], float(pred[i])) for i in order[:k]]

    if measure is not None:
        measured = [(cfg, measure(cfg)) for cfg, _ in top]
        best_cfg, best_m = max(measured, key=lambda t: t[1])
        best_pred = next(p for c, p in top if c == best_cfg)
        return SearchResult(best=best_cfg, predicted_tflops=best_pred,
                            measured_tflops=best_m, top_k=top,
                            n_candidates=len(cands), measured=measured)
    best_cfg, best_pred = top[0]
    return SearchResult(best=best_cfg, predicted_tflops=best_pred,
                        measured_tflops=None, top_k=top,
                        n_candidates=len(cands))


def oracle_search(space: ParamSpace, inputs: Mapping[str, int],
                  measure: Callable[[Config], float],
                  candidates: Optional[List[Config]] = None
                  ) -> Tuple[Config, float]:
    """Ground-truth exhaustive search on the backend itself — the '10 hours
    on hardware' baseline of §6, tractable here because the oracle is fast.
    Benchmarks use it to report ISAAC's regret vs the true optimum."""
    cands = candidates if candidates is not None else \
        enumerate_legal(space, inputs)
    best_cfg, best = None, -1.0
    for cfg in cands:
        y = measure(cfg)
        if y > best:
            best_cfg, best = cfg, y
    return best_cfg, best
