"""Feature engineering for the performance regressor (paper §5.2).

The paper's central modeling insight: Volkov-style performance models
(eq. 2-3) are built from products, quotients and maxima of hardware and
input/tuning quantities.  An MLP cannot easily represent products of its
inputs, but ``log`` turns products/quotients into sums/differences which a
ReLU network represents trivially (and ``max`` is native to ReLU).  The paper
reports that the log transform is the difference between converging and not
(Table 2, "no log" column).

A featurizer is generic over a :class:`~repro.core.space.ParamSpace`: the
feature vector is ``log2(input params) ++ log2(tuning params)``, standardized
to zero-mean/unit-variance with statistics estimated from the training set.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Mapping, Optional, Sequence, Tuple

import numpy as np

from .space import ParamSpace


@dataclasses.dataclass
class Featurizer:
    """Maps (inputs, config) dicts -> standardized log2 feature vectors."""

    space: ParamSpace
    log: bool = True                      # paper ablates this (Table 2)
    mean: Optional[np.ndarray] = None
    std: Optional[np.ndarray] = None

    @property
    def feature_names(self) -> Tuple[str, ...]:
        return tuple(self.space.input_params) + tuple(self.space.param_names)

    @property
    def dim(self) -> int:
        return len(self.feature_names)

    # -- raw (un-standardized) features --------------------------------------
    def raw(self, inputs: Mapping[str, int], cfg: Mapping[str, int]) -> np.ndarray:
        vals = [float(inputs[k]) for k in self.space.input_params]
        vals += [float(cfg[k]) for k in self.space.param_names]
        x = np.asarray(vals, dtype=np.float64)
        if self.log:
            # +1 shift keeps binary flags (0/1) and degenerate dims finite.
            x = np.log2(x + 1.0)
        return x

    def raw_batch(self, pairs: Sequence[Tuple[Mapping[str, int], Mapping[str, int]]]
                  ) -> np.ndarray:
        return np.stack([self.raw(i, c) for i, c in pairs])

    # -- standardization ------------------------------------------------------
    def fit(self, X_raw: np.ndarray) -> "Featurizer":
        self.mean = X_raw.mean(axis=0)
        self.std = X_raw.std(axis=0) + 1e-8
        return self

    def transform(self, X_raw: np.ndarray) -> np.ndarray:
        assert self.mean is not None, "call fit() first"
        return ((X_raw - self.mean) / self.std).astype(np.float32)

    def __call__(self, inputs: Mapping[str, int], cfg: Mapping[str, int]
                 ) -> np.ndarray:
        return self.transform(self.raw(inputs, cfg)[None])[0]

    # -- persistence ----------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "space": self.space.name,
            "log": self.log,
            "mean": None if self.mean is None else self.mean.tolist(),
            "std": None if self.std is None else self.std.tolist(),
        })

    @classmethod
    def from_json(cls, space: ParamSpace, payload: str) -> "Featurizer":
        d = json.loads(payload)
        assert d["space"] == space.name
        f = cls(space=space, log=d["log"])
        if d["mean"] is not None:
            f.mean = np.asarray(d["mean"], dtype=np.float64)
            f.std = np.asarray(d["std"], dtype=np.float64)
        return f


def target_transform(y_tflops: np.ndarray) -> np.ndarray:
    """Regress log-throughput: performance spans 3+ orders of magnitude and
    relative (not absolute) error is what matters for ranking kernels."""
    return np.log2(np.maximum(y_tflops, 1e-6)).astype(np.float32)


def target_untransform(y_log: np.ndarray) -> np.ndarray:
    # clip to a physically absurd ceiling (2^40 TFLOPS) so a regressor
    # extrapolating far off its training manifold saturates instead of
    # overflowing to inf and poisoning downstream argmax/geomean math
    return np.exp2(np.minimum(y_log, 40.0))
