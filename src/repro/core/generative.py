"""Generative modeling of the legal configuration space (paper §4).

When only X-hat is explicitly known, uniform sampling of configurations is
wasteful (paper: >99.9% of uniform GEMM samples are illegal).  The paper's
remedy is a *naive factorized categorical model*: treat the configuration as a
random vector with independent categorical components,

    p(x in X) ~= p(x_0) p(x_1) ... p(x_N),

estimate each p(x_i = v) as the proportion of value v among *accepted* samples
of a short uniform-sampling phase, and smooth with a Dirichlet prior by
initializing every count at alpha > 0 (the paper uses alpha = 100, and so do
we).  Sampling from the fitted model then concentrates on the legal region.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Mapping, Optional

import numpy as np

from .space import Config, ParamSpace


@dataclasses.dataclass
class CategoricalSampler:
    """Factorized categorical generative model with Dirichlet-prior smoothing."""

    space: ParamSpace
    alpha: float = 100.0
    counts: Optional[Dict[str, np.ndarray]] = None

    def __post_init__(self) -> None:
        if self.counts is None:
            self.counts = {
                name: np.full(len(choices), self.alpha, dtype=np.float64)
                for name, choices in self.space.params.items()
            }

    # -- fitting ------------------------------------------------------------
    def fit(self, inputs_list: List[Mapping[str, int]], n_uniform: int,
            rng: np.random.Generator) -> "CategoricalSampler":
        """Uniform-sampling phase: draw configurations uniformly from X-hat,
        check legality against inputs drawn from the workload distribution,
        and accumulate acceptance counts per parameter value."""
        names = self.space.param_names
        choices = [self.space.params[n] for n in names]
        for _ in range(n_uniform):
            idx = [rng.integers(len(c)) for c in choices]
            cfg = {n: c[i] for n, c, i in zip(names, choices, idx)}
            inputs = inputs_list[rng.integers(len(inputs_list))]
            if self.space.is_legal(cfg, inputs):
                for n, i in zip(names, idx):
                    self.counts[n][i] += 1.0
        return self

    def probs(self, name: str) -> np.ndarray:
        c = self.counts[name]
        return c / c.sum()

    # -- sampling -----------------------------------------------------------
    def sample(self, rng: np.random.Generator) -> Config:
        """Draw one configuration from the fitted factorized model.  The
        result is *probably* legal; callers re-check legality (the model is
        an importance distribution, not an exact characterization of X)."""
        cfg: Config = {}
        for name, choices in self.space.params.items():
            p = self.probs(name)
            cfg[name] = int(choices[rng.choice(len(choices), p=p)])
        return cfg

    def sample_legal(self, inputs: Mapping[str, int], rng: np.random.Generator,
                     max_tries: int = 1000) -> Optional[Config]:
        for _ in range(max_tries):
            cfg = self.sample(rng)
            if self.space.is_legal(cfg, inputs):
                return cfg
        return None

    # -- diagnostics (Table 1 of the paper) ----------------------------------
    def acceptance_rate(self, inputs_list: List[Mapping[str, int]], n: int,
                        rng: np.random.Generator,
                        uniform: bool = False) -> float:
        """Fraction of draws that land in X; `uniform=True` measures the naive
        baseline the paper compares against."""
        names = self.space.param_names
        choices = [self.space.params[n] for n in names]
        ok = 0
        for _ in range(n):
            if uniform:
                cfg = {nm: c[rng.integers(len(c))] for nm, c in zip(names, choices)}
            else:
                cfg = self.sample(rng)
            inputs = inputs_list[rng.integers(len(inputs_list))]
            ok += self.space.is_legal(cfg, inputs)
        return ok / n

    # -- persistence ----------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "space": self.space.name,
            "alpha": self.alpha,
            "counts": {k: v.tolist() for k, v in self.counts.items()},
        })

    @classmethod
    def from_json(cls, space: ParamSpace, payload: str) -> "CategoricalSampler":
        d = json.loads(payload)
        assert d["space"] == space.name
        sampler = cls(space=space, alpha=d["alpha"])
        sampler.counts = {k: np.asarray(v, dtype=np.float64)
                          for k, v in d["counts"].items()}
        return sampler


def workload_inputs(space: ParamSpace, n: int, rng: np.random.Generator
                    ) -> List[Dict[str, int]]:
    """Draw input-parameter vectors from a realistic workload distribution.

    The paper trains on inputs spanning LINPACK-, DeepBench-, ICA- and
    LAPACK-like regimes; we mirror that with log-uniform dims plus explicit
    skinny / deep-reduction tails so the model sees the irregular regions
    where input-awareness matters.
    """
    out: List[Dict[str, int]] = []

    def logu(lo: int, hi: int) -> int:
        return int(2 ** rng.uniform(np.log2(lo), np.log2(hi)))

    for _ in range(n):
        if space.name == "gemm":
            mode = rng.integers(4)
            if mode == 0:        # square-ish (LINPACK)
                m = n_ = k = logu(128, 8192)
            elif mode == 1:      # skinny-N (DeepBench fwd/bwd)
                m, n_, k = logu(512, 8192), logu(8, 256), logu(512, 8192)
            elif mode == 2:      # deep reduction (ICA / covariance)
                m = n_ = logu(16, 512)
                k = logu(8192, 131072)
            else:                # outer-product-ish (LAPACK blocked)
                m = n_ = logu(512, 8192)
                k = logu(16, 64)
            bits = int(rng.choice([16, 32]))
            out.append({"M": m, "N": n_, "K": k, "dtype_bits": bits,
                        "trans_a": int(rng.integers(2)),
                        "trans_b": int(rng.integers(2))})
        elif space.name == "conv":
            nb = int(rng.choice([8, 16, 32]))
            h = logu(7, 128)
            w = logu(7, 256)
            c = int(rng.choice([1, 16, 32, 64, 128, 256, 512, 832, 1024]))
            k = int(rng.choice([32, 64, 128, 174, 256, 512, 2048]))
            r = int(rng.choice([1, 3, 5]))
            s = int(rng.choice([1, 3, 5, 10, 20]))
            out.append({"N": nb, "H": h, "W": w, "C": c, "K": k,
                        "R": r, "S": s, "dtype_bits": int(rng.choice([16, 32]))})
        elif space.name == "attention":
            out.append({"B": logu(1, 64), "Hq": int(rng.choice([8, 16, 32, 64])),
                        "Hkv": int(rng.choice([1, 2, 8])),
                        "Lq": logu(128, 32768), "Lkv": logu(128, 32768),
                        "D": int(rng.choice([64, 128, 256])),
                        "dtype_bits": int(rng.choice([16, 32])),
                        "causal": int(rng.integers(2))})
        elif space.name == "ssd":
            out.append({"B": logu(1, 64), "L": logu(256, 65536),
                        "H": int(rng.choice([16, 32, 64])),
                        "P": int(rng.choice([32, 64, 128])),
                        "S": int(rng.choice([64, 128, 256])),
                        "dtype_bits": int(rng.choice([16, 32]))})
        else:
            raise ValueError(f"unknown space {space.name}")
    return out
