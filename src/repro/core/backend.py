"""Measurement backends: the oracle that labels (config, inputs) -> TFLOPS.

The paper benchmarks 50k real kernels on a GPU (§4).  This container has no
TPU attached, so the backend is pluggable (DESIGN.md §2):

  * :class:`SimulatedTPUBackend` — analytical TPU v5e model with exactly the
    max(latency/n, throughput) saturation structure the paper cites from
    Volkov (eq. 2-3), adapted to the TPU execution model (grid pipelining
    instead of warp occupancy, VMEM instead of shared memory, MXU alignment
    instead of warp shapes).  Deterministic given (config, inputs, seed), with
    multiplicative log-normal noise mimicking measurement jitter.
  * :class:`WallClockBackend` — times real jax.jit executions on the attached
    devices (XLA:CPU here; XLA:TPU on a real pod).  Demonstrates the pipeline
    end-to-end against true measurements.
  * :class:`InterpretBackend` — executes the actual Pallas kernel under
    interpret=True and checks it against the jnp reference; returns the
    simulator's throughput on success, raises on numerical mismatch.  Used by
    tests to guarantee every sampled config is *runnable*, the property that
    separates X from X-hat.

All backends expose ``measure(space_name, cfg, inputs) -> float`` (TFLOPS,
following the paper's choice of performance metric).
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
import time
from typing import Mapping

from .space import conv_out_shape

# ---------------------------------------------------------------------------
# TPU v5e hardware constants (the TARGET; the grading constants of the task).
# ---------------------------------------------------------------------------
PEAK_BF16_TFLOPS = 197.0            # per chip
PEAK_FP32_TFLOPS = PEAK_BF16_TFLOPS / 4.0   # MXU fp32 passes
HBM_GBPS = 819.0                    # per chip
ICI_GBPS = 50.0                     # per link per direction
VMEM_BYTES = 128 * 1024 * 1024
MXU = 128                           # systolic dimension
NUM_CORES = 1                       # v5e: one TensorCore per chip
DMA_ENGINES = 4                     # independent HBM DMA channels per core
DMA_ISSUE_US = 0.15                 # serial issue->data latency per DMA chain
GRID_STEP_OVERHEAD_US = 0.05        # scalar-core bookkeeping per grid step
KERNEL_LAUNCH_US = 2.0


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _align_eff(x: int, tile: int) -> float:
    """Fraction of a padded tile that is useful work (remainder handling).

    The paper handles remainders with PTX predication (§8.3, 2% overhead);
    Pallas masks via pl.when on padded blocks — the cost is that the last
    block computes on padding.
    """
    padded = _ceil_div(x, tile) * tile
    return x / padded


@dataclasses.dataclass
class SimulatedTPUBackend:
    """Analytical TPU v5e performance model (Volkov eq. 2-3 structure).

    The model computes, per kernel configuration:
      t_compute — MXU time for the useful+padding FLOPs of the tiling
      t_memory  — HBM traffic time for the block schedule (incl. split-K
                  partial materialization: the paper's "diminished write
                  bandwidth" for K_G > 1)
      t         — max(t_compute, t_memory) / pipeline_efficiency
    where pipeline_efficiency saturates with the number of grid steps exactly
    like eq. (2) saturates with occupancy n: few steps => the double-buffered
    DMA pipeline never hides the fill latency.
    """

    noise: float = 0.05         # log-normal sigma; 0 => deterministic
    seed: int = 0

    # -- public API -----------------------------------------------------------
    def measure(self, space_name: str, cfg: Mapping[str, int],
                inputs: Mapping[str, int]) -> float:
        if space_name == "gemm":
            flops, t_us = self._gemm_time_us(cfg, inputs)
        elif space_name == "conv":
            flops, t_us = self._conv_time_us(cfg, inputs)
        elif space_name == "attention":
            flops, t_us = self._attention_time_us(cfg, inputs)
        elif space_name == "ssd":
            flops, t_us = self._ssd_time_us(cfg, inputs)
        else:
            raise ValueError(space_name)
        tflops = flops / (t_us * 1e-6) / 1e12
        if self.noise > 0:
            tflops *= self._jitter(space_name, cfg, inputs)
        return float(tflops)

    def time_us(self, space_name: str, cfg: Mapping[str, int],
                inputs: Mapping[str, int]) -> float:
        fn = {"gemm": self._gemm_time_us, "conv": self._conv_time_us,
              "attention": self._attention_time_us, "ssd": self._ssd_time_us}
        return fn[space_name](cfg, inputs)[1]

    # -- deterministic pseudo-noise -------------------------------------------
    def _jitter(self, space_name, cfg, inputs) -> float:
        key = json_key(space_name, cfg, inputs, self.seed)
        h = int(hashlib.sha256(key.encode()).hexdigest()[:16], 16)
        u = (h % 10**9) / 10**9
        # Box-Muller single sample
        z = math.sqrt(-2 * math.log(max(u, 1e-9))) * math.cos(
            2 * math.pi * ((h >> 32) % 10**9) / 10**9)
        return math.exp(self.noise * z)

    # -- shared machinery -------------------------------------------------
    def _combine(self, t_compute_s: float, t_memory_s: float,
                 n_steps: int, prefetch: int) -> float:
        """Eq.(3) analogue with eq.(2)'s saturation.

        prefetch>=2 overlaps copies with compute: t = max(...) divided by a
        fill-amortization term n/(n + prefetch - 1) — a grid with few
        sequential steps never amortizes the pipeline fill (the TPU twin of
        low-occupancy latency exposure).  prefetch=1 serializes copy/compute:
        t = sum(...), the un-overlapped Volkov limit.
        """
        if prefetch <= 1:
            return t_compute_s + t_memory_s
        eff = n_steps / (n_steps + (prefetch - 1))
        return max(t_compute_s, t_memory_s) / eff

    def _dma_latency_us(self, n_steps: int, prefetch: int,
                        split: int) -> float:
        """Serial DMA-issue chain cost — the TPU-native analogue of the
        paper's occupancy-based latency hiding (DESIGN.md §3).

        Grid steps issue their slab DMAs in a serial dependency chain,
        `prefetch` outstanding at a time.  Reduction splitting (the paper's
        K_G/K_L) creates `split` *independent* accumulation chains whose DMAs
        interleave across the core's DMA engines — more outstanding requests,
        better HBM latency hiding, exactly the paper's 'reduction splitting
        improves latency hiding', re-derived for the DMA pipeline instead of
        warp occupancy.
        """
        outstanding = max(prefetch, 1) * min(max(split, 1), DMA_ENGINES)
        return n_steps * DMA_ISSUE_US / outstanding

    def _mxu_eff(self, bm: int, bn: int, bk: int, dtype_bits: int) -> float:
        """MXU utilization of one block-matmul: penalize tiles that do not
        fill the 128x128 systolic array or starve its pipeline depth."""
        eff_m = min(1.0, bm / MXU)
        eff_n = min(1.0, bn / MXU)
        # short K passes can't keep the systolic pipeline full
        eff_k = bk / (bk + MXU / 4)
        # fp32 runs as multi-pass on the MXU but with the same efficiency shape
        return eff_m * eff_n * eff_k

    def _peak_tflops(self, dtype_bits: int) -> float:
        return PEAK_BF16_TFLOPS if dtype_bits <= 16 else PEAK_FP32_TFLOPS

    # -- GEMM ------------------------------------------------------------
    def _gemm_time_us(self, cfg, inputs):
        M, N, K = inputs["M"], inputs["N"], inputs["K"]
        bits = inputs["dtype_bits"]
        bpe = bits // 8
        bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
        ks = cfg["k_split"]

        gm, gn = _ceil_div(M, bm), _ceil_div(N, bn)
        k_steps = _ceil_div(K, bk)
        k_per_split = _ceil_div(k_steps, ks)
        n_steps = gm * gn * ks * k_per_split    # total grid steps

        useful_flops = 2.0 * M * N * K
        # padded tiles still occupy the MXU
        pad = (_align_eff(M, bm) * _align_eff(N, bn) * _align_eff(K, bk))
        mxu = self._mxu_eff(bm, bn, bk, bits)
        # transposed operands need an in-VMEM relayout pass before the MXU;
        # the paper's §7 backward benchmarks show exactly this cost on GPU.
        trans_pen = 1.0
        if inputs.get("trans_a"):
            trans_pen *= 0.92
        if inputs.get("trans_b"):
            trans_pen *= 0.96
        # k_unroll: >1 exposes ILP to the Mosaic scheduler; saturates fast.
        unroll = cfg.get("k_unroll", 1)
        ilp = 1.0 - 0.06 / unroll
        peak = self._peak_tflops(bits) * 1e12
        t_compute_s = useful_flops / (pad * max(peak * mxu * trans_pen * ilp, 1e9))

        # HBM traffic: every (m,n) block re-reads its A/B slabs per k step;
        # an output block is written once per split (split-K materializes
        # k_split partials + a reduction pass that re-reads them).
        a_bytes = gm * gn * ks * k_per_split * (bm * bk * bpe)
        b_bytes = gm * gn * ks * k_per_split * (bk * bn * bpe)
        # grid-order-dependent L2-ish reuse of B slabs (order=0: m-major
        # revisits B; order=1 revisits A).  TPUs have no L2; this models
        # XLA/Mosaic keeping the revisited slab resident in VMEM across
        # consecutive grid steps.
        if cfg.get("order", 0) == 0 and gm > 1:
            b_bytes *= 0.65
        elif cfg.get("order", 0) == 1 and gn > 1:
            a_bytes *= 0.65
        acc_bpe = 4 if cfg.get("acc32", 1) else bpe
        out_bytes = M * N * bpe
        if ks > 1:
            # write ks partials + re-read them in the reduction pass (the
            # paper's 'diminished write bandwidth' for K_G > 1, TPU-style:
            # materialized partials, no atomics).
            out_bytes = 2 * ks * M * N * acc_bpe + M * N * bpe
        lat_us = self._dma_latency_us(n_steps, cfg.get("prefetch", 2), ks)
        t_memory_s = ((a_bytes + b_bytes + out_bytes) / (HBM_GBPS * 1e9)
                      + lat_us * 1e-6)

        t_s = self._combine(t_compute_s, t_memory_s, n_steps,
                            cfg.get("prefetch", 2))
        t_us = (t_s * 1e6 + KERNEL_LAUNCH_US
                + n_steps * GRID_STEP_OVERHEAD_US)
        return useful_flops, t_us

    # -- CONV (implicit GEMM) ---------------------------------------------
    def _conv_time_us(self, cfg, inputs):
        P, Q = conv_out_shape(inputs)
        Nb, C, Kf = inputs["N"], inputs["C"], inputs["K"]
        R, S = inputs["R"], inputs["S"]
        bits = inputs["dtype_bits"]
        bpe = bits // 8
        npq = Nb * P * Q

        b_npq, b_k, b_c = cfg["b_npq"], cfg["b_k"], cfg["b_c"]
        cs = cfg["c_split"]
        g_npq, g_k = _ceil_div(npq, b_npq), _ceil_div(Kf, b_k)
        c_steps = _ceil_div(C, b_c)
        c_per_split = _ceil_div(c_steps, cs)
        rs_inner = _ceil_div(R * S, cfg["rs_unroll"]) * cfg["rs_unroll"]
        n_steps = g_npq * g_k * cs * c_per_split

        useful_flops = 2.0 * npq * Kf * C * R * S
        pad = (_align_eff(npq, b_npq) * _align_eff(Kf, b_k)
               * _align_eff(C, b_c) * (R * S) / rs_inner)
        mxu = self._mxu_eff(b_npq, b_k, b_c, bits)
        peak = self._peak_tflops(bits) * 1e12
        unroll = cfg.get("rs_unroll", 1)
        ilp = 1.0 - 0.06 / unroll
        t_compute_s = useful_flops / (pad * max(peak * mxu * ilp, 1e9))

        # input slab must include the (r,s) halo; shifted-window reuses it
        i_bytes = n_steps * b_npq * b_c * bpe * 1.15      # 15% halo overhead
        f_bytes = n_steps * b_c * rs_inner * b_k * bpe / max(R * S / rs_inner, 1)
        acc_bpe = 4 if cfg.get("acc32", 1) else bpe
        out_bytes = npq * Kf * bpe
        if cs > 1:
            out_bytes = 2 * cs * npq * Kf * acc_bpe + npq * Kf * bpe
        lat_us = self._dma_latency_us(n_steps, cfg.get("prefetch", 2), cs)
        t_memory_s = ((i_bytes + f_bytes + out_bytes) / (HBM_GBPS * 1e9)
                      + lat_us * 1e-6)

        t_s = self._combine(t_compute_s, t_memory_s, n_steps,
                            cfg.get("prefetch", 2))
        t_us = (t_s * 1e6 + KERNEL_LAUNCH_US
                + n_steps * GRID_STEP_OVERHEAD_US)
        return useful_flops, t_us

    # -- Flash attention ----------------------------------------------------
    def _attention_time_us(self, cfg, inputs):
        B, Hq, Lq, Lkv, D = (inputs["B"], inputs["Hq"], inputs["Lq"],
                             inputs["Lkv"], inputs["D"])
        bits = inputs["dtype_bits"]
        bpe = bits // 8
        bq, bkv = cfg["b_q"], cfg["b_kv"]
        causal = bool(inputs.get("causal", 0))

        frac = 0.5 if causal and Lq == Lkv else 1.0
        useful_flops = 4.0 * B * Hq * Lq * Lkv * D * frac
        g_q = _ceil_div(Lq, bq)
        g_kv = _ceil_div(Lkv, bkv)
        n_steps = B * Hq * g_q * max(int(g_kv * frac), 1)

        pad = _align_eff(Lq, bq) * _align_eff(Lkv, bkv)
        mxu = self._mxu_eff(bq, D, bkv, bits) ** 0.5   # two chained matmuls
        peak = self._peak_tflops(bits) * 1e12
        # softmax runs on the VPU in parallel but bounds small-D efficiency
        vpu_tax = D / (D + 32)
        t_compute_s = useful_flops / (pad * max(peak * mxu * vpu_tax, 1e9))

        q_bytes = B * Hq * Lq * D * bpe
        kv_bytes = 2 * B * inputs["Hkv"] * Lkv * D * bpe * g_q * frac
        o_bytes = B * Hq * Lq * D * bpe
        lat_us = self._dma_latency_us(n_steps, cfg.get("prefetch", 2), 1)
        t_memory_s = ((q_bytes + kv_bytes + o_bytes) / (HBM_GBPS * 1e9)
                      + lat_us * 1e-6)

        t_s = self._combine(t_compute_s, t_memory_s, max(g_kv, 1),
                            cfg.get("prefetch", 2))
        t_us = t_s * 1e6 + KERNEL_LAUNCH_US + n_steps * 0.02
        return useful_flops, t_us

    # -- Mamba-2 SSD chunk scan ----------------------------------------------
    def _ssd_time_us(self, cfg, inputs):
        B, L, H, P, S = (inputs["B"], inputs["L"], inputs["H"], inputs["P"],
                         inputs["S"])
        bits = inputs["dtype_bits"]
        bpe = bits // 8
        c, bh = cfg["chunk"], cfg["b_heads"]
        n_chunks = _ceil_div(L, c)

        # SSD: intra-chunk quadratic attention-like term + inter-chunk state
        intra = 2.0 * B * H * n_chunks * c * c * (P + S)
        inter = 2.0 * B * H * n_chunks * (c * S * P * 2 + P * S)
        useful_flops = intra + inter
        pad = _align_eff(L, c)
        mxu = self._mxu_eff(c, P, S, bits)
        peak = self._peak_tflops(bits) * 1e12
        t_compute_s = useful_flops / (pad * max(peak * mxu, 1e9))

        x_bytes = B * H * L * P * bpe * 2
        bc_bytes = 2 * B * L * S * bpe
        state_bytes = B * H * n_chunks * P * S * 4    # carried in fp32
        steps = B * _ceil_div(H, bh) * n_chunks
        lat_us = self._dma_latency_us(steps, cfg.get("prefetch", 2), bh)
        t_memory_s = ((x_bytes + bc_bytes + state_bytes) / (HBM_GBPS * 1e9)
                      + lat_us * 1e-6)

        t_s = self._combine(t_compute_s, t_memory_s, max(n_chunks, 1),
                            cfg.get("prefetch", 2))
        t_us = t_s * 1e6 + KERNEL_LAUNCH_US + steps * 0.02
        return useful_flops, t_us


@dataclasses.dataclass
class WallClockBackend:
    """Times real jitted executions on the attached devices.

    On this container that is XLA:CPU — useful to prove the end-to-end tuning
    loop runs against real measurements (the space that matters on CPU is
    k_split/precision, not VMEM tiling).  On a real TPU pod the same class
    times the Pallas kernels themselves.
    """

    warmup: int = 1
    iters: int = 3

    def measure(self, space_name: str, cfg: Mapping[str, int],
                inputs: Mapping[str, int]) -> float:
        import jax
        import jax.numpy as jnp

        if space_name != "gemm":
            raise NotImplementedError("WallClockBackend covers GEMM")
        M, N, K = inputs["M"], inputs["N"], inputs["K"]
        dtype = jnp.bfloat16 if inputs["dtype_bits"] <= 16 else jnp.float32
        ks = cfg.get("k_split", 1)
        key = jax.random.PRNGKey(0)
        a = jax.random.normal(key, (M, K), jnp.float32).astype(dtype)
        b = jax.random.normal(key, (K, N), jnp.float32).astype(dtype)

        if ks > 1 and K % ks == 0:
            def f(a, b):
                ar = a.reshape(M, ks, K // ks).swapaxes(0, 1)
                br = b.reshape(ks, K // ks, N)
                part = jnp.einsum("smk,skn->smn", ar, br,
                                  preferred_element_type=jnp.float32)
                return part.sum(0).astype(dtype)
        else:
            def f(a, b):
                return jnp.matmul(a, b, preferred_element_type=jnp.float32
                                  ).astype(dtype)
        jf = jax.jit(f)
        out = jf(a, b)
        out.block_until_ready()
        for _ in range(self.warmup):
            jf(a, b).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(self.iters):
            jf(a, b).block_until_ready()
        dt = (time.perf_counter() - t0) / self.iters
        return 2.0 * M * N * K / dt / 1e12


@dataclasses.dataclass
class InterpretBackend:
    """Correctness oracle: run the real Pallas kernel (interpret=True) vs ref.

    Throughput cannot be measured in interpret mode; on success returns the
    simulator's estimate so the tuning loop composes, on numerical mismatch
    raises AssertionError — tests use this to certify sampled configs are in X.
    """

    sim: SimulatedTPUBackend = dataclasses.field(
        default_factory=lambda: SimulatedTPUBackend(noise=0.0))
    rtol: float = 2e-2

    def measure(self, space_name: str, cfg: Mapping[str, int],
                inputs: Mapping[str, int]) -> float:
        from repro.kernels import dispatch
        dispatch.check_config(space_name, dict(cfg), dict(inputs),
                              rtol=self.rtol)
        return self.sim.measure(space_name, cfg, inputs)


def json_key(space_name: str, cfg: Mapping[str, int],
             inputs: Mapping[str, int], seed: int = 0) -> str:
    import json
    return json.dumps({"s": space_name, "c": dict(sorted(cfg.items())),
                       "i": dict(sorted(inputs.items())), "seed": seed},
                      sort_keys=True)
