"""Vendor-library baseline: fixed kernel set + handcrafted selection.

The paper compares against cuBLAS/cuDNN, which it characterizes (§2, §8) as
"a set of several highly-optimized assembly kernels, and handcraft[ed]
heuristics for runtime kernel selection".  cuBLAS cannot run on TPU/CPU, so
the *baseline we beat* is a faithful reimplementation of that design pattern
for our TPU kernel space:

  * a small static menu of tile configurations (the analogue of cuBLAS's
    64-/128-wide SASS kernels — the paper notes N_L in {64,128} and K_L = 1);
  * a size-bucketed if/else selection heuristic;
  * no reduction splitting inside blocks (K_L=1) and global split only for
    extreme K (the deficiency §7.3 attributes to cuBLAS's heuristics).

Two query modes mirror the paper's protocol:
  * ``select``      — heuristic choice (the "cuBLAS" bar in Fig. 6-8);
  * ``best_kernel`` — exhaustive search over the static menu (the
    "Best Kernel" bar, i.e. cublasGemmEx bypassing the heuristics).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Mapping, Tuple

from .space import Config, ParamSpace

# Static GEMM kernel menu: what a vendor ships.  Large square-friendly tiles,
# N-tiles limited to {128, 256} lanes, K_L fixed to 1, one global-split variant.
VENDOR_GEMM_MENU: Tuple[Config, ...] = tuple(
    {"bm": bm, "bn": bn, "bk": bk, "k_unroll": 1, "k_split": ks,
     "order": 0, "acc32": 1, "prefetch": 2}
    for bm, bn in ((64, 128), (128, 128), (128, 256), (256, 256),
                   (256, 1024), (512, 512))
    for bk in (128, 512, 1024)
    for ks in (1, 16)
)

VENDOR_CONV_MENU: Tuple[Config, ...] = tuple(
    {"b_npq": bnpq, "b_k": bk, "b_c": bc, "rs_unroll": 1, "c_split": 1,
     "order": 0, "acc32": 1, "prefetch": 2}
    for bnpq in (64, 128, 256)
    for bk in (128, 256)
    for bc in (32, 64, 128)
)


@dataclasses.dataclass
class VendorHeuristicLibrary:
    """Fixed-menu library with size-bucketed selection heuristics."""

    space: ParamSpace
    menu: Tuple[Config, ...]

    @classmethod
    def gemm(cls, space: ParamSpace) -> "VendorHeuristicLibrary":
        return cls(space=space, menu=VENDOR_GEMM_MENU)

    @classmethod
    def conv(cls, space: ParamSpace) -> "VendorHeuristicLibrary":
        return cls(space=space, menu=VENDOR_CONV_MENU)

    def legal_menu(self, inputs: Mapping[str, int]) -> List[Config]:
        out = [c for c in self.menu if self.space.is_legal(c, inputs)]
        if not out:
            # vendor fallback kernel: smallest tiles in the menu, relaxed
            fallback = dict(min(self.menu, key=lambda c: sum(c.values())))
            out = [fallback]
        return out

    # -- the handcrafted heuristic (the "cuBLAS" bar) -------------------------
    def select(self, inputs: Mapping[str, int]) -> Config:
        legal = self.legal_menu(inputs)
        if self.space.name == "gemm":
            M, N, K = inputs["M"], inputs["N"], inputs["K"]
            # bucket by output size; ignore K except for the extreme
            # covariance regime (the paper: cuBLAS only global-splits, and
            # its heuristics often miss even that).
            if M >= 2048 and N >= 2048:
                want = {"bm": 256, "bn": 1024, "bk": 1024, "k_split": 1}
            elif M >= 512 and N >= 512:
                want = {"bm": 128, "bn": 256, "bk": 512, "k_split": 1}
            elif K >= 32768 and M * N <= 256 * 256:
                want = {"bm": 64, "bn": 128, "bk": 128, "k_split": 16}
            else:
                want = {"bm": 64, "bn": 128, "bk": 128, "k_split": 1}
        else:
            P, Q = inputs["H"], inputs["W"]
            npq = inputs["N"] * P * Q
            if npq >= 65536:
                want = {"b_npq": 256, "b_k": 128}
            elif npq >= 8192:
                want = {"b_npq": 128, "b_k": 128}
            else:
                want = {"b_npq": 64, "b_k": 128}
        # nearest legal menu entry to the heuristic's wish
        def dist(c: Config) -> float:
            return sum(abs(c.get(k, 0) - v) / max(v, 1) for k, v in want.items())
        return min(legal, key=dist)

    # -- exhaustive over the static menu (the "Best Kernel" bar) --------------
    def best_kernel(self, inputs: Mapping[str, int],
                    measure: Callable[[Config], float]) -> Tuple[Config, float]:
        legal = self.legal_menu(inputs)
        scored = [(c, measure(c)) for c in legal]
        return max(scored, key=lambda t: t[1])
