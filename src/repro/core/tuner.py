"""Tuner facade: train-once, infer-anywhere input-aware kernel selection.

Ties the paper's four components together behind one object:

    tuner = InputAwareTuner.train(GEMM_SPACE, n_samples=50_000)
    cfg   = tuner.best_config(gemm_input(M=2560, N=16, K=2560))   # cached

The result of ``best_config`` is exactly what the paper ships at runtime:
the tuning-parameter vector the model believes is fastest for this input,
optionally refined by re-measuring the top-k on the backend (§6), and cached
on the filesystem so later calls are free.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from .backend import SimulatedTPUBackend
from .dataset import Dataset, generate_dataset
from .features import Featurizer
from .generative import CategoricalSampler
from .mlp import MLP
from .search import SearchResult, exhaustive_search
from .space import SPACES, Config, ParamSpace

DEFAULT_CACHE = os.path.expanduser("~/.cache/repro-isaac")


def _input_key(space_name: str, inputs: Mapping[str, int]) -> str:
    blob = json.dumps({"s": space_name, "i": dict(sorted(inputs.items()))},
                      sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


@dataclasses.dataclass
class InputAwareTuner:
    """Trained input-aware tuner for one parameter space."""

    space: ParamSpace
    model: MLP
    featurizer: Featurizer
    sampler: CategoricalSampler
    backend: SimulatedTPUBackend
    top_k: int = 10
    cache_dir: Optional[str] = None
    _mem_cache: Dict[str, Config] = dataclasses.field(default_factory=dict)

    # -- training (the offline hours of §4-§5) --------------------------------
    @classmethod
    def train(cls, space: ParamSpace, *, n_samples: int = 20000,
              hidden: Tuple[int, ...] = (64, 128, 64), epochs: int = 40,
              backend: Optional[SimulatedTPUBackend] = None,
              seed: int = 0, cache_dir: Optional[str] = None,
              verbose: bool = False) -> "InputAwareTuner":
        import jax
        backend = backend or SimulatedTPUBackend()
        ds, sampler = generate_dataset(space, n_samples, backend=backend,
                                       seed=seed, verbose=verbose)
        featurizer, X, y = ds.featurize()
        model = MLP.create(jax.random.PRNGKey(seed), in_dim=featurizer.dim,
                           hidden=hidden)
        model.fit(X, y, epochs=epochs, verbose=verbose)
        return cls(space=space, model=model, featurizer=featurizer,
                   sampler=sampler, backend=backend, cache_dir=cache_dir)

    # -- runtime inference (§6) ------------------------------------------------
    def search(self, inputs: Mapping[str, int], *, remeasure: bool = True
               ) -> SearchResult:
        measure = (lambda cfg: self.backend.measure(self.space.name, cfg,
                                                    inputs)) if remeasure else None
        return exhaustive_search(self.space, inputs, model=self.model,
                                 featurizer=self.featurizer, top_k=self.top_k,
                                 measure=measure)

    def best_config(self, inputs: Mapping[str, int], *,
                    remeasure: bool = True) -> Config:
        key = _input_key(self.space.name, inputs)
        if key in self._mem_cache:
            return self._mem_cache[key]
        if self.cache_dir:
            p = pathlib.Path(self.cache_dir) / f"{self.space.name}-{key}.json"
            if p.exists():
                cfg = json.loads(p.read_text())
                self._mem_cache[key] = cfg
                return cfg
        cfg = self.search(inputs, remeasure=remeasure).best
        self._mem_cache[key] = cfg
        if self.cache_dir:
            pathlib.Path(self.cache_dir).mkdir(parents=True, exist_ok=True)
            (pathlib.Path(self.cache_dir) /
             f"{self.space.name}-{key}.json").write_text(json.dumps(cfg))
        return cfg

    # -- persistence ------------------------------------------------------------
    def save(self, directory: str) -> None:
        d = pathlib.Path(directory)
        d.mkdir(parents=True, exist_ok=True)
        (d / f"{self.space.name}.mlp.npz").write_bytes(self.model.to_bytes())
        (d / f"{self.space.name}.feat.json").write_text(self.featurizer.to_json())
        (d / f"{self.space.name}.sampler.json").write_text(self.sampler.to_json())

    @classmethod
    def load(cls, directory: str, space: ParamSpace,
             backend: Optional[SimulatedTPUBackend] = None,
             cache_dir: Optional[str] = None) -> "InputAwareTuner":
        d = pathlib.Path(directory)
        model = MLP.from_bytes((d / f"{space.name}.mlp.npz").read_bytes())
        featurizer = Featurizer.from_json(
            space, (d / f"{space.name}.feat.json").read_text())
        sampler = CategoricalSampler.from_json(
            space, (d / f"{space.name}.sampler.json").read_text())
        return cls(space=space, model=model, featurizer=featurizer,
                   sampler=sampler, backend=backend or SimulatedTPUBackend(),
                   cache_dir=cache_dir)


_GLOBAL_TUNERS: Dict[str, InputAwareTuner] = {}


def install_tuner(tuner: InputAwareTuner) -> None:
    """Make a tuner visible to the kernel dispatcher (models route GEMMs
    through it when present — the paper's 'kernel generation backend')."""
    _GLOBAL_TUNERS[tuner.space.name] = tuner


def get_tuner(space_name: str) -> Optional[InputAwareTuner]:
    return _GLOBAL_TUNERS.get(space_name)


def clear_tuners() -> None:
    _GLOBAL_TUNERS.clear()
