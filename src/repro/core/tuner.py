"""Tuner facade: train-once, infer-anywhere input-aware kernel selection.

Ties the paper's four components together behind one object:

    tuner = InputAwareTuner.train(GEMM_SPACE, n_samples=50_000)
    cfg   = tuner.best_config(gemm_input(M=2560, N=16, K=2560))   # cached

The result of ``best_config`` is exactly what the paper ships at runtime:
the tuning-parameter vector the model believes is fastest for this input,
optionally refined by re-measuring the top-k on the backend (§6), and
persisted as a :class:`repro.tunedb.TuneRecord` so later calls — in this
process or any other holding the same store — are free.  ``best_config``
always returns a plain ``Config`` (``Dict[str, int]``) regardless of which
layer (memory, store, fresh search) satisfied the lookup.
"""

from __future__ import annotations

import dataclasses
import os
import pathlib
from typing import Dict, Mapping, Optional, Tuple

from repro.tunedb.store import (RecordStore, TuneRecord, input_key,
                                normalize_config)

from .backend import SimulatedTPUBackend
from .dataset import generate_dataset
from .features import Featurizer
from .generative import CategoricalSampler
from .mlp import MLP
from .search import SearchResult, exhaustive_search
from .space import Config, ParamSpace

DEFAULT_CACHE = os.path.expanduser("~/.cache/repro-isaac")


@dataclasses.dataclass
class InputAwareTuner:
    """Trained input-aware tuner for one parameter space."""

    space: ParamSpace
    model: MLP
    featurizer: Featurizer
    sampler: CategoricalSampler
    backend: SimulatedTPUBackend
    top_k: int = 10
    store: Optional[RecordStore] = None
    cache_dir: Optional[str] = None     # legacy knob: dir-backed RecordStore
    _mem_cache: Dict[str, Config] = dataclasses.field(default_factory=dict)
    _dir_store: Optional[RecordStore] = dataclasses.field(
        default=None, repr=False)

    # -- training (the offline hours of §4-§5) --------------------------------
    @classmethod
    def train(cls, space: ParamSpace, *, n_samples: int = 20000,
              hidden: Tuple[int, ...] = (64, 128, 64), epochs: int = 40,
              backend: Optional[SimulatedTPUBackend] = None,
              seed: int = 0, store: Optional[RecordStore] = None,
              cache_dir: Optional[str] = None,
              verbose: bool = False) -> "InputAwareTuner":
        import jax
        backend = backend or SimulatedTPUBackend()
        ds, sampler = generate_dataset(space, n_samples, backend=backend,
                                       seed=seed, verbose=verbose)
        featurizer, X, y = ds.featurize()
        model = MLP.create(jax.random.PRNGKey(seed), in_dim=featurizer.dim,
                           hidden=hidden)
        model.fit(X, y, epochs=epochs, verbose=verbose)
        return cls(space=space, model=model, featurizer=featurizer,
                   sampler=sampler, backend=backend, store=store,
                   cache_dir=cache_dir)

    # -- runtime inference (§6) ------------------------------------------------
    def search(self, inputs: Mapping[str, int], *, remeasure: bool = True
               ) -> SearchResult:
        measure = (lambda cfg: self.backend.measure(self.space.name, cfg,
                                                    inputs)) if remeasure else None
        return exhaustive_search(self.space, inputs, model=self.model,
                                 featurizer=self.featurizer, top_k=self.top_k,
                                 measure=measure)

    def _resolve_store(self) -> Optional[RecordStore]:
        """Explicit store wins; else a store living under cache_dir."""
        if self.store is not None:
            return self.store
        if self.cache_dir:
            path = pathlib.Path(self.cache_dir) / "tunedb.jsonl"
            if self._dir_store is None or self._dir_store.path != path:
                self._dir_store = RecordStore.open(path)
            return self._dir_store
        return None

    def _fingerprint(self) -> str:
        """This tuner's backend fingerprint — its store-lookup dimension."""
        from repro.tunedb.session import backend_fingerprint
        return backend_fingerprint(self.backend)

    def _migrate_legacy_cache(self, key: str, inputs: Mapping[str, int],
                              store: Optional[RecordStore]
                              ) -> Optional[Config]:
        """One old-style per-shape cache file ({space}-{key}.json, pre-store)
        satisfies this lookup and is promoted into the store so the search it
        once paid for is never re-run."""
        if not self.cache_dir:
            return None
        legacy = pathlib.Path(self.cache_dir) / f"{self.space.name}-{key}.json"
        if not legacy.exists():
            return None
        import json
        try:
            cfg = normalize_config(json.loads(legacy.read_text()))
        except (ValueError, TypeError, AttributeError):
            return None        # unreadable/foreign file -> fresh search
        if store is not None:
            store.add(TuneRecord(
                space=self.space.name, inputs=dict(inputs), config=cfg,
                tflops=0.0, backend="unknown", source="import"))
        return cfg

    def best_config(self, inputs: Mapping[str, int], *,
                    remeasure: bool = True) -> Config:
        """Best known config for `inputs`, always as ``Dict[str, int]``.

        Lookup order: in-process memo -> record store -> fresh search (whose
        result is committed back to the store as a TuneRecord).
        """
        key = input_key(self.space.name, inputs)
        if key in self._mem_cache:
            return self._mem_cache[key]
        store = self._resolve_store()
        if store is not None:
            # fingerprint-scoped: another backend's record is not THIS
            # backend's answer — search (below) writes our own record instead
            rec = store.get(self.space.name, inputs,
                            backend=self._fingerprint())
            if rec is not None:
                cfg = normalize_config(rec.config)
                self._mem_cache[key] = cfg
                return cfg
        cfg = self._migrate_legacy_cache(key, inputs, store)
        if cfg is not None:
            self._mem_cache[key] = cfg
            return cfg
        res = self.search(inputs, remeasure=remeasure)
        cfg = normalize_config(res.best)
        self._mem_cache[key] = cfg
        if store is not None:
            from repro.tunedb.session import record_from_search
            store.add(record_from_search(self.space.name, inputs, res,
                                         self.backend, source="tuner"))
        return cfg

    # -- persistence ------------------------------------------------------------
    def save(self, directory: str) -> None:
        d = pathlib.Path(directory)
        d.mkdir(parents=True, exist_ok=True)
        (d / f"{self.space.name}.mlp.npz").write_bytes(self.model.to_bytes())
        (d / f"{self.space.name}.feat.json").write_text(self.featurizer.to_json())
        (d / f"{self.space.name}.sampler.json").write_text(self.sampler.to_json())

    @classmethod
    def load(cls, directory: str, space: ParamSpace,
             backend: Optional[SimulatedTPUBackend] = None,
             store: Optional[RecordStore] = None,
             cache_dir: Optional[str] = None) -> "InputAwareTuner":
        d = pathlib.Path(directory)
        model = MLP.from_bytes((d / f"{space.name}.mlp.npz").read_bytes())
        featurizer = Featurizer.from_json(
            space, (d / f"{space.name}.feat.json").read_text())
        sampler = CategoricalSampler.from_json(
            space, (d / f"{space.name}.sampler.json").read_text())
        return cls(space=space, model=model, featurizer=featurizer,
                   sampler=sampler, backend=backend or SimulatedTPUBackend(),
                   store=store, cache_dir=cache_dir)


_GLOBAL_TUNERS: Dict[str, InputAwareTuner] = {}


def install_tuner(tuner: InputAwareTuner) -> None:
    """Make a tuner visible to the kernel dispatcher (models route GEMMs
    through it when present — the paper's 'kernel generation backend')."""
    _GLOBAL_TUNERS[tuner.space.name] = tuner


def get_tuner(space_name: str) -> Optional[InputAwareTuner]:
    return _GLOBAL_TUNERS.get(space_name)


def clear_tuners() -> None:
    _GLOBAL_TUNERS.clear()
