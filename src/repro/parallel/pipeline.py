"""Pipeline parallelism: gpipe microbatch schedule over a 'stage' mesh axis.

Implemented with shard_map + collective_permute — the jax-native mapping of
the paper-era NCCL send/recv pipelines.  The production dry-run mesh uses
FSDP x TP x pod (all 40 cells fit without PP), so this module is the
*capability* deliverable: it is exercised by tests on a host-device mesh and
is what a >2-pod deployment of the 405B would enable on the 'pod' axis.

Schedule: classic fill-drain gpipe.  For n_micro microbatches and n_stages
stages, the loop runs n_micro + n_stages - 1 ticks; at tick t, stage s
processes microbatch (t - s) when 0 <= t - s < n_micro.  Activations advance
one stage per tick via ppermute; outputs accumulate on the last stage and are
broadcast back at the end (psum over a one-hot mask).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def pipeline_apply(stage_fn: Callable[[Any, jax.Array], jax.Array],
                   stage_params: Any, x: jax.Array, *, mesh: Mesh,
                   axis: str = "stage") -> jax.Array:
    """Run x through n_stages sequential stages with gpipe microbatching.

    stage_params: pytree whose leaves have leading dim n_stages (stage i's
      slice parameterizes stage_fn at stage i); sharded over `axis`.
    x: (n_micro, micro_batch, ...) microbatched input, replicated.
    Returns (n_micro, micro_batch, ...) outputs, replicated on every device.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    total = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_device(params, xs):
        # params leaves: (1, ...) — this device's stage slice
        params = jax.tree_util.tree_map(lambda p: p[0], params)
        stage = jax.lax.axis_index(axis)
        state = jnp.zeros_like(xs[0])                    # inflight activation
        outputs = jnp.zeros_like(xs)

        def tick(t, carry):
            state, outputs = carry
            mb = t - stage                                # microbatch index
            valid = (mb >= 0) & (mb < n_micro)
            inject = xs[jnp.clip(t, 0, n_micro - 1)]
            x_in = jnp.where(stage == 0, inject, state)
            y = stage_fn(params, x_in)
            y = jnp.where(valid, y, state)
            out_t = t - (n_stages - 1)
            write = (stage == n_stages - 1) & (out_t >= 0) & (out_t < n_micro)
            outputs = jax.lax.cond(
                write,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(out_t, 0, n_micro - 1), 0),
                lambda o: o, outputs)
            state = jax.lax.ppermute(y, axis, perm)
            return state, outputs

        _, outputs = jax.lax.fori_loop(0, total, tick, (state, outputs))
        # broadcast last stage's outputs to all stages
        mask = (stage == n_stages - 1).astype(outputs.dtype)
        return jax.lax.psum(outputs * mask, axis)

    spec_p = jax.tree_util.tree_map(
        lambda _: P(axis), stage_params)
    fn = shard_map(per_device, mesh=mesh,
                   in_specs=(spec_p, P()), out_specs=P(),
                   check_vma=False)
    return fn(stage_params, x)


def stage_split(params: Any, n_stages: int) -> Any:
    """Reshape a stacked-layer tree (L, ...) into (n_stages, L//n_stages, ...)
    so each pipeline stage owns a contiguous block of layers."""
    def one(p):
        L = p.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return p.reshape(n_stages, L // n_stages, *p.shape[1:])
    return jax.tree_util.tree_map(one, params)
