"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Physical mesh axes (launch/mesh.py):
  'pod'   — pure data parallelism across pods (gradients all-reduce across it)
  'data'  — FSDP/ZeRO-3: batch *and* parameter shards (all-gather on use)
  'model' — tensor/expert parallelism within a pod row

A *logical* axis name maps to zero or more physical axes.  Rules are applied
best-effort: a physical axis is dropped from the spec when the dimension size
is not divisible by it (e.g. smollm's 9 heads over model=16) — the framework
then relies on the remaining axes, which is what production systems do rather
than refusing to run (the drop is recorded so DESIGN/EXPERIMENTS can report
it).  All full-size assigned configs were chosen/padded (vocab rounded to a
multiple of 256) so that the big dims shard cleanly.

Parameter placement is decided by path-pattern rules over the pytree path, so
model code never hand-annotates parameters.
"""

from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> physical mesh axes (tuple => sharded over several)
LOGICAL_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),          # parameter dim sharded ZeRO-3 style
    "model": ("model",),        # TP: heads / mlp hidden / vocab
    "expert": ("model",),       # EP
    "seq": ("model",),          # SP (long-context KV/state sharding)
    "none": (),
}

# (path regex, per-dim logical axes).  First match wins.  Stacked layer
# params get an extra leading repeat dim handled automatically.
PARAM_RULES: List[Tuple[str, Tuple[str, ...]]] = [
    (r"embed$",                     ("model", "fsdp")),       # (V, D)
    (r"(wq|wk|wv)$",                ("fsdp", "model")),
    (r"wo$",                        ("model", "fsdp")),
    (r"(w_gate|w_up)$",             ("fsdp", "model")),       # dense mlp
    (r"w_down$",                    ("model", "fsdp")),
    (r"moe/(w_gate|w_up)$",         ("expert", "fsdp", "model")),
    (r"moe/w_down$",                ("expert", "model", "fsdp")),
    (r"moe/router$",                ("none", "none")),
    (r"w_in$",                      ("fsdp", "model")),       # mamba in-proj
    (r"w_out$",                     ("model", "fsdp")),
    (r"conv_w$",                    ("none", "model")),
    (r"conv_b$",                    ("model",)),
    # everything else (norm scales, a_log, biases): replicated
]

_MOE_3D = re.compile(r"moe/(w_gate|w_up|w_down)$")

# Alternative rule sets (hillclimb experiments; launch/dryrun.py --rules).
# 'dp_only': replicate every parameter — correct for small models where
# FSDP/TP all-gathers dwarf the compute (smollm on 256 chips).
RULE_SETS: Dict[str, List[Tuple[str, Tuple[str, ...]]]] = {
    "default": PARAM_RULES,
    "dp_only": [],
}
_ACTIVE_PARAM_RULES: List[Tuple[str, Tuple[str, ...]]] = PARAM_RULES


def set_param_rules(name: str) -> None:
    global _ACTIVE_PARAM_RULES
    _ACTIVE_PARAM_RULES = RULE_SETS[name]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axes_for(path_s: str, ndim: int, stacked: bool) -> Tuple[str, ...]:
    for pat, axes in _ACTIVE_PARAM_RULES:
        if re.search(pat, path_s):
            if stacked and len(axes) == ndim - 1:
                return ("none",) + axes
            if len(axes) == ndim:
                return axes
    return ("none",) * ndim


def logical_to_spec(axes: Sequence[str], shape: Sequence[int],
                    mesh: Mesh) -> P:
    """Resolve logical axes to a PartitionSpec, dropping physical axes that
    do not divide the corresponding dimension (best-effort sharding)."""
    out: List[Any] = []
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set = set()
    for dim, name in zip(shape, axes):
        phys = [a for a in LOGICAL_RULES.get(name, ()) if a in sizes]
        keep: List[str] = []
        prod = 1
        for a in phys:
            if a in used:
                continue
            if dim % (prod * sizes[a]) == 0:
                keep.append(a)
                prod *= sizes[a]
        for a in keep:
            used.add(a)
        out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*out)


def param_shardings(params: Any, mesh: Mesh) -> Any:
    """NamedSharding tree mirroring `params` via the PARAM_RULES table."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        stacked = "layers/" in ps or "encoder/" in ps
        axes = _axes_for(ps, np.ndim(leaf), stacked)
        spec = logical_to_spec(axes, np.shape(leaf), mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Activation constraints — light-touch hints for GSPMD propagation.
# ---------------------------------------------------------------------------

_ACTIVE_MESH: Optional[Mesh] = None


class use_rules:
    """Context manager the trainer / dry-run enters so that model-internal
    ``constrain`` calls resolve against the right mesh.  Without it they are
    no-ops (pure single-device execution, e.g. unit tests)."""

    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh
        self._prev: Optional[Mesh] = None

    def __enter__(self):
        global _ACTIVE_MESH
        self._prev, _ACTIVE_MESH = _ACTIVE_MESH, self.mesh
        return self

    def __exit__(self, *exc):
        global _ACTIVE_MESH
        _ACTIVE_MESH = self._prev
        return False


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


def axis_size(logical: str) -> int:
    """Product of active-mesh sizes behind a logical axis (1 if no mesh)."""
    mesh = _ACTIVE_MESH
    if mesh is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in LOGICAL_RULES.get(logical, ()):
        n *= sizes.get(a, 1)
    return n


def constrain(x: jax.Array, *axes: str) -> jax.Array:
    """with_sharding_constraint via logical names; no-op outside use_rules."""
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    spec = logical_to_spec(axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_spec(mesh: Mesh, shape: Sequence[int]) -> NamedSharding:
    """Sharding for a (B, S, ...) host batch: batch over ('pod','data')."""
    axes = ("batch",) + ("none",) * (len(shape) - 1)
    return NamedSharding(mesh, logical_to_spec(axes, shape, mesh))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
