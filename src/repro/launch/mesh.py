"""Production mesh factory.

Defined as a FUNCTION (not module-level state) so importing this module never
touches jax device initialization — the dry-run must set XLA_FLAGS before the
first jax call, and tests/benches must keep seeing 1 device.

Mesh shapes (TPU v5e):
  single pod:  (data=16, model=16)           = 256 chips
  multi-pod:   (pod=2, data=16, model=16)    = 512 chips

Axis roles: 'pod' = pure DP across pods (slow inter-pod links carry only the
gradient all-reduce, int8-compressible); 'data' = FSDP batch+param shards;
'model' = TP/EP/SP within a pod row (fast ICI).
"""

from __future__ import annotations

import jax

from repro.compat import auto_axis_types, make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=auto_axis_types(len(axes)))


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Whatever-devices-exist mesh for tests/examples (1 CPU here)."""
    n = len(jax.devices())
    data = n // model
    return make_mesh((data, model), ("data", "model"),
                     axis_types=auto_axis_types(2))
