import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede any jax import: jax locks the device count on first init.
# This module is the ONLY place the 512 placeholder devices exist; tests and
# benchmarks see the real single CPU device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the production step function is lowered against
ShapeDtypeStruct stand-ins (no allocation), compiled for the target mesh, and
the compiled artifact is mined for:
  * memory_analysis()  — proves the cell fits v5e HBM (per-device);
  * cost_analysis()    — per-device FLOPs / bytes for the roofline terms;
  * HLO collective ops — per-device collective bytes (analysis/hlo.py).

Artifacts land in results/dryrun/<arch>--<shape>--<mesh>.json; the roofline
table and EXPERIMENTS.md sections are generated from them (benchmarks and
analysis never re-compile).

Usage:
  python -m repro.launch.dryrun --all                  # every cell, resumable
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh pod
  python -m repro.launch.dryrun ... --override remat=False --tag exp1
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import collective_bytes
from repro.analysis.roofline import model_flops
from repro.configs import ARCH_NAMES, SHAPES, applicable, get_config
from repro.configs.shapes import batch_specs, cache_specs, decode_specs
from repro.launch.mesh import make_production_mesh
from repro.models import ModelConfig, decode_step, loss_fn, prefill
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.parallel import sharding as shd

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def opt_config_for(cfg: ModelConfig) -> AdamWConfig:
    """bf16 optimizer states above ~20B params (DESIGN.md §7 memory math)."""
    big = cfg.param_count > 2e10
    return AdamWConfig(state_dtype=jnp.bfloat16 if big else jnp.float32)


# ---------------------------------------------------------------------------
# step builders (one per shape kind)
# ---------------------------------------------------------------------------

def train_microbatches(cfg: ModelConfig) -> int:
    """Gradient-accumulation factor for the dry-run training step: bounds
    live activation memory for the huge configs (DESIGN.md §7)."""
    if cfg.param_count > 2e11:
        return 8
    if cfg.param_count > 5e10:
        return 4
    return 1


def grad_accum_dtype(cfg: ModelConfig):
    """f32 gradient accumulators except at 405B scale, where the extra
    params-sized f32 buffer alone would blow the single-pod HBM budget;
    bf16 accumulation over <=8 microbatches is the documented trade."""
    return jnp.bfloat16 if cfg.param_count > 2e11 else jnp.float32


def build_train(cfg: ModelConfig, mesh, shape):
    opt_cfg = opt_config_for(cfg)
    nm = train_microbatches(cfg)
    acc_dt = grad_accum_dtype(cfg)

    def init_state():
        from repro.models import init_params
        params = init_params(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": adamw_init(params, opt_cfg)}

    def step(state, batch):
        params = state["params"]
        with shd.use_rules(mesh):
            if nm > 1:
                # microbatch dim is provided by the host batch layout
                # (mb, B/mb, ...), so no resharding reshape is needed
                def micro(gsum, mb):
                    (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        params, cfg, mb)
                    gsum = jax.tree_util.tree_map(
                        lambda a, b: a + b.astype(acc_dt), gsum, g)
                    return gsum, (l, aux["acc"])
                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, acc_dt), params)
                gsum, (ls, accs) = jax.lax.scan(
                    micro, g0, batch, unroll=bool(cfg.unroll_scan))
                grads = jax.tree_util.tree_map(lambda g: g / nm, gsum)
                loss, acc = ls.mean(), accs.mean()
            else:
                (loss, aux), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, cfg, batch)
                acc = aux["acc"]
            params, opt, om = adamw_update(params, grads, state["opt"],
                                           opt_cfg)
        return ({"params": params, "opt": opt},
                {"loss": loss, "acc": acc, **om})

    state_t = jax.eval_shape(init_state)
    state_sh = shd.param_shardings(state_t, mesh)
    state_specs = jax.tree_util.tree_map(
        lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns),
        state_t, state_sh)
    b_specs = batch_specs(cfg, shape.name, mesh)
    if nm > 1:
        def micro_spec(s):
            B = s.shape[0]
            assert B % nm == 0, (B, nm)
            sh = None
            if s.sharding is not None:
                spec = s.sharding.spec
                sh = jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec(None, *spec))
            return jax.ShapeDtypeStruct((nm, B // nm) + s.shape[1:],
                                        s.dtype, sharding=sh)
        b_specs = jax.tree_util.tree_map(micro_spec, b_specs)
    jitted = jax.jit(step, donate_argnums=(0,),
                     out_shardings=(state_sh, None))
    return jitted, (state_specs, b_specs)


def build_prefill(cfg: ModelConfig, mesh, shape):
    def step(params, batch, cache):
        with shd.use_rules(mesh):
            return prefill(params, cfg, batch, cache)

    from repro.models import init_params
    params_t = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    params_sh = shd.param_shardings(params_t, mesh)
    params_specs = jax.tree_util.tree_map(
        lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns),
        params_t, params_sh)
    b_specs = batch_specs(cfg, shape.name, mesh)
    c_specs = cache_specs(cfg, shape.global_batch, shape.seq_len, mesh)
    cache_sh = jax.tree_util.tree_map(lambda s: s.sharding, c_specs)
    jitted = jax.jit(step, donate_argnums=(2,),
                     out_shardings=(None, cache_sh))
    return jitted, (params_specs, b_specs, c_specs)


def build_decode(cfg: ModelConfig, mesh, shape):
    def step(params, tokens, cache, index, memory=None):
        with shd.use_rules(mesh):
            return decode_step(params, cfg, tokens, cache, index,
                               memory=memory)

    from repro.models import init_params
    params_t = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    params_sh = shd.param_shardings(params_t, mesh)
    params_specs = jax.tree_util.tree_map(
        lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns),
        params_t, params_sh)
    d = decode_specs(cfg, shape.name, mesh)
    cache_sh = jax.tree_util.tree_map(lambda s: s.sharding, d["cache"])
    jitted = jax.jit(step, donate_argnums=(2,),
                     out_shardings=(None, cache_sh))
    args = (params_specs, d["tokens"], d["cache"], d["index"])
    if cfg.is_encdec:
        args = args + (d["memory"],)
    return jitted, args


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             overrides: Optional[Dict[str, Any]] = None,
             tag: str = "", rules: str = "default",
             verbose: bool = True) -> Dict[str, Any]:
    shd.set_param_rules(rules)
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    chips = int(np.prod(list(mesh.shape.values())))

    ok, reason = applicable(cfg, shape_name)
    art: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "kind": shape.kind, "tag": tag,
        "params": cfg.param_count, "active_params": cfg.active_param_count,
        "model_flops": model_flops(cfg, shape, kind=shape.kind),
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
    }
    if not ok:
        art["skipped"] = reason
        return art

    builder = {"train": build_train, "prefill": build_prefill,
               "decode": build_decode}[shape.kind]
    bits = 16 if cfg.dtype == jnp.bfloat16 else 32

    # ---- pass 1: full-depth scanned compile -> memory analysis -------------
    # (XLA cost_analysis counts a while body ONCE, so flops/bytes/collectives
    #  come from the unrolled reduced-depth passes below instead.)
    t0 = time.time()
    jitted, specs = builder(cfg, mesh, shape)
    compiled = jitted.lower(*specs).compile()
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca_raw = compiled.cost_analysis() or {}

    # ---- pass 2+3: unrolled depth-R compiles -> exact linear cost model ----
    def cost_at(r: int) -> Dict[str, float]:
        rcfg = dataclasses.replace(
            cfg, n_layers=len(cfg.pattern) * r,
            encoder_layers=(r if cfg.is_encdec else 0),
            unroll_scan=True)
        j, sp = builder(rcfg, mesh, shape)
        comp = j.lower(*sp).compile()
        c = comp.cost_analysis() or {}
        coll = collective_bytes(comp.as_text(), normalize_bits=bits)
        return {"flops": float(c.get("flops", 0.0)),
                "bytes": float(c.get("bytes accessed", 0.0)),
                "coll": {k: float(v) for k, v in coll.items()}}

    t0 = time.time()
    c1, c2 = cost_at(1), cost_at(2)
    t_cost = time.time() - t0
    R = cfg.n_repeats

    def extrap(a1: float, a2: float) -> float:
        return a1 + (R - 1) * (a2 - a1)

    flops = extrap(c1["flops"], c2["flops"])
    bytes_acc = extrap(c1["bytes"], c2["bytes"])
    colls = {k: extrap(c1["coll"][k], c2["coll"][k]) for k in c1["coll"]}

    arg = int(getattr(ma, "argument_size_in_bytes", 0))
    out_b = int(getattr(ma, "output_size_in_bytes", 0))
    tmp = int(getattr(ma, "temp_size_in_bytes", 0))
    alias = int(getattr(ma, "alias_size_in_bytes", 0))
    art.update({
        "cost": {"flops": flops, "bytes_accessed": bytes_acc,
                 "flops_depth1": c1["flops"], "flops_depth2": c2["flops"],
                 "flops_scanned_raw": float(ca_raw.get("flops", 0.0))},
        "memory": {"argument": arg, "output": out_b, "temp": tmp,
                   "alias": alias,
                   "peak_per_device": arg + out_b + tmp - alias,
                   # XLA:CPU upcasts bf16 compute to f32, inflating temp
                   # buffers ~2x vs the TPU lowering; argument/output keep
                   # their declared dtypes.  The estimate halves temp for
                   # bf16 models (fp32 accumulators make it conservative
                   # only to first order — recorded as an ESTIMATE).
                   "peak_per_device_bf16_est":
                       arg + out_b - alias + (tmp // 2 if bits == 16
                                              else tmp)},
        "collectives": colls,
        "compile_s": round(t_compile, 2), "cost_pass_s": round(t_cost, 2),
    })
    if verbose:
        print(f"[{arch} | {shape_name} | {mesh_name}] "
              f"compile {t_compile:.1f}s (+{t_cost:.1f}s cost passes)  "
              f"flops/dev {flops:.3e}  "
              f"peak/dev {art['memory']['peak_per_device']/2**30:.2f} GiB  "
              f"coll/dev {colls['total']/2**20:.1f} MiB")
        print(f"  memory_analysis: {ma}")
    return art


def artifact_path(arch: str, shape_name: str, mesh_name: str,
                  tag: str = "") -> pathlib.Path:
    t = f"--{tag}" if tag else ""
    return RESULTS / f"{arch}--{shape_name}--{mesh_name}{t}.json"


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_NAMES)
    p.add_argument("--shape", choices=list(SHAPES))
    p.add_argument("--mesh", choices=["pod", "multipod"], default="pod")
    p.add_argument("--all", action="store_true")
    p.add_argument("--force", action="store_true")
    p.add_argument("--tag", default="")
    p.add_argument("--rules", default="default",
                   choices=["default", "dp_only"],
                   help="parameter-sharding rule set (perf experiments)")
    p.add_argument("--override", action="append", default=[],
                   help="ModelConfig field override, e.g. remat=False")
    args = p.parse_args()

    overrides: Dict[str, Any] = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        overrides[k] = (False if v == "False" else True if v == "True"
                        else int(v) if v.lstrip("-").isdigit() else
                        float(v) if "." in v else v)

    cells = []
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES:
                for m in ("pod", "multipod"):
                    cells.append((a, s, m))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, args.mesh)]

    RESULTS.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch, s, m in cells:
        path = artifact_path(arch, s, m, args.tag)
        if path.exists() and not args.force:
            print(f"[skip existing] {path.name}")
            continue
        try:
            art = run_cell(arch, s, m, overrides=overrides or None,
                           tag=args.tag, rules=args.rules)
            art["rules"] = args.rules
            path.write_text(json.dumps(art, indent=1))
        except Exception:
            failures += 1
            print(f"[FAIL] {arch} {s} {m}")
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
