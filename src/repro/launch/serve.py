"""Serving launcher: batched generation through the continuous-batching
engine.  ``python -m repro.launch.serve --arch smollm-135m --smoke``"""

import argparse

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config, smoke_config
from repro.models import init_params
from repro.serve import Engine, ServeConfig


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_NAMES, default="smollm-135m")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--max-new", type=int, default=32)
    p.add_argument("--max-len", type=int, default=256)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--tunedb", default=None,
                   help="warm-start kernel dispatch from this record store")
    p.add_argument("--tunedb-backend", default=None,
                   help="pin dispatch to one backend fingerprint")
    p.add_argument("--admission", choices=["fifo", "store"], default="fifo",
                   help="batch admission policy: 'store' prefers pending "
                        "requests whose prefill shapes hit the frozen "
                        "dispatch plan and groups equal prompt lengths")
    p.add_argument("--retune", action="store_true",
                   help="enable in-process continuous retuning "
                        "(drift-triggered sessions + model hot-swap)")
    p.add_argument("--retune-interval", type=int, default=64,
                   help="decode ticks between retune-controller polls")
    p.add_argument("--retune-async", action="store_true",
                   help="run triggered retune epochs on a background "
                        "thread: polls submit and return, the swap lands "
                        "when the session+retrain completes")
    p.add_argument("--retune-fleet", default=None,
                   help="fleet directory to publish drift-triggered plans "
                        "to (run `python -m repro.tunedb fleet worker` "
                        "processes against it); implies --retune-async")
    p.add_argument("--retune-cooldown-ticks", type=int, default=0,
                   help="decode ticks a retune blocks the next trigger for")
    p.add_argument("--retune-max-sessions", type=int, default=0,
                   help="retune sessions allowed per --retune-window "
                        "seconds (0 = unlimited)")
    p.add_argument("--retune-window", type=float, default=600.0)
    p.add_argument("--retune-min-gain", type=float, default=0.0,
                   help="skip epochs whose projected gain over the "
                        "nearest-record tier is below this fraction")
    p.add_argument("--retune-sentry", type=float, default=None,
                   help="regression-sentry noise margin gating each "
                        "retune's serving swap (omit to disable)")
    p.add_argument("--plan-dir", default=None,
                   help="cold-start from this persisted plan artifact "
                        "(`tunedb plan export`) instead of compiling one "
                        "at install time")
    p.add_argument("--follow", default=None,
                   help="plan registry directory to follow: each published "
                        "generation is pulled, digest-verified, and "
                        "hot-swapped into serving")
    p.add_argument("--follow-interval", type=float, default=2.0,
                   help="seconds between plan-registry polls")
    p.add_argument("--retune-publish", default=None,
                   help="plan registry directory each successful retune "
                        "publishes its compiled plan to")
    p.add_argument("--telemetry-export", type=float, default=0.0,
                   help="with --retune-fleet: export this engine's shape "
                        "telemetry to the fleet bus every N seconds and "
                        "retune off the aggregated fleet-global view "
                        "(0 = process-local telemetry)")
    p.add_argument("--router", choices=["affinity", "round_robin", "random"],
                   default=None,
                   help="request-router policy: 'affinity' routes each "
                        "request to the replica whose dispatch plan covers "
                        "its shapes (load-bounded, with a no-starvation "
                        "escape); omit to disable routing")
    p.add_argument("--status-port", type=int, default=None,
                   help="serve /metrics, /status, /plan and /trace from "
                        "inside the engine on this port (0 = ephemeral)")
    p.add_argument("--trace-sample", type=float, default=0.0,
                   help="request-trace sampling rate (0 = tracing off, "
                        "1.0 = every trace root); spans export via /trace, "
                        "--trace-out, and `tunedb trace`")
    p.add_argument("--trace-out", default=None,
                   help="write the run's spans as Chrome trace-event JSON "
                        "here after generation (open in Perfetto)")
    p.add_argument("--request-deadline", type=float, default=None,
                   help="per-request wall-clock deadline in seconds, "
                        "enforced at decode-tick boundaries: overdue "
                        "pending requests are rejected unserved, overdue "
                        "active ones retire with the tokens they have")
    p.add_argument("--shed-threshold", type=int, default=None,
                   help="admission backlog cap: while active+pending "
                        "exceeds it the newest arrivals are shed and "
                        "/healthz answers 503 until the backlog drains")
    p.add_argument("--measure", choices=["wallclock", "sim"], default=None,
                   help="re-measure model top-k candidates on the serving "
                        "path: 'wallclock' times real kernels on TPU "
                        "(simulated fallback off-hardware, warns once), "
                        "'sim' always uses the analytic backend")
    args = p.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if jax.default_backend() == "cpu" and not args.smoke \
            and cfg.param_count > 1e9:
        raise SystemExit(f"{cfg.name} is dry-run-only here; use --smoke")
    if cfg.is_encdec:
        raise SystemExit("enc-dec serving is exercised via the dry-run "
                         "decode cells; the engine serves LM archs")

    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(
        max_len=args.max_len, slots=args.slots,
        temperature=args.temperature, tunedb=args.tunedb,
        tunedb_backend=args.tunedb_backend, admission=args.admission,
        retune=args.retune,
        retune_interval=args.retune_interval,
        retune_async=args.retune_async,
        retune_fleet=args.retune_fleet,
        retune_cooldown_ticks=args.retune_cooldown_ticks,
        retune_max_sessions=args.retune_max_sessions,
        retune_window_s=args.retune_window,
        retune_min_gain=args.retune_min_gain,
        retune_sentry=args.retune_sentry,
        plan_dir=args.plan_dir,
        follow=args.follow,
        follow_interval_s=args.follow_interval,
        retune_publish=args.retune_publish,
        telemetry_export_s=args.telemetry_export,
        router=args.router,
        status_port=args.status_port,
        trace_sample=args.trace_sample,
        request_deadline_s=args.request_deadline,
        shed_threshold=args.shed_threshold,
        measure=args.measure))
    if eng.status_server is not None:
        print(f"status endpoint: {eng.status_server.url} "
              f"(/metrics /status /plan /trace /healthz)")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, args.prompt_len)
               for _ in range(args.requests)]
    import time
    t0 = time.perf_counter()
    outs = eng.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    print(f"{len(outs)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, {eng.ticks} decode ticks, "
          f"{total/max(eng.ticks,1):.2f} tokens/tick)")
    if args.shed_threshold is not None or args.request_deadline is not None:
        print(f"degradation: {eng.shed_requests} request(s) shed, "
              f"{eng.deadline_retired} deadline-retired")
    if eng.controller is not None:
        if eng.controller.async_active():
            print("waiting for the in-flight async retune to land...")
            if (eng.controller.wait_async(timeout=60.0) is None
                    and eng.controller.async_active()):
                # a fleet with no live workers can outwait this launcher;
                # the published jobs persist on the bus either way
                print("async retune still in flight after 60s — exiting; "
                      "fleet jobs stay queued (run `fleet worker` / "
                      "`fleet drain --wait` to finish and merge them)")
        st = eng.controller.stats()
        print(f"retune: {st['retunes']} epoch(s) over {st['checks']} polls, "
              f"serving generation {st['generation']} "
              f"(telemetry scope: {st['telemetry_scope']})")
    if eng.router is not None:
        rt = eng.router.stats()
        print(f"router[{rt['policy']}]: {rt['decisions']} decision(s) "
              f"by outcome {rt['outcomes']}")
    if eng.tracer is not None:
        ts = eng.tracer.stats()
        print(f"trace: {ts['sampled']} root(s) sampled, "
              f"{ts['dropped']} dropped, {ts['spans']} span(s) retained")
        if args.trace_out:
            n = eng.tracer.export(args.trace_out)
            print(f"trace: wrote {n} span(s) -> {args.trace_out} "
                  "(open in https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
