"""Training launcher: ``python -m repro.launch.train --arch smollm-135m ...``

Single-process driver for the host devices (the same Trainer the examples
use); on a real multi-host pod this module is what each host would run after
``jax.distributed.initialize()``.  Fault-tolerance wiring: auto-resume from
the newest checkpoint, async snapshots, SIGTERM-graceful exit, straggler
monitor, deterministic data resume.

XLA flags: latency-hiding scheduler + async collectives are what a real TPU
deployment sets; they are exported here (harmless on CPU).
"""

import os
os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_latency_hiding_scheduler=true")

import argparse

import jax

from repro.configs import ARCH_NAMES, get_config, smoke_config
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainConfig


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_NAMES, default="smollm-135m")
    p.add_argument("--smoke", action="store_true",
                   help="use the reduced smoke config (CPU-trainable)")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--seq-len", type=int, default=512)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--compress-grads", action="store_true")
    p.add_argument("--checkpoint-dir", default=None)
    p.add_argument("--checkpoint-every", type=int, default=100)
    args = p.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if jax.default_backend() == "cpu" and not args.smoke \
            and cfg.param_count > 1e9:
        raise SystemExit(
            f"{cfg.name} has {cfg.param_count/1e9:.0f}B params - on this "
            "host run with --smoke (full configs are dry-run only here)")

    trainer = Trainer(
        cfg,
        AdamWConfig(lr=args.lr, total_steps=args.steps,
                    warmup_steps=max(args.steps // 20, 1)),
        TrainConfig(steps=args.steps, microbatches=args.microbatches,
                    compress_grads=args.compress_grads,
                    checkpoint_dir=args.checkpoint_dir,
                    checkpoint_every=args.checkpoint_every),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                   global_batch=args.global_batch),
    )
    result = trainer.run()
    print(f"final loss: {result['history'][-1]['loss']:.4f}  "
          f"straggler events: {len(result['straggler_events'])}")


if __name__ == "__main__":
    main()
