"""Trainer: jitted train_step builder + the training loop glue.

make_train_step wires together model loss, gradient compression (int8 +
error feedback, applied to the cross-pod reduction payload), AdamW with
dtype-configurable states, and microbatch gradient accumulation.  Under a
mesh, params/opt-state get rule-based shardings (parallel/sharding.py) and
the step is jitted with donate_argnums so buffers are reused in place.

The Trainer class is the single-process driver used by the examples and the
launcher: auto-resume from the latest checkpoint, periodic async snapshots,
preemption-safe exit, straggler monitoring.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models import ModelConfig, init_params, loss_fn
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compress_grads, decompress_grads,
                         init_error_feedback)
from . import checkpoint as ckpt
from .fault import PreemptionHandler, StragglerMonitor


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatches: int = 1            # grad accumulation factor
    compress_grads: bool = False     # int8 + error feedback
    stochastic_rounding: bool = False
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    async_checkpoint: bool = True
    log_every: int = 10
    seed: int = 0


def make_train_step(model_cfg: ModelConfig, opt_cfg: AdamWConfig,
                    train_cfg: TrainConfig,
                    mesh=None) -> Callable:
    """Returns step(state, batch) -> (state, metrics); state = dict with
    params / opt / ef / rng."""

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(
            params, model_cfg, batch)

    def step(state, batch):
        params = state["params"]
        nm = train_cfg.microbatches
        if nm > 1:
            # split batch along the batch axis, accumulate grads in fp32
            def micro(carry, mb):
                gsum, lsum, asum = carry
                (loss, aux), g = grads_of(params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss, asum + aux["acc"]), None

            def reshape_mb(x):
                b = x.shape[0]
                return x.reshape(nm, b // nm, *x.shape[1:])
            mbs = jax.tree_util.tree_map(reshape_mb, batch)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum, asum), _ = jax.lax.scan(
                micro, (g0, jnp.zeros(()), jnp.zeros(())), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / nm, gsum)
            loss, acc = lsum / nm, asum / nm
        else:
            (loss, aux), grads = grads_of(params, batch)
            acc = aux["acc"]

        metrics = {"loss": loss, "acc": acc}
        ef = state.get("ef")
        if train_cfg.compress_grads and ef is not None:
            q, scales, ef = compress_grads(grads, ef)
            grads = decompress_grads(q, scales)
            metrics["ef_norm"] = jnp.sqrt(sum(
                jnp.sum(jnp.square(e)) for e in jax.tree_util.tree_leaves(ef)))

        sr_key = None
        rng = state["rng"]
        if train_cfg.stochastic_rounding:
            rng, sr_key = jax.random.split(rng)
        params, opt, om = adamw_update(params, grads, state["opt"], opt_cfg,
                                       sr_key=sr_key)
        metrics.update(om)
        new_state = {"params": params, "opt": opt, "rng": rng}
        if ef is not None:
            new_state["ef"] = ef
        return new_state, metrics

    if mesh is not None:
        return jax.jit(step, donate_argnums=(0,))
    return jax.jit(step, donate_argnums=(0,))


def init_train_state(model_cfg: ModelConfig, opt_cfg: AdamWConfig,
                     train_cfg: TrainConfig, key: jax.Array) -> Dict[str, Any]:
    params = init_params(model_cfg, key)
    state = {"params": params, "opt": adamw_init(params, opt_cfg),
             "rng": jax.random.PRNGKey(train_cfg.seed + 1)}
    if train_cfg.compress_grads:
        state["ef"] = init_error_feedback(params)
    return state


class Trainer:
    """Single-driver training loop with checkpoint/resume/fault handling."""

    def __init__(self, model_cfg: ModelConfig, opt_cfg: AdamWConfig,
                 train_cfg: TrainConfig, data_cfg: DataConfig, mesh=None):
        self.model_cfg, self.opt_cfg = model_cfg, opt_cfg
        self.train_cfg, self.data_cfg = train_cfg, data_cfg
        self.mesh = mesh
        self.pipeline = SyntheticTokenPipeline(data_cfg)
        self.step_fn = make_train_step(model_cfg, opt_cfg, train_cfg, mesh)
        self.monitor = StragglerMonitor()
        self.preempt = PreemptionHandler()
        self._ckpt_thread = None
        self.history: list = []

    # -- state ----------------------------------------------------------------
    def init_or_resume(self) -> Tuple[Dict[str, Any], int]:
        tc = self.train_cfg
        key = jax.random.PRNGKey(tc.seed)
        if tc.checkpoint_dir and ckpt.latest_step(tc.checkpoint_dir) is not None:
            template = jax.eval_shape(
                lambda: init_train_state(self.model_cfg, self.opt_cfg, tc, key))
            template = jax.tree_util.tree_map(
                lambda s: np.zeros(s.shape, s.dtype), template)
            state, step, data_step = ckpt.load_checkpoint(
                tc.checkpoint_dir, template)
            state = jax.tree_util.tree_map(jnp.asarray, state)
            return state, step
        return init_train_state(self.model_cfg, self.opt_cfg, tc, key), 0

    def _save(self, state, step):
        tc = self.train_cfg
        if not tc.checkpoint_dir:
            return
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
        self._ckpt_thread = ckpt.save_checkpoint(
            tc.checkpoint_dir, step, state, data_step=step,
            async_save=tc.async_checkpoint)

    # -- loop -----------------------------------------------------------------
    def run(self, verbose: bool = True) -> Dict[str, Any]:
        tc = self.train_cfg
        state, start = self.init_or_resume()
        step = start
        for step in range(start, tc.steps):
            batch = jax.tree_util.tree_map(
                jnp.asarray, self.pipeline.batch(step))
            self.monitor.step_start()
            state, metrics = self.step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = self.monitor.step_end(step)
            self.history.append({"step": step, "loss": loss,
                                 "time": dt})
            if verbose and (step % tc.log_every == 0 or step == tc.steps - 1):
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"acc {float(metrics['acc']):.3f}  {dt*1e3:.0f} ms")
            if tc.checkpoint_dir and step > start \
                    and step % tc.checkpoint_every == 0:
                self._save(state, step)
            if self.preempt.should_stop:
                self._save(state, step)
                break
        self._save(state, step + 1) if tc.checkpoint_dir else None
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
        return {"state": state, "history": self.history,
                "straggler_events": self.monitor.events}
