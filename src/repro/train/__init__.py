from .trainer import Trainer, TrainConfig, make_train_step
from .checkpoint import save_checkpoint, load_checkpoint, latest_step
from .fault import PreemptionHandler, StragglerMonitor

__all__ = ["Trainer", "TrainConfig", "make_train_step", "save_checkpoint",
           "load_checkpoint", "latest_step", "PreemptionHandler",
           "StragglerMonitor"]
