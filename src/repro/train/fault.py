"""Fault tolerance: preemption handling, straggler mitigation, auto-resume.

On a real 1000+-node cluster the failure model is: (a) planned preemptions
(maintenance) delivered as SIGTERM with a grace window, (b) hard node loss
(job restarts from the latest checkpoint; the elastic loader reshards), and
(c) stragglers (a slow chip stretches every synchronous step).  This module
implements the coordinator-side machinery for (a) and (c); (b) is covered by
checkpoint.py + the launcher's auto-resume (launch/train.py).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, List, Optional


class PreemptionHandler:
    """SIGTERM/SIGINT -> graceful "checkpoint now, then exit" flag.

    Usage:
        handler = PreemptionHandler()
        for step in ...:
            train_step(...)
            if handler.should_stop:
                save_checkpoint(...); break
    """

    def __init__(self, signals=(signal.SIGTERM,)):
        self.should_stop = False
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handle)
            except ValueError:          # non-main thread (tests)
                pass

    def _handle(self, signum, frame):
        self.should_stop = True

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


@dataclasses.dataclass
class StragglerMonitor:
    """Per-step deadline tracking with an EWMA baseline.

    A synchronous SPMD step runs at the speed of the slowest chip; the
    monitor detects when recent steps exceed `threshold` x the EWMA baseline
    and invokes `on_straggler` — on a real cluster that callback triggers
    hot-spare swap / topology rebalance; the default callback records the
    event so the trainer can surface it in metrics and logs.
    """

    threshold: float = 2.0
    ewma_alpha: float = 0.1
    grace_steps: int = 5
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    def __post_init__(self):
        self._ewma: Optional[float] = None
        self._seen = 0
        self.events: List[dict] = []
        self._t0: Optional[float] = None

    def step_start(self) -> None:
        self._t0 = time.perf_counter()

    def step_end(self, step: int) -> float:
        dt = time.perf_counter() - self._t0
        self._seen += 1
        if self._ewma is None:
            self._ewma = dt
        if self._seen > self.grace_steps and dt > self.threshold * self._ewma:
            evt = {"step": step, "step_time": dt, "baseline": self._ewma}
            self.events.append(evt)
            if self.on_straggler:
                self.on_straggler(step, dt, self._ewma)
        else:
            # only healthy steps update the baseline (a straggler must not
            # poison its own detector)
            self._ewma = (1 - self.ewma_alpha) * self._ewma \
                + self.ewma_alpha * dt
        return dt
