"""Sharded, mesh-agnostic, async checkpointing.

Format: one directory per step containing
  manifest.json  — tree structure, shapes, dtypes, step, data_step
  arrays.npz     — one entry per leaf, keyed by pytree path

Save is atomic (write to ``step-K.tmp``, rename) and optionally async (a
background thread serializes a host snapshot while training continues —
the jax arrays are copied to host synchronously first, which is the cheap
part).  Load reshapes nothing: arrays are ``device_put`` against *whatever
shardings the current mesh wants*, so a checkpoint written on a 256-chip mesh
restores onto 512 chips or 1 host unchanged — this is the elastic-scaling
story.  On a real multi-host pod each host would write only its addressable
shards plus the shared manifest; the format (path-keyed leaves + manifest)
is chosen so that extension is additive.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten(template: Any, arrays: Dict[str, np.ndarray]) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        a = arrays[key]
        assert a.shape == tuple(np.shape(leaf)), (key, a.shape, np.shape(leaf))
        leaves.append(a)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory: str, step: int, state: Any, *,
                    data_step: int = 0, async_save: bool = False,
                    keep: int = 3) -> threading.Thread | None:
    """Snapshot `state` (any pytree) at `step`.  Returns the writer thread
    when async_save (join it before exiting), else None."""
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    # synchronous device->host snapshot (consistent point-in-time copy)
    host = _flatten(state)

    def write():
        tmp = d / f"step-{step}.tmp"
        final = d / f"step-{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        np.savez(tmp / "arrays.npz", **host)
        (tmp / "manifest.json").write_text(json.dumps({
            "step": step, "data_step": data_step,
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                     for k, v in host.items()},
        }))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(d, keep)

    if async_save:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _gc(d: pathlib.Path, keep: int) -> None:
    steps = sorted(int(p.name.split("-")[1]) for p in d.glob("step-*")
                   if p.is_dir() and not p.name.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(d / f"step-{s}", ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = [int(p.name.split("-")[1]) for p in d.glob("step-*")
             if p.is_dir() and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def load_checkpoint(directory: str, template: Any, *,
                    step: Optional[int] = None,
                    shardings: Any = None) -> Tuple[Any, int, int]:
    """Restore (state, step, data_step).  `template` supplies the tree
    structure + shapes (e.g. from jax.eval_shape of the init fn); `shardings`
    (optional, mirroring the tree) places each leaf on the current mesh —
    this is where elastic resharding happens."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    d = pathlib.Path(directory) / f"step-{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    with np.load(d / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files}
    state = _unflatten(template, arrays)
    if shardings is not None:
        flat_s, treedef = jax.tree_util.tree_flatten(shardings)
        flat_x = treedef.flatten_up_to(state)
        state = jax.tree_util.tree_unflatten(
            treedef, [jax.device_put(x, s) for x, s in zip(flat_x, flat_s)])
    return state, manifest["step"], manifest.get("data_step", 0)
