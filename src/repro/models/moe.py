"""Mixture-of-Experts layer (dbrx / arctic / jamba).

Three execution paths, chosen by the layer wrapper:

  moe()        — single-device / no-mesh reference path (smoke tests): the
                 argsort+scatter capacity dispatch, pure jnp.
  moe_ep()     — production expert-parallel path via shard_map: experts are
                 sharded over the 'model' mesh axis; activations arrive
                 batch-sharded and model-replicated, so dispatch is a purely
                 LOCAL gather/scatter into each device's own expert buffers,
                 expert FFNs run on local weights, and the only communication
                 is one psum over 'model' to combine expert outputs — the
                 same wire cost as a TP MLP all-reduce.  This is the
                 jax-native mapping of the GShard/Switch all-to-all pattern
                 (DESIGN.md §6): GSPMD cannot shard a data-dependent scatter
                 on its own, so the EP structure is made explicit.
  moe_decode() — decode path (few tokens): every expert runs on every token
                 (dense einsum over the expert axis, EP-sharded by GSPMD) and
                 a sparse (T, E) weight matrix combines — no gathers of
                 expert weight slabs, which would defeat EP sharding.

The expert FFN is three batched rectangular GEMMs (E, C, D) x (E, D, F):
exactly the small-irregular GEMM regime the paper's input-aware tuner
targets (DESIGN.md §5).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from .layers import Params, dense_init


def init_moe(key: jax.Array, d_model: int, d_ff: int, n_experts: int,
             dtype) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d_model, n_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (n_experts, d_model, d_ff), dtype,
                             fan_in=d_model),
        "w_up": dense_init(ks[2], (n_experts, d_model, d_ff), dtype,
                           fan_in=d_model),
        "w_down": dense_init(ks[3], (n_experts, d_ff, d_model), dtype,
                             fan_in=d_ff),
    }


def _route(router_logits: jax.Array, top_k: int
           ) -> Tuple[jax.Array, jax.Array]:
    """(T, E) -> (weights (T, k), expert ids (T, k)); weights renormalized."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx


def _aux_loss(logits: jax.Array, idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch load-balancing loss: E * sum_e f_e * p_e."""
    me = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).mean(
        axis=tuple(range(logits.ndim - 1)))
    fe = jax.nn.one_hot(idx[..., 0], n_experts).mean(
        axis=tuple(range(idx.ndim - 1)))
    return (n_experts * jnp.sum(me * fe)).astype(jnp.float32)


def _capacity(S: int, top_k: int, n_experts: int, cf: float) -> int:
    return max(int(math.ceil(S * top_k * cf / n_experts)), 1)


def _dispatch_row(x_row, w_row, idx_row, *, n_experts: int, top_k: int,
                  C: int, e_first: int, e_count: int):
    """One sequence row -> (buffers (e_count, C, D), combine metadata).

    Slot-major formulation: all O(D)-wide intermediates are sized by the
    local expert capacity (e_count*C), never by S*top_k — the token->slot
    permutation is computed on integer vectors and then applied as ONE
    gather of shape (e_count*C, D).  (A token-major x_row[tok] gather would
    materialize an S*top_k x D buffer — 4x the activations, and 16x wasted
    on an EP device that only owns 1/16th of the experts.)"""
    S, D = x_row.shape
    k = top_k
    flat_e = idx_row.reshape(S * k)
    flat_t = jnp.repeat(jnp.arange(S), k)
    flat_w = w_row.reshape(S * k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=n_experts)
    start = jnp.cumsum(counts) - counts
    pos = jnp.arange(S * k) - start[sorted_e]               # slot in expert
    local = (sorted_e >= e_first) & (sorted_e < e_first + e_count)
    keep = (pos < C) & local
    slot = jnp.where(keep, (sorted_e - e_first) * C + pos, e_count * C)
    tok = flat_t[order]
    # invert: which token (and weight) fills each local slot
    n_slots = e_count * C
    slot_tok = jnp.zeros((n_slots + 1,), jnp.int32).at[slot].set(
        tok.astype(jnp.int32), mode="drop")[:-1]
    slot_w = jnp.zeros((n_slots + 1,), jnp.float32).at[slot].set(
        flat_w[order], mode="drop")[:-1]
    slot_valid = jnp.zeros((n_slots + 1,), jnp.bool_).at[slot].set(
        keep, mode="drop")[:-1]
    buf = x_row[slot_tok] * slot_valid[:, None].astype(x_row.dtype)
    return buf.reshape(e_count, C, D), (slot_tok, slot_w, slot_valid)


def _combine_row(y_row, meta, *, S: int, D: int):
    """Scatter-add local expert outputs back to tokens: O(e_count*C*D)."""
    slot_tok, slot_w, slot_valid = meta
    contrib = y_row * (slot_w * slot_valid)[:, None].astype(y_row.dtype)
    out = jnp.zeros((S, D), y_row.dtype)
    return out.at[slot_tok].add(contrib, mode="drop")


def _expert_ffn(buffers, wg, wu, wd):
    """(B, E, C, D) x (E, D, F) -> (B, E, C, D), batched rectangular GEMMs."""
    g = jnp.einsum("becd,edf->becf", buffers, wg)
    u = jnp.einsum("becd,edf->becf", buffers, wu)
    return jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, wd)


def moe(p: Params, x: jax.Array, *, n_experts: int, top_k: int,
        capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """Reference path (no mesh): x (B, S, D) -> (out, aux_loss)."""
    B, S, D = x.shape
    E = n_experts
    C = _capacity(S, top_k, E, capacity_factor)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    w, idx = _route(logits.reshape(B * S, E), top_k)
    w = w.reshape(B, S, top_k)
    idx = idx.reshape(B, S, top_k)
    aux = _aux_loss(logits, idx, E)

    buffers, meta = jax.vmap(
        lambda xr, wr, ir: _dispatch_row(
            xr, wr, ir, n_experts=E, top_k=top_k, C=C, e_first=0, e_count=E)
    )(x, w, idx)                                            # (B, E, C, D)
    ye = _expert_ffn(buffers, p["w_gate"], p["w_up"], p["w_down"])
    ye = ye.reshape(B, E * C, D)
    out = jax.vmap(
        lambda yr, mr: _combine_row(yr, mr, S=S, D=D)
    )(ye, meta)
    return out.astype(x.dtype), aux


def moe_ep(p: Params, x: jax.Array, *, n_experts: int, top_k: int,
           capacity_factor: float, mesh, model_axis: str = "model"
           ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel path (production): see module docstring."""
    B, S, D = x.shape
    E = n_experts
    tp = mesh.shape[model_axis]
    e_loc = E // tp
    C = _capacity(S, top_k, E, capacity_factor)
    batch_axes = tuple(a for a in mesh.axis_names if a != model_axis)

    def local_fn(router, wg, wu, wd, x_loc):
        # x_loc (B_loc, S, D) — replicated over model_axis; wg (e_loc, D, F)
        e_first = jax.lax.axis_index(model_axis) * e_loc
        Bl = x_loc.shape[0]
        logits = jnp.einsum("bsd,de->bse", x_loc.astype(jnp.float32), router)
        w, idx = _route(logits.reshape(Bl * S, E), top_k)
        w = w.reshape(Bl, S, top_k)
        idx = idx.reshape(Bl, S, top_k)
        aux = _aux_loss(logits, idx, E)

        buffers, meta = jax.vmap(
            lambda xr, wr, ir: _dispatch_row(
                xr, wr, ir, n_experts=E, top_k=top_k, C=C,
                e_first=e_first, e_count=e_loc)
        )(x_loc, w, idx)                                    # (Bl, e_loc, C, D)
        ye = _expert_ffn(buffers, wg, wu, wd)
        ye = ye.reshape(Bl, e_loc * C, D)
        part = jax.vmap(
            lambda yr, mr: _combine_row(yr, mr, S=S, D=D)
        )(ye, meta)
        # combine expert partial outputs — the EP "all-to-all return trip"
        # collapsed into one all-reduce (same bytes as a TP MLP psum)
        return jax.lax.psum(part, model_axis), aux

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), P(model_axis), P(model_axis), P(model_axis),
                  P(batch_axes)),
        out_specs=(P(batch_axes), P()),
        check_vma=False)
    out, aux = fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)
    return out.astype(x.dtype), aux


def moe_ep_a2a(p: Params, x: jax.Array, *, n_experts: int, top_k: int,
               capacity_factor: float, mesh, model_axis: str = "model"
               ) -> Tuple[jax.Array, jax.Array]:
    """All-to-all expert parallelism (hillclimb H1-iter3; EXPERIMENTS §Perf).

    moe_ep() gathers the full sequence onto every device and psums the
    output back — 2 full-activation collectives per layer.  Here the
    sequence stays sharded over `model_axis`: each device routes only its
    S/tp token slice into capacity-bounded per-destination buffers, ONE
    all-to-all ships tokens to their expert owners, expert FFNs run, and a
    second all-to-all returns outputs to be combined locally.  Wire bytes
    drop from ~2*S*D to ~2*(S/tp)*k*cf*D per device — the GShard/Switch
    pattern expressed TPU-natively.
    """
    B, S, D = x.shape
    E = n_experts
    tp = mesh.shape[model_axis]
    e_loc = E // tp
    S_loc = S // tp
    C = _capacity(S_loc, top_k, E, capacity_factor)   # per (src, expert)
    batch_axes = tuple(a for a in mesh.axis_names if a != model_axis)

    def local_fn(router, wg, wu, wd, x_loc):
        # x_loc (Bl, S_loc, D); wg (e_loc, D, F)
        Bl = x_loc.shape[0]
        logits = jnp.einsum("bsd,de->bse", x_loc.astype(jnp.float32), router)
        w, idx = _route(logits.reshape(Bl * S_loc, E), top_k)
        w = w.reshape(Bl, S_loc, top_k)
        idx = idx.reshape(Bl, S_loc, top_k)
        aux = jax.lax.pmean(_aux_loss(logits, idx, E), model_axis)

        # local dispatch into per-(destination expert) buffers
        buffers, meta = jax.vmap(
            lambda xr, wr, ir: _dispatch_row(
                xr, wr, ir, n_experts=E, top_k=top_k, C=C,
                e_first=0, e_count=E)
        )(x_loc, w, idx)                               # (Bl, E, C, D)

        # ship to owners: (E = tp*e_loc) -> exchange over the leading tp
        send = buffers.reshape(Bl, tp, e_loc, C, D).transpose(1, 0, 2, 3, 4)
        recv = jax.lax.all_to_all(send, model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv (tp=src, Bl, e_loc, C, D): all slots this device's experts own
        xe = recv.transpose(1, 2, 0, 3, 4).reshape(Bl, e_loc, tp * C, D)
        ye = _expert_ffn(xe, wg, wu, wd)
        back = ye.reshape(Bl, e_loc, tp, C, D).transpose(2, 0, 1, 3, 4)
        ret = jax.lax.all_to_all(back, model_axis, split_axis=0,
                                 concat_axis=0, tiled=False)
        # ret (tp=dest-expert-group, Bl, e_loc, C, D) == original slot layout
        y = ret.transpose(1, 0, 2, 3, 4).reshape(Bl, E * C, D)
        out = jax.vmap(
            lambda yr, mr: _combine_row(yr, mr, S=S_loc, D=D)
        )(y, meta)
        return out, aux

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), P(model_axis), P(model_axis), P(model_axis),
                  P(batch_axes, model_axis)),
        out_specs=(P(batch_axes, model_axis), P()),
        check_vma=False)
    out, aux = fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)
    return out.astype(x.dtype), aux


def moe_decode(p: Params, x: jax.Array, *, n_experts: int, top_k: int
               ) -> jax.Array:
    """Decode path (S small): dense over experts + sparse combine."""
    B, S, D = x.shape
    E, k = n_experts, top_k
    T = B * S
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    w, idx = _route(logits.reshape(T, E), k)                # (T, k)
    xt = x.reshape(T, D)
    g = jnp.einsum("td,edf->etf", xt, p["w_gate"])
    u = jnp.einsum("td,edf->etf", xt, p["w_up"])
    y = jnp.einsum("etf,efd->etd", jax.nn.silu(g) * u, p["w_down"])
    we = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], idx].add(w)                 # sparse combine
    out = jnp.einsum("etd,te->td", y.astype(jnp.float32), we)
    return out.reshape(B, S, D).astype(x.dtype)
