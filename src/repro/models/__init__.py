from .model import (ModelConfig, init_params, forward, loss_fn, init_cache,
                    decode_step, prefill, encode,
                    ATTN, MAMBA, DENSE, MOE_MLP, MOE_DENSE, NONE)

__all__ = ["ModelConfig", "init_params", "forward", "loss_fn", "init_cache",
           "decode_step", "prefill", "encode",
           "ATTN", "MAMBA", "DENSE", "MOE_MLP", "MOE_DENSE", "NONE"]
