"""Unified composable LM: dense / MoE / SSM / hybrid / enc-dec / stub-frontend.

One :class:`ModelConfig` covers all ten assigned architectures.  Layers are
described by a repeating *pattern* of (mixer, mlp) pairs; parameters for each
pattern position are stacked over the repeat count and the forward pass is a
``jax.lax.scan`` over repeats (essential for the 126-layer llama3-405b HLO to
stay compact) with optional remat.

Entry points:
  init_params(cfg, key)                     -> param pytree
  forward(params, cfg, batch)               -> logits/hidden (training path)
  loss_fn(params, cfg, batch)               -> (loss, metrics)
  init_cache(cfg, batch, max_len)           -> decode cache pytree
  prefill(params, cfg, batch, cache)        -> (last_logits, cache)
  decode_step(params, cfg, tokens, cache, index) -> (logits, cache)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain
from . import layers as L
from . import moe as MOE
from . import ssm as SSM

Params = Dict[str, Any]

ATTN, MAMBA = "attn", "mamba"
DENSE, MOE_MLP, MOE_DENSE, NONE = "dense", "moe", "moe+dense", "none"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                      # 0 => d_model // n_heads
    # pattern: ((mixer, mlp), ...) repeated n_layers // len(pattern) times
    pattern: Tuple[Tuple[str, str], ...] = ((ATTN, DENSE),)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssd_chunk: int = 256
    # encoder-decoder (whisper): encoder_layers > 0 enables cross-attention
    encoder_layers: int = 0
    encoder_len: int = 0                   # stub frame count
    # stub frontends: 'none' | 'audio' | 'vision'
    frontend: str = "none"
    n_frontend_tokens: int = 0             # vision: patch embeds prepended
    # numerics / structure
    qk_norm: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    remat: bool = True
    attn_chunk: int = 1024
    logit_chunk: int = 512
    tie_embeddings: bool = True
    decode_kv_splits: int = 1      # >1: SP flash-decoding over the KV cache
    # --- beyond-paper perf knobs (EXPERIMENTS.md §Perf; default = baseline)
    causal_block_skip: bool = False   # skip upper-tri attention blocks (~2x)
    decode_replicate_acts: bool = False  # decode: replicate tiny activations
    #   so projections consume 2D-TP weights in place (no weight gathers)
    moe_a2a: bool = False             # all-to-all EP (vs gather+psum EP)
    mlp_tp: bool = True               # False: pure-SP MLP (tiny models whose
    #   TP slices are smaller than the resharding they cost; pair w/ dp_only)
    # cost-accounting mode (launch/dryrun.py): XLA cost_analysis counts a
    # while-loop body ONCE, so for exact FLOP/byte/collective accounting the
    # dry-run compiles reduced-depth configs with every scan unrolled and
    # extrapolates linearly in depth.  Never set for real execution.
    unroll_scan: bool = False

    # ---- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab, 256)

    @property
    def n_repeats(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            self.name, self.n_layers, len(self.pattern))
        return self.n_layers // len(self.pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def param_count(self) -> int:
        """Total parameters (for 6ND model-FLOPs accounting)."""
        return self._count_params(active=False)

    @property
    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        return self._count_params(active=True)

    def _count_params(self, active: bool) -> int:
        n = self.padded_vocab * self.d_model      # embed (tied head)
        if not self.tie_embeddings:
            n *= 2
        n += self.d_model                         # final norm
        per = self._layer_params(active=active)
        n += self.n_repeats * sum(per)
        if self.is_encdec:
            # decoder cross-attention blocks (+ their norms)
            n += self.n_layers * (self._attn_params() + self.d_model)
            # encoder stack: plain (attn, dense) layers + final norm
            enc = (self._attn_params() + 3 * self.d_model * self.d_ff
                   + 2 * self.d_model)
            n += self.encoder_layers * enc + self.d_model
        return n

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.hd
        return d * (self.n_heads + 2 * self.n_kv) * hd + self.n_heads * hd * d

    def _layer_params(self, active: bool = False) -> Tuple[int, ...]:
        d, f = self.d_model, self.d_ff
        out = []
        for mixer, mlp_kind in self.pattern:
            n = 2 * d                                        # norms
            if mixer == ATTN:
                n += self._attn_params()
            else:
                di = 2 * d
                nh = di // self.ssm_head_dim
                n += d * (2 * di + 2 * self.ssm_state + nh) + di * d
            if mlp_kind in (DENSE, MOE_DENSE):
                n += 3 * d * f
            if mlp_kind in (MOE_MLP, MOE_DENSE):
                e = self.top_k if active else self.n_experts
                n += d * self.n_experts + e * 3 * d * f
            out.append(n)
        return tuple(out)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, key: jax.Array, mixer: str, mlp_kind: str,
                cross: bool) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"norm1": jnp.ones((cfg.d_model,), cfg.dtype),
                 "norm2": jnp.ones((cfg.d_model,), cfg.dtype)}
    if mixer == ATTN:
        p["attn"] = L.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                     cfg.n_kv, cfg.hd, cfg.qk_norm, cfg.dtype)
    else:
        p["mamba"] = SSM.init_mamba(ks[0], cfg.d_model, cfg.ssm_state,
                                    cfg.ssm_head_dim, cfg.dtype)
    if cross:
        p["cross"] = L.init_attention(ks[1], cfg.d_model, cfg.n_heads,
                                      cfg.n_kv, cfg.hd, False, cfg.dtype)
        p["norm_cross"] = jnp.ones((cfg.d_model,), cfg.dtype)
    if mlp_kind in (DENSE, MOE_DENSE):
        p["mlp"] = L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.dtype)
    if mlp_kind in (MOE_MLP, MOE_DENSE):
        p["moe"] = MOE.init_moe(ks[3], cfg.d_model, cfg.d_ff, cfg.n_experts,
                                cfg.dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    keys = jax.random.split(key, 4 + len(cfg.pattern))
    params: Params = {
        "embed": L.embed_init(keys[0], cfg.padded_vocab, cfg.d_model,
                              cfg.dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    # decoder stack: per pattern position, stacked over repeats
    layer_tree: Params = {}
    for i, (mixer, mlp_kind) in enumerate(cfg.pattern):
        def one(k):
            return _init_layer(cfg, k, mixer, mlp_kind, cross=cfg.is_encdec)
        ks = jax.random.split(keys[1 + i], cfg.n_repeats)
        layer_tree[f"pos{i}"] = jax.vmap(one)(ks)
    params["layers"] = layer_tree

    if cfg.is_encdec:
        def enc_one(k):
            return _init_layer(cfg, k, ATTN, DENSE, cross=False)
        ks = jax.random.split(keys[-1], cfg.encoder_layers)
        params["encoder"] = {"pos0": jax.vmap(enc_one)(ks),
                             "norm": jnp.ones((cfg.d_model,), cfg.dtype)}
    return params


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _apply_layer(cfg: ModelConfig, p: Params, x: jax.Array, *,
                 mixer: str, mlp_kind: str, positions: jax.Array,
                 causal: bool, memory: Optional[jax.Array],
                 cache: Optional[Params], cache_index,
                 ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Pre-norm residual block.  Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Optional[Params] = dict(cache) if cache is not None else None

    if cache is not None and cfg.decode_replicate_acts:
        # decode activations are tiny (B, 1, D).  Sharding their FEATURE dim
        # over 'data' aligns x with the FSDP shard of every weight's
        # contraction dim, so projections lower to partial-matmul + psum of
        # activation-sized tensors instead of gathering weight shards
        # (GSPMD's cost model otherwise picks the 0.5 GiB/layer W-gather
        # over the 0.5 MB psum).  Batch stays replicated across 'data' here;
        # attention re-shards q against the batch-sharded KV cache.
        x = constrain(x, "none", "none", "fsdp")

    def _decode_fsdp(t: jax.Array) -> jax.Array:
        # the norm's cross-D mean breaks feature sharding; re-pin the norm
        # OUTPUT (the projection input) so x @ W contracts against the FSDP
        # weight shard in place instead of gathering W (decode only)
        if cache is not None and cfg.decode_replicate_acts:
            return constrain(t, "none", "none", "fsdp")
        return t

    h = _decode_fsdp(L.rms_norm(x, p["norm1"], cfg.norm_eps))
    if mixer == ATTN:
        attn_cache = cache.get("attn") if cache is not None else None
        out, nc = L.attention(
            p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
            positions=positions, causal=causal, rope_theta=cfg.rope_theta,
            qk_norm=cfg.qk_norm, norm_eps=cfg.norm_eps, cache=attn_cache,
            cache_index=cache_index, attn_chunk=cfg.attn_chunk,
            decode_kv_splits=cfg.decode_kv_splits, unroll=cfg.unroll_scan,
            causal_block_skip=cfg.causal_block_skip)
        if new_cache is not None:
            new_cache["attn"] = nc
    else:
        mamba_cache = cache.get("mamba") if cache is not None else None
        out, nc = SSM.mamba_block(
            p["mamba"], h, d_model=cfg.d_model, state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim, chunk=cfg.ssd_chunk, cache=mamba_cache,
            unroll=cfg.unroll_scan)
        if new_cache is not None:
            new_cache["mamba"] = nc
    x = constrain(x + out, "batch", "seq", "none")

    if memory is not None and "cross" in p:
        h = _decode_fsdp(L.rms_norm(x, p["norm_cross"], cfg.norm_eps))
        out, _ = L.attention(
            p["cross"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
            positions=positions, causal=False, rope_theta=cfg.rope_theta,
            qk_norm=False, norm_eps=cfg.norm_eps, memory=memory,
            attn_chunk=cfg.attn_chunk, unroll=cfg.unroll_scan)
        x = x + out

    if mlp_kind != NONE:
        h = _decode_fsdp(L.rms_norm(x, p["norm2"], cfg.norm_eps))
        out = jnp.zeros_like(x)
        if mlp_kind in (DENSE, MOE_DENSE):
            out = out + L.mlp(p["mlp"], h, tp=cfg.mlp_tp)
        if mlp_kind in (MOE_MLP, MOE_DENSE):
            if cache is not None and h.shape[1] == 1:
                # decode only: the dense-all-experts path is O(T*E) — right
                # for one token per sequence, catastrophic for a 32k prefill
                mo = MOE.moe_decode(p["moe"], h, n_experts=cfg.n_experts,
                                    top_k=cfg.top_k)
            else:
                from repro.parallel import sharding as shd
                mesh = shd.active_mesh()
                ep_ok = (mesh is not None and "model" in mesh.axis_names
                         and cfg.n_experts % mesh.shape["model"] == 0)
                if ep_ok:
                    bsz = 1
                    for a in mesh.axis_names:
                        if a != "model":
                            bsz *= mesh.shape[a]
                    ep_ok = h.shape[0] % bsz == 0
                if ep_ok and cfg.moe_a2a \
                        and h.shape[1] % mesh.shape["model"] == 0:
                    mo, aux = MOE.moe_ep_a2a(
                        p["moe"], h, n_experts=cfg.n_experts,
                        top_k=cfg.top_k,
                        capacity_factor=cfg.capacity_factor, mesh=mesh)
                elif ep_ok:
                    mo, aux = MOE.moe_ep(
                        p["moe"], h, n_experts=cfg.n_experts,
                        top_k=cfg.top_k,
                        capacity_factor=cfg.capacity_factor, mesh=mesh)
                else:
                    mo, aux = MOE.moe(p["moe"], h, n_experts=cfg.n_experts,
                                      top_k=cfg.top_k,
                                      capacity_factor=cfg.capacity_factor)
            out = out + mo
        x = constrain(x + out, "batch", "seq", "none")
    return x, new_cache, aux


def _run_stack(cfg: ModelConfig, stack: Params, x: jax.Array, *,
               pattern: Tuple[Tuple[str, str], ...], positions: jax.Array,
               causal: bool, memory: Optional[jax.Array] = None,
               cache: Optional[Params] = None, cache_index=None,
               ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Scan over stacked repeats; pattern positions applied sequentially
    inside the body.  cache (if given) is scanned alongside the params."""

    def body(carry, scanned):
        x, aux = carry
        layer_p, layer_c = scanned
        new_c: Dict[str, Any] = {}
        for i, (mixer, mlp_kind) in enumerate(pattern):
            c_i = layer_c.get(f"pos{i}") if layer_c is not None else None
            x, nc, a = _apply_layer(
                cfg, layer_p[f"pos{i}"],
                x, mixer=mixer, mlp_kind=mlp_kind, positions=positions,
                causal=causal, memory=memory, cache=c_i,
                cache_index=cache_index)
            if nc is not None:
                new_c[f"pos{i}"] = nc
            aux = aux + a
        return (x, aux), (new_c if new_c else None)

    if cfg.remat:
        body = jax.checkpoint(body)

    aux0 = jnp.zeros((), jnp.float32)
    if cfg.unroll_scan:
        # cost-accounting mode: true Python unroll (see ModelConfig)
        n_rep = jax.tree_util.tree_leaves(stack)[0].shape[0]
        carry = (x, aux0)
        caches = []
        for r in range(n_rep):
            sl = jax.tree_util.tree_map(lambda v: v[r], (stack, cache))
            carry, y = body(carry, sl)
            caches.append(y)
        (x, aux) = carry
        new_cache = (jax.tree_util.tree_map(
            lambda *vs: jnp.stack(vs), *caches) if caches[0] is not None
            else None)
        return x, new_cache, aux
    (x, aux), new_cache = jax.lax.scan(body, (x, aux0), (stack, cache))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# embedding / logits
# ---------------------------------------------------------------------------

def _embed(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    emb = params["embed"][tokens]                 # (B, S, D) gather
    return constrain(emb, "batch", "seq", "none")


def _chunked_xent(cfg: ModelConfig, x: jax.Array, embed: jax.Array,
                  targets: jax.Array, mask: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy without materializing full (B, S, V) logits: scan over
    sequence chunks; each chunk's logits live only inside its scan step."""
    B, S, D = x.shape
    ck = min(cfg.logit_chunk, S)
    pad = (-S) % ck
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = (S + pad) // ck
    xs = x.reshape(B, n, ck, D).transpose(1, 0, 2, 3)
    ts = targets.reshape(B, n, ck).transpose(1, 0, 2)
    ms = mask.reshape(B, n, ck).transpose(1, 0, 2)
    w_t = embed.astype(cfg.dtype)

    def body(carry, inp):
        loss_sum, correct = carry
        xc, tc, mc = inp
        logits = jnp.einsum("bsd,vd->bsv", xc, w_t).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        loss_sum = loss_sum + jnp.sum((lse - tgt) * mc)
        correct = correct + jnp.sum(
            (jnp.argmax(logits, -1) == tc) * mc)
        return (loss_sum, correct), None

    body = jax.checkpoint(body)
    (loss_sum, correct), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ts, ms), unroll=bool(cfg.unroll_scan))
    denom = jnp.maximum(mask.sum(), 1.0)
    return loss_sum / denom, correct / denom


def _logits(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    return jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(cfg.dtype)
                      ).astype(jnp.float32)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def _frontend_concat(cfg: ModelConfig, params: Params, batch: Dict[str, Any]
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (x (B,S,D), targets (B,S), loss_mask (B,S)) for the decoder."""
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens)
    targets = batch.get("targets", tokens)
    mask = batch.get("loss_mask", jnp.ones(tokens.shape, jnp.float32))
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(cfg.dtype)     # (B, Np, D)
        x = jnp.concatenate([pe, x], axis=1)
        npatch = pe.shape[1]
        targets = jnp.concatenate(
            [jnp.zeros((x.shape[0], npatch), targets.dtype), targets], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((x.shape[0], npatch), mask.dtype), mask], axis=1)
    return x, targets, mask


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """Encoder stack over stub frame embeddings (B, L_enc, D)."""
    x = frames.astype(cfg.dtype)
    positions = jnp.arange(x.shape[1])
    x, _, _ = _run_stack(cfg, {"pos0": params["encoder"]["pos0"]}, x,
                         pattern=((ATTN, DENSE),), positions=positions,
                         causal=False)
    # encoder params are stored under pos0 stacked; norm applied after
    return L.rms_norm(x, params["encoder"]["norm"], cfg.norm_eps)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, Any]
            ) -> Tuple[jax.Array, jax.Array]:
    """Training/prefill forward.  Returns (final hidden (B,S,D), aux_loss)."""
    x, _, _ = _frontend_concat(cfg, params, batch)
    positions = jnp.arange(x.shape[1])
    memory = None
    if cfg.is_encdec:
        memory = encode(cfg, params, batch["encoder_embeds"])
    x, _, aux = _run_stack(cfg, params["layers"], x, pattern=cfg.pattern,
                           positions=positions, causal=True, memory=memory)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, Any],
            aux_weight: float = 0.01
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x, aux = forward(params, cfg, batch)
    _, targets, mask = _frontend_concat_shapes(cfg, batch)
    # next-token shift: predict t+1 from t
    x_in = x[:, :-1]
    tgt = targets[:, 1:]
    msk = mask[:, 1:]
    xent, acc = _chunked_xent(cfg, x_in, params["embed"], tgt, msk)
    loss = xent + aux_weight * aux
    return loss, {"loss": loss, "xent": xent, "aux": aux, "acc": acc}


def _frontend_concat_shapes(cfg: ModelConfig, batch: Dict[str, Any]):
    """targets/mask aligned with the (possibly frontend-extended) sequence,
    without re-running the embedding."""
    tokens = batch["tokens"]
    targets = batch.get("targets", tokens)
    mask = batch.get("loss_mask", jnp.ones(tokens.shape, jnp.float32))
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        npatch = batch["patch_embeds"].shape[1]
        B = tokens.shape[0]
        targets = jnp.concatenate(
            [jnp.zeros((B, npatch), targets.dtype), targets], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((B, npatch), mask.dtype), mask], axis=1)
    return None, targets, mask


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """Decode cache pytree, stacked over repeats like the params."""
    cache: Params = {}
    for i, (mixer, _) in enumerate(cfg.pattern):
        if mixer == ATTN:
            kv = jnp.zeros((cfg.n_repeats, batch, max_len, cfg.n_kv, cfg.hd),
                           cfg.dtype)
            cache[f"pos{i}"] = {"attn": {"k": kv, "v": kv}}
        else:
            one = SSM.init_mamba_cache(batch, cfg.d_model, cfg.ssm_state,
                                       cfg.ssm_head_dim, cfg.dtype)
            cache[f"pos{i}"] = {"mamba": jax.tree_util.tree_map(
                lambda v: jnp.broadcast_to(
                    v[None], (cfg.n_repeats,) + v.shape), one)}
    return cache


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                cache: Params, index: jax.Array,
                memory: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Params]:
    """One decode step.  tokens (B, 1) int32; index = current length —
    scalar, or (B,) for per-slot positions (continuous batching).
    Returns (logits (B, V), new cache)."""
    x = _embed(cfg, params, tokens)
    positions = (jnp.asarray(index).reshape(-1, 1)
                 + jnp.arange(tokens.shape[1])[None, :])
    if cfg.is_encdec and memory is None:
        raise ValueError("enc-dec decode requires encoder memory")
    x, new_cache, _ = _run_stack(
        cfg, params["layers"], x, pattern=cfg.pattern, positions=positions,
        causal=True, memory=memory, cache=cache, cache_index=index)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.decode_replicate_acts:
        # keep the D contraction aligned with the embed table's fsdp shard
        x = constrain(x, "none", "none", "fsdp")
    logits = _logits(cfg, params, x)[:, -1]
    return logits, new_cache


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, Any],
            cache: Params) -> Tuple[jax.Array, Params]:
    """Prefill: run the full prompt through the stack, filling the cache.
    Returns (last-position logits (B, V), cache)."""
    x, _, _ = _frontend_concat(cfg, params, batch)
    positions = jnp.arange(x.shape[1])
    memory = None
    if cfg.is_encdec:
        memory = encode(cfg, params, batch["encoder_embeds"])
    x, new_cache, _ = _run_stack(
        cfg, params["layers"], x, pattern=cfg.pattern, positions=positions,
        causal=True, memory=memory, cache=cache,
        cache_index=jnp.zeros((), jnp.int32))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, params, x[:, -1:])[:, -1]
    return logits, new_cache
