"""Shared model building blocks, pure JAX.

Every block routes its large GEMMs through ``repro.kernels.dispatch`` so that
on a real TPU the input-aware tuner (the paper's contribution) supplies the
kernel configuration, while under SPMD jit / the CPU dry-run the same call
lowers to plain XLA ops whose cost analysis reflects the true dataflow.

Conventions:
  * params are nested dicts of jax.Arrays (pytrees);
  * activations default to bfloat16, accumulation/normalization in fp32;
  * shapes follow (batch, seq, ...) with heads split as (..., n_heads, head_dim).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dispatch

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype,
               fan_in: Optional[int] = None) -> jax.Array:
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norm / rope
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (B, S, H, D); positions (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (train/prefill path: chunked flash-style in pure jnp so that
# the 32k-seq dry-run never materializes an (S, S) score tensor)
# ---------------------------------------------------------------------------

def init_attention(key: jax.Array, d_model: int, n_heads: int, n_kv: int,
                   head_dim: int, qk_norm: bool, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, n_heads * head_dim), dtype),
        "wk": dense_init(ks[1], (d_model, n_kv * head_dim), dtype),
        "wv": dense_init(ks[2], (d_model, n_kv * head_dim), dtype),
        "wo": dense_init(ks[3], (n_heads * head_dim, d_model), dtype,
                         fan_in=n_heads * head_dim),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), dtype)
        p["k_norm"] = jnp.ones((head_dim,), dtype)
    return p


def _chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool, q_start: jax.Array | int,
                       kv_len: Optional[jax.Array] = None,
                       chunk: int = 1024, unroll: bool = False) -> jax.Array:
    """Flash-style attention in pure jnp: scan over KV chunks with running
    (max, sum) so peak memory is O(S * chunk), not O(S^2).

    q (B, Sq, H, D); k/v (B, Skv, G, D) with G = kv heads; H % G == 0.
    GQA K/V are expanded to the full H heads *inside* each chunk step (an
    O(chunk)-sized gather) so every live tensor carries a flat H dimension —
    the layout head-TP sharding propagates through cleanly.
    q_start: absolute position of q[0] (for causal masking during decode).
    kv_len: number of valid kv positions (B,) or scalar; None = all valid.
    """
    B, Sq, H, D = q.shape
    Skv, G = k.shape[1], k.shape[2]
    rep = H // G
    scale = 1.0 / math.sqrt(D)
    chunk = min(chunk, Skv)
    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # (n_chunks, B, chunk, G, D)
    kc = k.reshape(B, n_chunks, chunk, G, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, G, D).transpose(1, 0, 2, 3, 4)

    qf = q.astype(jnp.float32) * scale                   # (B, Sq, H, D)
    # q_start may be scalar or per-batch (B,) — normalize to (B or 1, Sq)
    q_pos = jnp.asarray(q_start).reshape(-1, 1) + jnp.arange(Sq)[None, :]
    valid_len = Skv if kv_len is None else kv_len

    def body(carry, inp):
        m, l, acc = carry                     # running max / sum / out
        kb, vb, c_idx = inp                   # (B, chunk, G, D)
        kb = jnp.repeat(kb, rep, axis=2).astype(jnp.float32)  # (B,ck,H,D)
        vb = jnp.repeat(vb, rep, axis=2).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb)        # (B,H,Sq,chunk)
        kv_pos = c_idx * chunk + jnp.arange(chunk)       # (chunk,)
        mask = kv_pos[None, None, :] < jnp.asarray(valid_len).reshape(-1, 1, 1)
        if causal:
            mask = mask & (kv_pos[None, None, :] <= q_pos[:, :, None])
        s = jnp.where(mask[:, None, :, :], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vb)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    # checkpoint the chunk body: without it, scan's backward stacks every
    # chunk's score matrix — silently re-materializing the full O(S^2) buffer
    # the chunking exists to avoid.
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)),
        unroll=bool(unroll))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 2, 1, 3)                      # (B, Sq, H, D)
    return out.astype(q.dtype)


def _block_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            chunk: int = 1024,
                            unroll: bool = False) -> jax.Array:
    """Causal self-attention that SKIPS upper-triangular blocks (hillclimb
    H-series; see EXPERIMENTS.md §Perf).

    The plain chunked scan computes every (q, kv-chunk) pair and masks the
    future — half the score FLOPs are discarded.  Here both axes are chunked
    and a single scan walks only the nq*(nq+1)/2 lower-triangular block
    pairs (a static list), updating that q-chunk's running (max, sum, acc)
    in place.  Same math, ~2x fewer attention FLOPs at full sequence length.

    Requires Sq == Skv, q_start == 0, full validity (the training/prefill
    self-attention case); callers fall back to _chunked_attention otherwise.
    """
    B, Sq, H, D = q.shape
    G = k.shape[2]
    rep = H // G
    scale = 1.0 / math.sqrt(D)
    ck = min(chunk, Sq)
    assert Sq % ck == 0, (Sq, ck)
    nq = Sq // ck
    qc = (q.astype(jnp.float32) * scale).reshape(B, nq, ck, H, D
                                                 ).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, nq, ck, G, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nq, ck, G, D).transpose(1, 0, 2, 3, 4)

    pairs = [(qi, ki) for qi in range(nq) for ki in range(qi + 1)]
    qis = jnp.asarray([p[0] for p in pairs])
    kis = jnp.asarray([p[1] for p in pairs])

    def body(carry, inp):
        m, l, acc = carry          # (nq, B, H, ck) / ... / (nq, B, H, ck, D)
        qi, ki = inp
        qb = jax.lax.dynamic_index_in_dim(qc, qi, 0, keepdims=False)
        kb = jnp.repeat(jax.lax.dynamic_index_in_dim(kc, ki, 0, False),
                        rep, axis=2).astype(jnp.float32)
        vb = jnp.repeat(jax.lax.dynamic_index_in_dim(vc, ki, 0, False),
                        rep, axis=2).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb)
        # mask only the diagonal block (qi == ki); earlier blocks are fully
        # visible, later ones never computed
        tri = jnp.tril(jnp.ones((ck, ck), bool))
        s = jnp.where((qi != ki) | tri[None, None], s, -1e30)
        mi = jax.lax.dynamic_index_in_dim(m, qi, 0, False)
        li = jax.lax.dynamic_index_in_dim(l, qi, 0, False)
        ai = jax.lax.dynamic_index_in_dim(acc, qi, 0, False)
        m_new = jnp.maximum(mi, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mi - m_new)
        li = li * corr + p.sum(axis=-1)
        ai = ai * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vb)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, li, qi, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, ai, qi, 0)
        return (m, l, acc), None

    m0 = jnp.full((nq, B, H, ck), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((nq, B, H, ck), jnp.float32)
    a0 = jnp.zeros((nq, B, H, ck, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                  (qis, kis), unroll=bool(unroll))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    # (nq, B, H, ck, D) -> (B, Sq, H, D)
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


def attention(p: Params, x: jax.Array, *, n_heads: int, n_kv: int,
              head_dim: int, positions: jax.Array, causal: bool,
              rope_theta: float, qk_norm: bool, norm_eps: float,
              cache: Optional[Params] = None,
              cache_index: Optional[jax.Array] = None,
              memory: Optional[jax.Array] = None,
              attn_chunk: int = 1024,
              decode_kv_splits: int = 1,
              unroll: bool = False,
              causal_block_skip: bool = False,
              ) -> Tuple[jax.Array, Optional[Params]]:
    """GQA attention block body (no residual / pre-norm — caller owns those).

    cache: {'k': (B, L_max, G, D), 'v': ...} decode KV cache; cache_index is
    the number of tokens already in it.  memory: encoder output for
    cross-attention (whisper decoder) — keys/values come from memory instead
    of x, no cache, no causal mask.
    """
    from repro.parallel import sharding as shd
    B, S, _ = x.shape
    q = dispatch.matmul2(x, p["wq"]).reshape(B, S, n_heads, head_dim)
    kv_src = memory if memory is not None else x
    Skv_in = kv_src.shape[1]
    k = dispatch.matmul2(kv_src, p["wk"]).reshape(B, Skv_in, n_kv, head_dim)
    v = dispatch.matmul2(kv_src, p["wv"]).reshape(B, Skv_in, n_kv, head_dim)

    # Attention TP placement.  Preferred: Megatron head-TP — q sharded over
    # heads, K/V gathered over 'model' (small, no quadratic term), the score
    # and PV work split by head, and wo contracting a head-sharded input so
    # the projection weights stay TP-resident.  Fallback when H % tp != 0
    # (smollm 9H, qwen3 40H, arctic 56H, whisper 8H): sequence-parallel
    # queries — the quadratic work splits by query position instead, at the
    # cost of gathering attention projection weights.
    # Applies to training AND prefill (S > 1, cache being filled): without
    # it GSPMD replicates the 32k x 32k score work across the model axis for
    # non-head-divisible archs (16x redundancy — EXPERIMENTS.md §Perf H4).
    tp = shd.axis_size("model")
    if tp > 1 and S > 1:
        if n_heads % tp == 0:
            q = shd.constrain(q, "batch", "none", "model", "none")
        elif S % tp == 0:
            q = shd.constrain(q, "batch", "seq", "none", "none")
        k = shd.constrain(k, "batch", "none", "none", "none")
        v = shd.constrain(v, "batch", "none", "none", "none")

    if qk_norm:
        q = rms_norm(q, p["q_norm"], norm_eps)
        k = rms_norm(k, p["k_norm"], norm_eps)

    if memory is None:
        q = apply_rope(q, positions, rope_theta)
        kv_positions = positions
        k = apply_rope(k, kv_positions, rope_theta)

    new_cache = None
    kv_len = None
    if cache is not None:
        idx = jnp.asarray(cache_index)
        if idx.ndim == 0:
            k_full = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
            v_full = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
        else:
            # per-sequence cache positions (continuous batching slots)
            rows = jnp.arange(B)[:, None]
            cols = idx[:, None] + jnp.arange(S)[None, :]
            k_full = cache["k"].at[rows, cols].set(
                k.astype(cache["k"].dtype), mode="drop")
            v_full = cache["v"].at[rows, cols].set(
                v.astype(cache["v"].dtype), mode="drop")
        new_cache = {"k": k_full, "v": v_full}
        k, v = k_full, v_full
        kv_len = idx + S
        q_start = idx
    else:
        q_start = 0

    n_splits = 0
    if cache is not None and S == 1 and decode_kv_splits > 1:
        # long-context decode: sequence-parallel flash-decoding (SP).
        # The split count routes through the tuned attention space (this
        # runs at trace time, so the resolver's telemetry record is
        # captured by the engine like any kernel call); the caller's
        # decode_kv_splits is the heuristic fallback when nothing tuned
        # resolves, so untuned processes behave exactly as before.
        from repro.serve.flash_decode import (flash_decode_attention,
                                              resolve_decode_splits)
        n_splits = resolve_decode_splits(
            B=B, Hq=n_heads, Hkv=n_kv, Lkv=k.shape[1], D=head_dim,
            dtype_bits=dispatch._dtype_bits(q.dtype), causal=int(causal),
            default=decode_kv_splits)
        if n_splits <= 1 or k.shape[1] % n_splits != 0:
            n_splits = 0                 # untiled split: dense decode path
    if n_splits > 1:
        out = flash_decode_attention(q, k, v, kv_len, n_splits=n_splits)
    elif causal_block_skip and causal and memory is None and cache is None \
            and q.shape[1] == k.shape[1] \
            and q.shape[1] % min(attn_chunk, q.shape[1]) == 0:
        out = _block_causal_attention(q, k, v, chunk=attn_chunk,
                                      unroll=unroll)
    else:
        out = _chunked_attention(q, k, v, causal=causal and memory is None,
                                 q_start=q_start, kv_len=kv_len,
                                 chunk=attn_chunk, unroll=unroll)
    out = out.reshape(B, S, n_heads * head_dim)
    if cache is not None and S == 1:
        # decode: wo's contraction dim (H*hd) is 'model'-sharded — pin the
        # attention output to match so wo is consumed in place (psum) rather
        # than gathered, and pin wo's OUTPUT D-sharded over 'data' likewise
        # (see ModelConfig.decode_replicate_acts)
        out = shd.constrain(out, "none", "none", "model")
        proj = dispatch.matmul2(out, p["wo"])
        return shd.constrain(proj, "none", "none", "fsdp"), new_cache
    return dispatch.matmul2(out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# dense SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key: jax.Array, d_model: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), dtype, fan_in=d_ff),
    }


def mlp(p: Params, x: jax.Array, tp: bool = True) -> jax.Array:
    from repro.parallel import sharding as shd
    g = dispatch.matmul2(x, p["w_gate"])
    u = dispatch.matmul2(x, p["w_up"])
    if not tp:
        # pure-SP: tokens stay sequence-sharded, weights are consumed
        # replicated (dp_only rules) — zero activation reshards
        g = shd.constrain(g, "batch", "seq", "none")
        u = shd.constrain(u, "batch", "seq", "none")
        return dispatch.matmul2(jax.nn.silu(g) * u, p["w_down"])
    # Megatron TP: pin the hidden activations to the 'model' axis so the
    # ffn weights are consumed in their TP-sharded layout (all-gather x over
    # S, psum after w_down) instead of GSPMD electing to gather weights.
    # Decode (S == 1): keep batch unconstrained — feature-sharded decode
    # activations contract against the FSDP weight shards with tiny psums,
    # and forcing batch sharding here would reintroduce weight gathers.
    if x.shape[1] == 1:
        g = shd.constrain(g, "none", "none", "model")
        u = shd.constrain(u, "none", "none", "model")
        out = dispatch.matmul2(jax.nn.silu(g) * u, p["w_down"])
        # pin the output D-sharded over 'data' as well — otherwise GSPMD
        # prefers replicating the output and gathering w_down's D shards
        return shd.constrain(out, "none", "none", "fsdp")
    g = shd.constrain(g, "batch", "none", "model")
    u = shd.constrain(u, "batch", "none", "model")
    return dispatch.matmul2(jax.nn.silu(g) * u, p["w_down"])
