"""Mamba-2 (SSD — state-space duality) mixer, pure JAX.

Train/prefill path uses the *chunked* SSD formulation (arXiv:2405.21060): the
sequence is split into chunks of Q steps; within a chunk the recurrence is a
masked attention-like matmul (quadratic in Q, MXU-friendly), across chunks a
short lax.scan carries the (H, P, S) state.  This is the same math the Pallas
``kernels/ssd.py`` kernel implements — on TPU the dispatcher routes to it with
the tuner-chosen chunk size; here the pure-jnp version keeps the dry-run HLO
matmul-dominated (the point of SSD).

Decode path is the O(1)-per-step recurrence on a carried state.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Params, dense_init

CONV_WIDTH = 4


def init_mamba(key: jax.Array, d_model: int, state: int, head_dim: int,
               dtype) -> Params:
    d_inner = 2 * d_model
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 6)
    conv_ch = d_inner + 2 * state
    return {
        "w_in": dense_init(ks[0], (d_model, 2 * d_inner + 2 * state + n_heads),
                           dtype),
        "conv_w": (jax.random.normal(ks[1], (CONV_WIDTH, conv_ch), jnp.float32)
                   / math.sqrt(CONV_WIDTH)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm": jnp.ones((d_inner,), dtype),
        "w_out": dense_init(ks[2], (d_inner, d_model), dtype, fan_in=d_inner),
    }


def _split_proj(proj: jax.Array, d_inner: int, state: int, n_heads: int):
    z, xbc, dt = jnp.split(
        proj, [d_inner, 2 * d_inner + 2 * state], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d, width CONV_WIDTH.  xbc (B, L, C).
    state (B, CONV_WIDTH-1, C) carries the last inputs for decode.
    Returns (out (B, L, C), new_state)."""
    B, L, C = xbc.shape
    if state is None:
        state = jnp.zeros((B, CONV_WIDTH - 1, C), xbc.dtype)
    full = jnp.concatenate([state, xbc], axis=1)          # (B, L+W-1, C)
    out = jnp.zeros((B, L, C), jnp.float32)
    for i in range(CONV_WIDTH):
        out = out + (jax.lax.dynamic_slice_in_dim(full, i, L, axis=1)
                     .astype(jnp.float32) * w[i].astype(jnp.float32))
    out = jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)
    new_state = jax.lax.dynamic_slice_in_dim(full, L, CONV_WIDTH - 1, axis=1)
    return out, new_state


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
                cm: jax.Array, *, chunk: int = 256,
                return_final_state: bool = False, unroll: bool = False):
    """Chunked SSD.  x (B,L,H,P), dt (B,L,H) (post-softplus), a (H,) (<0),
    bm/cm (B,L,S).  Returns y (B,L,H,P) (and the final (B,H,P,S) state when
    ``return_final_state`` — used by prefill).  Matches ref.py::ssd_ref."""
    B, L, H, P = x.shape
    S = bm.shape[-1]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad
    nc = Lp // Q

    xf = x.reshape(B, nc, Q, H, P).astype(jnp.float32)
    dtf = dt.reshape(B, nc, Q, H).astype(jnp.float32)
    bf = bm.reshape(B, nc, Q, S).astype(jnp.float32)
    cf = cm.reshape(B, nc, Q, S).astype(jnp.float32)
    af = a.astype(jnp.float32)

    logl = af[None, None, None, :] * dtf                  # (B,nc,Q,H) <= 0
    cum = jnp.cumsum(logl, axis=2)                        # inclusive
    # intra-chunk: y[t] += sum_{s<=t} C_t.B_s exp(cum_t - cum_s) dt_s x_s
    # mask the EXPONENT, not the exp: s > t gives cum_t - cum_s > 0 which
    # overflows to inf for strong decay, and where(mask, inf, 0) then
    # poisons the backward pass with inf * 0 = NaN.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,t,s,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -jnp.inf))
    cb = jnp.einsum("bnts,bnqs->bntq", cf, bf)            # (B,nc,t,s)
    scores = cb[..., None] * decay * dtf[:, :, None, :, :]  # (B,nc,t,s,H)
    y_intra = jnp.einsum("bntsh,bnshp->bnthp", scores, xf)

    # chunk states: S_c = sum_s exp(cum_last - cum_s) dt_s x_s (x) B_s
    seg = jnp.exp(cum[:, :, -1:, :] - cum)                # (B,nc,Q,H)
    contrib = jnp.einsum("bnqh,bnqhp,bnqs->bnhps",
                         seg * dtf, xf, bf)               # (B,nc,H,P,S)
    total = jnp.exp(cum[:, :, -1, :])                     # (B,nc,H)

    def step(state, inp):
        s_c, tot = inp                                    # (B,H,P,S), (B,H)
        out_state = state                                 # state BEFORE chunk
        new = state * tot[:, :, None, None] + s_c
        return new, out_state

    final_state, prev_states = jax.lax.scan(
        step, jnp.zeros((B, H, P, S), jnp.float32),
        (contrib.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
        unroll=bool(unroll))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (B,nc,H,P,S)

    # inter-chunk: y[t] += C_t . (exp(cum_t) * state_prev)
    y_inter = jnp.einsum("bnqs,bnqh,bnhps->bnqhp",
                         cf, jnp.exp(cum), prev_states)
    y = (y_intra + y_inter).reshape(B, Lp, H, P)[:, :L]
    if return_final_state:
        return y.astype(x.dtype), final_state
    return y.astype(x.dtype)


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    a: jax.Array, bm: jax.Array, cm: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrence.  state (B,H,P,S); x (B,H,P); dt (B,H);
    bm/cm (B,S).  Returns (new_state, y (B,H,P))."""
    decay = jnp.exp(a[None, :] * dt)                      # (B,H)
    contrib = jnp.einsum("bh,bhp,bs->bhps", dt, x, bm)
    new_state = state * decay[:, :, None, None] + contrib
    y = jnp.einsum("bhps,bs->bhp", new_state, cm)
    return new_state, y


def mamba_block(p: Params, x: jax.Array, *, d_model: int, state: int,
                head_dim: int, chunk: int = 256,
                cache: Optional[Dict[str, jax.Array]] = None,
                unroll: bool = False
                ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full Mamba-2 mixer.  x (B, L, D).  cache = {'conv': (B,W-1,C),
    'ssm': (B,H,P,S)} for decode (L==1); None for train/prefill."""
    from repro.kernels import dispatch
    from repro.parallel import sharding as shd
    B, L, D = x.shape
    d_inner = 2 * d_model
    H = d_inner // head_dim
    proj = dispatch.matmul2(x, p["w_in"])
    # TP: the fused projection is 'model'-sharded (w_in rule); pin it so the
    # SSD work below splits by head instead of replicating.
    proj = shd.constrain(proj, "batch", "none", "model")
    z, xbc, dt_raw = _split_proj(proj, d_inner, state, H)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["a_log"])

    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + state], axis=-1)
    xh = xs.reshape(B, L, H, head_dim)
    xh = shd.constrain(xh, "batch", "none", "model", "none")

    new_cache = None
    if cache is not None and L == 1:        # decode step
        new_ssm, y = ssd_decode_step(
            cache["ssm"], xh[:, 0].astype(jnp.float32), dt[:, 0], a,
            bmat[:, 0].astype(jnp.float32), cmat[:, 0].astype(jnp.float32))
        y = y[:, None]                                      # (B,1,H,P)
        new_cache = {"conv": new_conv, "ssm": new_ssm}
    elif cache is not None:                 # prefill: fill state from scratch
        y, final = ssd_chunked(xh, dt, a, bmat, cmat, chunk=chunk,
                               return_final_state=True, unroll=unroll)
        new_cache = {"conv": new_conv, "ssm": final}
    else:
        y = ssd_chunked(xh, dt, a, bmat, cmat, chunk=chunk, unroll=unroll)

    y = y + xh.astype(y.dtype) * p["d_skip"][None, None, :, None]
    y = y.reshape(B, L, d_inner)
    # gated RMSNorm (mamba2 style): norm(y * silu(z))
    from .layers import rms_norm
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)
                                                 ).astype(x.dtype), p["norm"])
    out = dispatch.matmul2(y, p["w_out"])
    return out, new_cache


def init_mamba_cache(batch: int, d_model: int, state: int, head_dim: int,
                     dtype) -> Dict[str, jax.Array]:
    d_inner = 2 * d_model
    H = d_inner // head_dim
    return {
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, d_inner + 2 * state), dtype),
        "ssm": jnp.zeros((batch, H, head_dim, state), jnp.float32),
    }
