"""smollm-135m [dense] — 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
llama-arch small; also the end-to-end training example model.
[hf:HuggingFaceTB/SmolLM-135M; hf]"""

import jax.numpy as jnp
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    n_layers=30, d_model=576, n_heads=9, n_kv=3, d_ff=1536,
    vocab=49152, head_dim=64,
    dtype=jnp.bfloat16,
    decode_kv_splits=16,
)

SMOKE = ModelConfig(
    name="smollm-135m-smoke",
    n_layers=2, d_model=72, n_heads=3, n_kv=1, d_ff=192,
    vocab=512, head_dim=24,
    dtype=jnp.float32, attn_chunk=64, logit_chunk=64,
)
