from .registry import ARCH_NAMES, get_config, smoke_config
from .shapes import SHAPES, applicable, input_specs, shape_kind

__all__ = ["ARCH_NAMES", "get_config", "smoke_config", "SHAPES",
           "applicable", "input_specs", "shape_kind"]
