"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936, qk_norm.  [hf:Qwen/Qwen3-8B; hf]"""

import jax.numpy as jnp
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    n_layers=40, d_model=5120, n_heads=40, n_kv=8, d_ff=17408,
    vocab=151936, head_dim=128,
    qk_norm=True,
    dtype=jnp.bfloat16,
    decode_kv_splits=16,
)

SMOKE = ModelConfig(
    name="qwen3-14b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
    vocab=512, head_dim=16, qk_norm=True,
    dtype=jnp.float32, attn_chunk=64, logit_chunk=64,
)
