"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2.  Mamba:attention 1:7 interleave (one
attention layer per 8-layer period), MoE every other layer.
Adaptation note (DESIGN.md): Jamba v0.1 uses a Mamba-1 mixer (d_state=16);
we use our SSD (Mamba-2) mixer with state=128 so the hybrid shares the
tuned SSD kernel — same 1:7 structure, same attention/MoE placement.
[arXiv:2403.19887; hf]"""

import jax.numpy as jnp
from repro.models import ModelConfig

# 8-layer period, repeated 4x: attention at position 3 (1:7), MoE every
# other layer (positions 0, 2, 4, 6).
PERIOD = (
    ("mamba", "moe"), ("mamba", "dense"),
    ("mamba", "moe"), ("attn", "dense"),
    ("mamba", "moe"), ("mamba", "dense"),
    ("mamba", "moe"), ("mamba", "dense"),
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
    vocab=65536, head_dim=128,
    pattern=PERIOD,
    n_experts=16, top_k=2,
    ssm_state=128, ssm_head_dim=64,
    dtype=jnp.bfloat16,
    decode_kv_splits=16,
)

SMOKE = ModelConfig(
    name="jamba-v0.1-52b-smoke",
    n_layers=8, d_model=64, n_heads=4, n_kv=2, d_ff=128,
    vocab=512, head_dim=16,
    pattern=PERIOD,
    n_experts=4, top_k=2,
    ssm_state=16, ssm_head_dim=16,
    dtype=jnp.float32, ssd_chunk=32, attn_chunk=64, logit_chunk=64,
)
