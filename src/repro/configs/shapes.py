"""Assigned input shapes x applicability rules + ShapeDtypeStruct factories.

Four shapes per architecture (40 cells):
  train_4k     seq_len=4096   global_batch=256   lowers train_step
  prefill_32k  seq_len=32768  global_batch=32    lowers prefill (serve)
  decode_32k   seq_len=32768  global_batch=128   lowers decode_step (serve)
  long_500k    seq_len=524288 global_batch=1     lowers decode_step (serve)

Rules (per spec): ``long_500k`` needs sub-quadratic attention — run only for
SSM/hybrid (mamba2-1.3b, jamba-v0.1-52b), skip for pure full-attention archs.
No assigned arch is encoder-only, so decode shapes run everywhere (whisper
decodes with its decoder over stub encoder memory).

``input_specs`` returns weak-type-correct jax.ShapeDtypeStruct stand-ins with
NamedShardings attached when a mesh is given — no device allocation, the
pattern the multi-pod dry-run lowers against.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.models import ModelConfig, MAMBA
from repro.parallel.sharding import logical_to_spec


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str                   # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


def _is_subquadratic(cfg: ModelConfig) -> bool:
    return any(mixer == MAMBA for mixer, _ in cfg.pattern)


def applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped)."""
    if shape_name == "long_500k" and not _is_subquadratic(cfg):
        return False, ("pure full-attention arch: 500k context needs "
                       "sub-quadratic attention (spec rule; DESIGN.md §5)")
    return True, ""


def shape_kind(shape_name: str) -> str:
    return SHAPES[shape_name].kind


def _sds(shape, dtype, mesh: Optional[Mesh], axes: Tuple[str, ...]):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    spec = logical_to_spec(axes, shape, mesh)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ModelConfig, shape_name: str,
                mesh: Optional[Mesh] = None) -> Dict[str, Any]:
    """Model-input ShapeDtypeStructs for the training/prefill batch."""
    sh = SHAPES[shape_name]
    B, S = sh.global_batch, sh.seq_len
    out: Dict[str, Any] = {}
    tok_len = S
    if cfg.frontend == "vision":
        tok_len = S - cfg.n_frontend_tokens
        out["patch_embeds"] = _sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                   cfg.dtype, mesh, ("batch", "none", "none"))
    out["tokens"] = _sds((B, tok_len), jnp.int32, mesh, ("batch", "none"))
    if cfg.is_encdec:
        out["encoder_embeds"] = _sds((B, cfg.encoder_len, cfg.d_model),
                                     cfg.dtype, mesh, ("batch", "none", "none"))
    return out


def decode_specs(cfg: ModelConfig, shape_name: str,
                 mesh: Optional[Mesh] = None) -> Dict[str, Any]:
    """Inputs for one decode step: new tokens + cache + index (+ memory)."""
    sh = SHAPES[shape_name]
    B, L = sh.global_batch, sh.seq_len
    out: Dict[str, Any] = {
        "tokens": _sds((B, 1), jnp.int32, mesh, ("batch", "none")),
        "index": _sds((), jnp.int32, mesh, ()),
        "cache": cache_specs(cfg, B, L, mesh),
    }
    if cfg.is_encdec:
        out["memory"] = _sds((B, cfg.encoder_len, cfg.d_model), cfg.dtype,
                             mesh, ("batch", "none", "none"))
    return out


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                mesh: Optional[Mesh] = None) -> Any:
    """ShapeDtypeStruct tree mirroring models.init_cache, with decode-time
    shardings: KV length over 'seq' ('model' axis — flash-decoding SP),
    mamba state heads over 'model'."""
    cache: Dict[str, Any] = {}
    R = cfg.n_repeats
    for i, (mixer, _) in enumerate(cfg.pattern):
        if mixer == "attn":
            kv = _sds((R, batch, max_len, cfg.n_kv, cfg.hd), cfg.dtype, mesh,
                      ("none", "batch", "seq", "none", "none"))
            cache[f"pos{i}"] = {"attn": {"k": kv, "v": kv}}
        else:
            d_inner = 2 * cfg.d_model
            H = d_inner // cfg.ssm_head_dim
            cache[f"pos{i}"] = {"mamba": {
                "conv": _sds((R, batch, 3, d_inner + 2 * cfg.ssm_state),
                             cfg.dtype, mesh,
                             ("none", "batch", "none", "model")),
                "ssm": _sds((R, batch, H, cfg.ssm_head_dim, cfg.ssm_state),
                            jnp.float32, mesh,
                            ("none", "batch", "model", "none", "none")),
            }}
    return cache


def input_specs(cfg: ModelConfig, shape_name: str,
                mesh: Optional[Mesh] = None) -> Dict[str, Any]:
    """Unified entry: ShapeDtypeStruct stand-ins for every model input of
    this (arch x shape) cell — training batch for 'train'/'prefill' kinds,
    token/cache/index set for 'decode' kinds."""
    kind = shape_kind(shape_name)
    if kind in ("train", "prefill"):
        out = dict(batch_specs(cfg, shape_name, mesh))
        if kind == "prefill":
            sh = SHAPES[shape_name]
            out["cache"] = cache_specs(cfg, sh.global_batch, sh.seq_len, mesh)
        return out
    return decode_specs(cfg, shape_name, mesh)


def make_batch(cfg: ModelConfig, shape_name: str, *, scale: float = 1.0,
               seed: int = 0) -> Dict[str, Any]:
    """Concrete (small-seed) batch matching batch_specs — used by smoke tests
    with reduced shapes, NOT by the dry-run."""
    specs = batch_specs(cfg, shape_name, mesh=None)
    rng = np.random.default_rng(seed)
    out = {}
    for k, s in specs.items():
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[k] = jnp.asarray(
                rng.integers(0, cfg.vocab, s.shape), s.dtype)
        else:
            out[k] = jnp.asarray(rng.normal(0, scale, s.shape), s.dtype)
    return out
