"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256.  GQA, 128k vocab; bf16 optimizer states required to fit the
256-chip pod (DESIGN.md §7).  [arXiv:2407.21783; unverified]"""

import jax.numpy as jnp
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    n_layers=126, d_model=16384, n_heads=128, n_kv=8, d_ff=53248,
    vocab=128256, head_dim=128,
    rope_theta=5e5,
    dtype=jnp.bfloat16,
    decode_kv_splits=16,
)

SMOKE = ModelConfig(
    name="llama3-405b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=192,
    vocab=512, head_dim=16, rope_theta=5e5,
    dtype=jnp.float32, attn_chunk=64, logit_chunk=64,
)
