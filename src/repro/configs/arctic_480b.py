"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 PLUS a dense residual MLP in parallel.
[hf:Snowflake/snowflake-arctic-base; hf]"""

import jax.numpy as jnp
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    n_layers=35, d_model=7168, n_heads=56, n_kv=8, d_ff=4864,
    vocab=32000, head_dim=128,
    pattern=(("attn", "moe+dense"),),
    n_experts=128, top_k=2,
    dtype=jnp.bfloat16,
    decode_kv_splits=16,
)

SMOKE = ModelConfig(
    name="arctic-480b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=96,
    vocab=512, head_dim=16,
    pattern=(("attn", "moe+dense"),),
    n_experts=8, top_k=2,
    dtype=jnp.float32, attn_chunk=64, logit_chunk=64,
)
