"""glm4-9b [dense] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
RoPE, extreme GQA (kv=2).  [hf:THUDM/glm-4-9b; hf]"""

import jax.numpy as jnp
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    n_layers=40, d_model=4096, n_heads=32, n_kv=2, d_ff=13696,
    vocab=151552, head_dim=128,
    dtype=jnp.bfloat16,
    decode_kv_splits=16,
)

SMOKE = ModelConfig(
    name="glm4-9b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=1, d_ff=128,
    vocab=512, head_dim=16,
    dtype=jnp.float32, attn_chunk=64, logit_chunk=64,
)
