"""whisper-base [audio] — 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865.
Encoder-decoder; the conv/mel frontend is a STUB per spec: input_specs()
supplies precomputed frame embeddings (1500 frames = 30 s) to the encoder.
The decoder self-attends causally and cross-attends into the encoder output.
[arXiv:2212.04356; unverified]"""

import jax.numpy as jnp
from repro.models import ModelConfig

ENCODER_FRAMES = 1500

CONFIG = ModelConfig(
    name="whisper-base",
    n_layers=6, d_model=512, n_heads=8, n_kv=8, d_ff=2048,
    vocab=51865, head_dim=64,
    encoder_layers=6, encoder_len=ENCODER_FRAMES,
    frontend="audio",
    dtype=jnp.bfloat16,
    decode_kv_splits=16,
)

SMOKE = ModelConfig(
    name="whisper-base-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128,
    vocab=512, head_dim=16,
    encoder_layers=2, encoder_len=16, frontend="audio",
    dtype=jnp.float32, attn_chunk=64, logit_chunk=64,
)
