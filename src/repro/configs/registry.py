"""Architecture registry: ``--arch <id>`` lookup for configs and smoke configs."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models import ModelConfig

_MODULES: Dict[str, str] = {
    "dbrx-132b": "dbrx_132b",
    "arctic-480b": "arctic_480b",
    "internvl2-76b": "internvl2_76b",
    "qwen3-14b": "qwen3_14b",
    "smollm-135m": "smollm_135m",
    "llama3-405b": "llama3_405b",
    "glm4-9b": "glm4_9b",
    "whisper-base": "whisper_base",
    "mamba2-1.3b": "mamba2_1p3b",
    "jamba-v0.1-52b": "jamba_v01_52b",
}

ARCH_NAMES: List[str] = list(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE
