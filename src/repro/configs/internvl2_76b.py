"""internvl2-76b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256.  InternViT frontend is a STUB per spec: input_specs() supplies
precomputed patch embeddings prepended to the token sequence; the listed
config is the InternLM2/LLaMA-style language backbone.  [arXiv:2404.16821]"""

import jax.numpy as jnp
from repro.models import ModelConfig

N_PATCHES = 256        # stub ViT output tokens per example

CONFIG = ModelConfig(
    name="internvl2-76b",
    n_layers=80, d_model=8192, n_heads=64, n_kv=8, d_ff=28672,
    vocab=128256, head_dim=128,
    frontend="vision", n_frontend_tokens=N_PATCHES,
    dtype=jnp.bfloat16,
    decode_kv_splits=16,
)

SMOKE = ModelConfig(
    name="internvl2-76b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
    vocab=512, head_dim=16,
    frontend="vision", n_frontend_tokens=8,
    dtype=jnp.float32, attn_chunk=64, logit_chunk=64,
)
