"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4, fine-grained.  [hf:databricks/dbrx-base; unverified]"""

import jax.numpy as jnp
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    n_layers=40, d_model=6144, n_heads=48, n_kv=8, d_ff=10752,
    vocab=100352, head_dim=128,
    pattern=(("attn", "moe"),),
    n_experts=16, top_k=4,
    dtype=jnp.bfloat16,
    decode_kv_splits=16,
)

SMOKE = ModelConfig(
    name="dbrx-132b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
    vocab=512, head_dim=16,
    pattern=(("attn", "moe"),),
    n_experts=4, top_k=2,
    dtype=jnp.float32, attn_chunk=64, logit_chunk=64,
)
