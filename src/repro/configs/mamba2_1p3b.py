"""mamba2-1.3b [ssm] — 48L d_model=2048, attention-free, vocab=50280,
ssm_state=128.  SSD (state-space duality): chunked matmul formulation in
train/prefill, O(1) recurrence in decode — the arch that runs long_500k.
[arXiv:2405.21060; unverified]"""

import jax.numpy as jnp
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    n_layers=48, d_model=2048, n_heads=0, n_kv=0, d_ff=0,
    vocab=50280,
    pattern=(("mamba", "none"),),
    ssm_state=128, ssm_head_dim=64,
    dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="mamba2-1.3b-smoke",
    n_layers=2, d_model=64, n_heads=0, n_kv=0, d_ff=0,
    vocab=512,
    pattern=(("mamba", "none"),),
    ssm_state=16, ssm_head_dim=16,
    dtype=jnp.float32, ssd_chunk=32, logit_chunk=64,
)
