"""Parameterized Pallas TPU GEMM — the paper's §3.2 kernel, TPU-native.

Tuning parameters (see core/space.py for the PTX->Pallas mapping):
  bm, bn     output VMEM block (paper: M_L x N_L)
  bk         K-extent of the A/B slabs per grid step (paper: U)
  k_unroll   in-kernel unroll of the bk contraction (paper: K_S) — the MXU
             sees k_unroll independent (bm, bk/k_unroll) passes per step,
             giving the Mosaic scheduler ILP slack
  k_split    parallel split-K (paper: K_G).  TPUs have no global atomics, so
             the kernel materializes k_split partial outputs which the ops.py
             wrapper reduces — paying the paper's 'diminished write
             bandwidth' honestly
  order      grid-walk order: 0 = m-major (reuses B slabs across consecutive
             steps), 1 = n-major (reuses A slabs)
  acc32      accumulate in fp32 scratch (1) or the IO dtype (0)
  prefetch   conceptual DMA pipeline depth.  Pallas/Mosaic double-buffers
             sequential grid blocks automatically; the parameter is honored
             by the performance model and recorded for the generated config,
             but the kernel body is identical (documented DESIGN.md §3).

The kernel assumes shape-aligned operands; ``ops.matmul`` pads/slices (the
simulator charges that padding via its alignment-efficiency terms).
"""

from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int,
                 k_unroll: int, acc32: bool):
    """One (bm, bn) output block: accumulate a_ref @ b_ref over the k grid."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    bk = a.shape[-1]
    acc_t = acc_ref.dtype
    # K_S: statically unrolled sub-tiles expose independent MXU passes.
    sub = bk // k_unroll
    acc = acc_ref[...]
    for u in range(k_unroll):
        a_u = jax.lax.slice_in_dim(a, u * sub, (u + 1) * sub, axis=1)
        b_u = jax.lax.slice_in_dim(b, u * sub, (u + 1) * sub, axis=0)
        acc = acc + jnp.dot(a_u, b_u, preferred_element_type=acc_t)
    acc_ref[...] = acc

    @pl.when(k == k_steps - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(a: jax.Array, b: jax.Array, cfg: Mapping[str, int], *,
                  interpret: bool = True) -> jax.Array:
    """Aligned GEMM: a (M, K) @ b (K, N) -> (k_split, M, N) partials.

    Requires M % bm == 0, N % bn == 0, K % (k_split * bk) == 0 (ops.matmul
    guarantees this via padding).
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
    ks = cfg.get("k_split", 1)
    k_unroll = cfg.get("k_unroll", 1)
    acc32 = bool(cfg.get("acc32", 1))
    order = cfg.get("order", 0)
    assert M % bm == 0 and N % bn == 0 and K % (ks * bk) == 0, (
        (M, N, K), (bm, bn, bk, ks))
    gm, gn = M // bm, N // bn
    kps = K // (ks * bk)          # sequential k steps per split

    # grid = (split, outer, inner, k); `order` picks which of m/n is outer.
    if order == 0:
        grid = (ks, gm, gn, kps)
        a_map = lambda s, m, n, k: (m, s * kps + k)
        b_map = lambda s, m, n, k: (s * kps + k, n)
        o_map = lambda s, m, n, k: (s, m, n)
    else:
        grid = (ks, gn, gm, kps)
        a_map = lambda s, n, m, k: (m, s * kps + k)
        b_map = lambda s, n, m, k: (s * kps + k, n)
        o_map = lambda s, n, m, k: (s, m, n)

    acc_dtype = jnp.float32 if acc32 else a.dtype
    out_shape = jax.ShapeDtypeStruct((ks, M, N), a.dtype)

    kernel = functools.partial(
        _gemm_kernel, k_steps=kps, k_unroll=k_unroll, acc32=acc32)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), a_map),
            pl.BlockSpec((bk, bn), b_map),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), o_map),
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )(a, b)
