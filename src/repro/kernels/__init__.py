"""Pallas TPU kernels for the tuner's parameter spaces.

  matmul.py     paper §3.2 GEMM (bm/bn/bk/k_unroll/k_split/order/acc32)
  conv.py       paper §3.3 implicit-GEMM conv (shifted-window, c_split)
  attention.py  flash attention (beyond-paper tunable op)
  ssd.py        Mamba-2 SSD chunk scan (beyond-paper tunable op)
  ref.py        pure-jnp oracles
  ops.py        jit wrappers: padding + partial reduction
  dispatch.py   tuned-config routing (TPU: Pallas; CPU/dry-run: XLA ops)
"""

from . import dispatch, ops, ref
