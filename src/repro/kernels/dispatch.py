"""Kernel dispatch: route model compute through tuned kernels.

On TPU, ``matmul``/``conv2d``/... run the Pallas kernels with the
input-aware configuration from the installed tuner (the paper's §6 runtime:
input parameters fixed by the call site, tuning parameters inferred and
cached).  On CPU — including the multi-pod dry-run — they lower to plain XLA
ops so ``cost_analysis()`` reflects the true dataflow (DESIGN.md §4).

``check_config`` executes a Pallas kernel under interpret mode against its
ref.py oracle — the correctness notion of kernel legality used by
InterpretBackend and the test suite.
"""

from __future__ import annotations

from typing import Dict, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import ops, ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _dtype_bits(dtype) -> int:
    """Bit width of a dtype; safe on integer inputs (jnp.finfo floats only)."""
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.finfo(dtype).bits
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.iinfo(dtype).bits
    return 32


def _tuned_cfg(space_name: str, inputs: Mapping[str, int]
               ) -> Optional[Dict[str, int]]:
    """Config resolution: installed tuner, else nearest tunedb record.

    The store fallback is what lets a serving process with NO tuner in it
    (engine warm-start) still run tuned kernels: exact shape hits return the
    committed config, novel shapes borrow their nearest tuned neighbor and
    rely on the ops-layer block clamping for runnability.
    """
    from repro.core.tuner import get_tuner
    tuner = get_tuner(space_name)
    if tuner is not None:
        return tuner.best_config(inputs, remeasure=False)
    from repro.tunedb.store import get_store
    store = get_store()
    if store is not None:
        rec = store.nearest(space_name, inputs)   # memoized inside the store
        if rec is not None:
            return dict(rec.config)
    return None


def _record(space_name: str, inputs: Mapping[str, int]) -> None:
    from repro.tunedb.telemetry import record_shape
    record_shape(space_name, inputs)


def matmul(a: jax.Array, b: jax.Array, *, prefer_kernel: bool = False
           ) -> jax.Array:
    """Model-facing GEMM.  prefer_kernel forces the Pallas path (tests)."""
    if a.ndim == 2 and b.ndim == 2:     # non-2D operands: plain jnp.dot only
        from repro.core.space import gemm_input
        inputs = gemm_input(a.shape[0], b.shape[1], a.shape[1],
                            _dtype_bits(a.dtype))
        _record("gemm", inputs)
        if on_tpu() or prefer_kernel:
            cfg = _tuned_cfg("gemm", inputs)
            return ops.matmul(a, b, cfg, interpret=not on_tpu())
    return jnp.dot(a, b)


def matmul2(x: jax.Array, w: jax.Array, *, prefer_kernel: bool = False
            ) -> jax.Array:
    """Projection GEMM (..., D) @ (D, F) -> (..., F): the model-facing entry
    point.  Leading dims fold into M, so the tuner sees the true GEMM shape."""
    lead = x.shape[:-1]
    if on_tpu() or prefer_kernel:
        x2 = x.reshape(-1, x.shape[-1])
        return matmul(x2, w, prefer_kernel=prefer_kernel).reshape(*lead,
                                                                  w.shape[-1])
    from repro.core.space import gemm_input
    M = 1
    for d in lead:
        M *= d
    _record("gemm", gemm_input(M, w.shape[-1], x.shape[-1],
                               _dtype_bits(x.dtype)))
    return jnp.dot(x, w)


def conv2d(i: jax.Array, f: jax.Array, *, prefer_kernel: bool = False
           ) -> jax.Array:
    from repro.core.space import conv_input
    N, H, W, C = i.shape
    R, S, _, K = f.shape
    inputs = conv_input(N, H, W, C, K, R, S, _dtype_bits(i.dtype))
    _record("conv", inputs)
    if on_tpu() or prefer_kernel:
        cfg = _tuned_cfg("conv", inputs)
        return ops.conv2d(i, f, cfg, interpret=not on_tpu())
    return ref.conv2d_ref(i, f)


def flash_attention(q, k, v, *, causal=True, q_offset=0,
                    prefer_kernel: bool = False):
    inputs = {"B": q.shape[0], "Hq": q.shape[1], "Hkv": k.shape[1],
              "Lq": q.shape[2], "Lkv": k.shape[2], "D": q.shape[3],
              "dtype_bits": _dtype_bits(q.dtype), "causal": int(causal)}
    _record("attention", inputs)
    if on_tpu() or prefer_kernel:
        cfg = _tuned_cfg("attention", inputs)
        return ops.flash_attention(q, k, v, cfg, causal=causal,
                                   q_offset=q_offset,
                                   interpret=not on_tpu())
    return ref.attention_ref(q, k, v, causal=causal, q_offset=q_offset)


def ssd_scan(x, dt, a, bm, cm, *, prefer_kernel: bool = False):
    inputs = {"B": x.shape[0], "L": x.shape[1], "H": x.shape[2],
              "P": x.shape[3], "S": bm.shape[-1],
              "dtype_bits": _dtype_bits(x.dtype)}
    _record("ssd", inputs)
    if on_tpu() or prefer_kernel:
        cfg = _tuned_cfg("ssd", inputs)
        return ops.ssd_scan(x, dt, a, bm, cm, cfg, interpret=not on_tpu())
    # CPU/dry-run path: chunked-but-pure-jnp SSD (identical math, XLA ops)
    return ref.ssd_ref(x, dt, a, bm, cm)


# ---------------------------------------------------------------------------
# Correctness gate used by InterpretBackend + tests
# ---------------------------------------------------------------------------

def check_config(space_name: str, cfg: Dict[str, int],
                 inputs: Dict[str, int], *, rtol: float = 2e-2,
                 seed: int = 0, max_dim: int = 512) -> None:
    """Run the Pallas kernel for `cfg` on a shrunken instance of `inputs`
    (interpret mode) and assert allclose against the jnp oracle.  Raises on
    mismatch.  Dims are capped at max_dim to keep interpret mode fast — the
    config's *structure* (splits, unrolls, block shapes) is exercised fully.
    """
    rng = np.random.default_rng(seed)
    dtype = jnp.bfloat16 if inputs.get("dtype_bits", 16) <= 16 else jnp.float32
    cap = lambda v: int(min(v, max_dim))

    if space_name == "gemm":
        M, N, K = cap(inputs["M"]), cap(inputs["N"]), cap(inputs["K"])
        a = jnp.asarray(rng.normal(size=(M, K)), dtype)
        b = jnp.asarray(rng.normal(size=(K, N)), dtype)
        got = ops.matmul(a, b, cfg)
        want = ref.matmul_ref(a, b)
    elif space_name == "conv":
        N, H, W = cap(inputs["N"]), cap(inputs["H"]), cap(inputs["W"])
        C, K = cap(inputs["C"]), cap(inputs["K"])
        R, S = inputs["R"], inputs["S"]
        i = jnp.asarray(rng.normal(size=(min(N, 2), min(H, 16), min(W, 16), C)),
                        dtype)
        f = jnp.asarray(rng.normal(size=(R, S, C, K)) / (R * S * C) ** 0.5,
                        dtype)
        got = ops.conv2d(i, f, cfg)
        want = ref.conv2d_ref(i, f)
    elif space_name == "attention":
        B, Hq, Hkv = min(inputs["B"], 2), min(inputs["Hq"], 4), inputs["Hkv"]
        Hkv = min(Hkv, Hq)
        while Hq % Hkv:
            Hkv -= 1
        Lq, Lkv, D = cap(inputs["Lq"]), cap(inputs["Lkv"]), min(inputs["D"], 128)
        causal = bool(inputs.get("causal", 1)) and Lq == Lkv
        q = jnp.asarray(rng.normal(size=(B, Hq, Lq, D)), dtype)
        k = jnp.asarray(rng.normal(size=(B, Hkv, Lkv, D)), dtype)
        v = jnp.asarray(rng.normal(size=(B, Hkv, Lkv, D)), dtype)
        got = ops.flash_attention(q, k, v, cfg, causal=causal)
        want = ref.attention_ref(q, k, v, causal=causal)
    elif space_name == "ssd":
        B, L = min(inputs["B"], 2), cap(inputs["L"])
        H, P, S = min(inputs["H"], 4), min(inputs["P"], 64), min(inputs["S"], 64)
        x = jnp.asarray(rng.normal(size=(B, L, H, P)), dtype)
        dt = jnp.asarray(rng.uniform(0.01, 0.1, size=(B, L, H)), dtype)
        a = -jnp.asarray(rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
        bm = jnp.asarray(rng.normal(size=(B, L, S)), dtype)
        cm = jnp.asarray(rng.normal(size=(B, L, S)), dtype)
        got = ops.ssd_scan(x, dt, a, bm, cm, cfg)
        want = ref.ssd_ref(x, dt, a, bm, cm)
    else:
        raise ValueError(space_name)

    g = np.asarray(got, np.float32)
    w = np.asarray(want, np.float32)
    scale = max(float(np.abs(w).max()), 1e-6)
    err = float(np.abs(g - w).max()) / scale
    if not np.isfinite(g).all():
        raise AssertionError(f"{space_name} cfg {cfg}: non-finite output")
    if err > rtol:
        raise AssertionError(
            f"{space_name} cfg {cfg}: rel err {err:.4f} > {rtol}")
