"""Kernel dispatch: route model compute through tuned kernels.

On TPU, ``matmul``/``conv2d``/... run the Pallas kernels with the
input-aware configuration from the installed tuner (the paper's §6 runtime:
input parameters fixed by the call site, tuning parameters inferred and
cached).  On CPU — including the multi-pod dry-run — they lower to plain XLA
ops so ``cost_analysis()`` reflects the true dataflow (DESIGN.md §4).

``check_config`` executes a Pallas kernel under interpret mode against its
ref.py oracle — the correctness notion of kernel legality used by
InterpretBackend and the test suite.
"""

from __future__ import annotations

import warnings
from typing import Dict, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import ops, ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# one warning per (reason, space): a degraded serving process says so once,
# then keeps serving on the heuristic tier instead of spamming or crashing.
# The latch only throttles the *log line* — every occurrence still counts
# in the ``tunedb_dispatch_degraded_calls_total{reason,space}`` counter, so
# a process quietly living on vendor heuristics is visible in /metrics
# even though it warned exactly once.
_WARNED: set = set()
_DEGRADED_COUNTER = None        # bound lazily: obs must not import at startup


def _count_degraded(reason: str, space: str) -> None:
    global _DEGRADED_COUNTER
    counter = _DEGRADED_COUNTER
    if counter is None:
        try:
            from repro.tunedb.obs.metrics import get_registry
        except Exception:       # obs unavailable: degrade silently
            return
        counter = _DEGRADED_COUNTER = lambda r, s: get_registry().counter(
            "tunedb_dispatch_degraded_calls_total",
            "dispatches served by the heuristic fallback tier",
        ).inc(reason=r, space=s)
    try:
        counter(reason, space)
    except Exception:           # observability must never block dispatch
        pass


def _warn_once(key: tuple, msg: str) -> None:
    _count_degraded(str(key[0]), str(key[1]) if len(key) > 1 else "")
    if key not in _WARNED:
        _WARNED.add(key)
        warnings.warn(msg, RuntimeWarning, stacklevel=3)


def reset_fallback_warnings() -> None:
    """Re-arm the warn-once latches (tests; store/model reinstall).

    ``tunedb.store.install_serving`` calls this on EVERY install/hot-swap:
    a fresh store or ModelSet generation that degrades deserves its own
    warning — a latch left over from a degraded predecessor must not
    silently swallow it.
    """
    _WARNED.clear()


_HEURISTIC_LIBS: Dict[str, object] = {}


def _heuristic_cfg(space_name: str, inputs: Mapping[str, int]
                   ) -> Optional[Dict[str, int]]:
    """Last-resort config: the vendor-style size-bucket heuristics.

    Serving keeps running — slower, never wrong — when every tuned tier
    (store record, model, nearest neighbor) comes up empty.
    """
    if space_name not in ("gemm", "conv"):
        return None                     # ops-layer defaults cover attn/ssd
    lib = _HEURISTIC_LIBS.get(space_name)
    if lib is None:
        from repro.core.heuristics import VendorHeuristicLibrary
        from repro.core.space import SPACES
        maker = (VendorHeuristicLibrary.gemm if space_name == "gemm"
                 else VendorHeuristicLibrary.conv)
        lib = _HEURISTIC_LIBS[space_name] = maker(SPACES[space_name])
    return dict(lib.select(inputs))


# lazily bound tuner/serving-state accessors (_tuned_cfg); import-time
# binding would cycle through repro.tunedb.store -> this module
_GET_TUNER = None
_SERVING_STATE = None
# the trace module, bound on first resolution (False = unavailable).  The
# per-call tracing probe is ONE module-attribute read (`_TRACE._TRACER`):
# with tracing disabled that attribute is None and the resolution path is
# byte-identical to the untraced one — the E18 zero-instrument-call gate.
_TRACE = None


def _dtype_bits(dtype) -> int:
    """Bit width of a dtype; safe on integer inputs (jnp.finfo floats only)."""
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.finfo(dtype).bits
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.iinfo(dtype).bits
    return 32


def _tuned_cfg(space_name: str, inputs: Mapping[str, int]
               ) -> Optional[Dict[str, int]]:
    """Config resolution for a serving process with no tuner.

    Tier 0 is the **frozen dispatch plan** (PR 5): ``install_serving``
    compiles the generation's (store, ModelSet, telemetry hot set) into one
    flat shape->config table, so the steady-state hot set resolves with a
    single lock-free dict probe — no sha1 key digest, no model scan, no
    neighbor search.  The plan stands aside (``store.version`` moved past
    the version it was compiled from) the moment the store gains a record,
    so a frozen entry never shadows a fresher tuning outcome.

    Plan misses fall into the PR 2 three-tier slow path:

      1. exact record hit   — the store's fingerprint-keyed index;
      2. model-guided       — the per-(space, backend) performance regressor
                              scores every legal config in one batched MLP
                              forward pass (paper §6) and its pick is
                              memoized per shape;
      3. nearest neighbor   — the closest tuned shape's config, the pre-model
                              fallback, now only for shapes the model tier
                              cannot serve (no trained model, no legal cfg).

    A successful slow-path resolution is PROMOTED into the plan's overlay,
    so every shape pays the full stack at most once per generation.  An
    installed tuner (training/benchmark processes) short-circuits all of
    it.  If every tier misses but tuned serving was *configured* (a store or
    models are installed), dispatch degrades to the vendor-style heuristics
    and warns once — a missing/torn store file or an unreadable model
    artifact must never take serving down.

    The store, ModelSet, fingerprint pin, and plan come from ONE atomic
    ``serving_state()`` read: a concurrent retune hot-swap
    (``install_serving``) flips the whole generation at once, so a
    resolution never mixes the old store with the new models (or an old
    plan with a new store) — the plan a reader holds always belongs to the
    generation it read.
    """
    global _TRACE
    t = _TRACE
    if t is None:
        try:
            from repro.tunedb.obs import trace as t
        except Exception:
            t = False
        _TRACE = t
    tr = t._TRACER if t else None
    if tr is not None:
        # tracing enabled: time the resolution under the thread's current
        # trace (a no-op context when this thread has no sampled trace
        # open), attributing the winning tier and shape key
        with tr.span("dispatch.resolve", space=space_name) as sp:
            cfg, tier = _resolve_cfg(space_name, inputs)
            if sp is not None:
                sp.attrs["tier"] = tier
                sp.attrs["shape"] = ",".join(
                    f"{k}={v}" for k, v in sorted(inputs.items()))
        return cfg
    return _resolve_cfg(space_name, inputs)[0]


def _resolve_cfg(space_name: str, inputs: Mapping[str, int]
                 ) -> tuple:
    """The tier-resolution body of :func:`_tuned_cfg`, returning
    ``(config, winning tier)`` — tier is one of ``tuner``/``none``/
    ``plan``/``exact``/``model``/``nearest``/``degraded``."""
    global _GET_TUNER, _SERVING_STATE
    if _GET_TUNER is None:
        # bound once: the per-call `from x import y` module-dict round
        # trips are measurable against the single-probe plan path
        from repro.core.tuner import get_tuner
        from repro.tunedb.store import serving_state
        _GET_TUNER, _SERVING_STATE = get_tuner, serving_state
    tuner = _GET_TUNER(space_name)
    if tuner is not None:
        return tuner.best_config(inputs, remeasure=False), "tuner"
    state = _SERVING_STATE()
    store, models, fp = state.store, state.models, state.fingerprint
    plan = state.plan
    if store is None and models is None and plan is None:
        return None, "none"              # untuned process: ops defaults
    key = None
    if plan is not None and (store is None
                             or store.version == plan.store_version):
        key = tuple(sorted(inputs.items()))      # store.shape_key, inlined
        entry = plan.lookup(space_name, key)
        if entry is not None:            # tier 0: frozen plan hit
            cfg, tier = entry
            plan.hits += 1
            # plan hits keep the per-tier serving statistics honest: the
            # entry's originating tier gets the credit it would have
            # earned on the slow path — including the exact-tier MISS a
            # model/nearest-served shape books there (store coverage must
            # not inflate just because the plan warmed up)
            if store is None:            # plan-only serving (golden artifact
                pass                     # cold start): no store to credit
            elif tier == "exact":
                store.hits += 1
            elif tier == "nearest":
                store.misses += 1
                store.nearest_hits += 1
            else:
                if store is not None:
                    store.misses += 1
                if models is not None:   # duck-typed stubs may lack counters
                    models.hits = getattr(models, "hits", 0) + 1
            return dict(cfg), "plan"
        plan.misses += 1
    cfg = tier = None
    if store is not None:
        rec = store.get(space_name, inputs, backend=fp)
        if rec is not None:              # tier 1: exact record hit
            cfg, tier = rec.config, "exact"
    if cfg is None and models is not None:
        got = models.predict(space_name, inputs, backend=fp)
        if got is not None:              # tier 2: model-guided search
            cfg, tier = got[0], "model"
    if cfg is None and store is not None:
        rec = store.nearest(space_name, inputs, backend=fp)
        if rec is not None:              # tier 3: nearest tuned neighbor
            cfg, tier = rec.config, "nearest"
    if cfg is not None:
        if key is not None and (store is None
                                or store.version == plan.store_version):
            plan.promote(space_name, key, cfg, tier)
        return dict(cfg), tier
    _warn_once(("untuned", space_name),
               f"tunedb: no record, model, or neighbor for a {space_name} "
               f"shape {dict(inputs)}; serving on vendor heuristics")
    return _heuristic_cfg(space_name, inputs), "degraded"


def _record(space_name: str, inputs: Mapping[str, int]) -> None:
    from repro.tunedb.telemetry import record_shape
    record_shape(space_name, inputs)


def matmul(a: jax.Array, b: jax.Array, *, prefer_kernel: bool = False
           ) -> jax.Array:
    """Model-facing GEMM.  prefer_kernel forces the Pallas path (tests)."""
    if a.ndim == 2 and b.ndim == 2:     # non-2D operands: plain jnp.dot only
        from repro.core.space import gemm_input
        inputs = gemm_input(a.shape[0], b.shape[1], a.shape[1],
                            _dtype_bits(a.dtype))
        _record("gemm", inputs)
        if on_tpu() or prefer_kernel:
            cfg = _tuned_cfg("gemm", inputs)
            return ops.matmul(a, b, cfg, interpret=not on_tpu())
    return jnp.dot(a, b)


def matmul2(x: jax.Array, w: jax.Array, *, prefer_kernel: bool = False
            ) -> jax.Array:
    """Projection GEMM (..., D) @ (D, F) -> (..., F): the model-facing entry
    point.  Leading dims fold into M, so the tuner sees the true GEMM shape."""
    lead = x.shape[:-1]
    if on_tpu() or prefer_kernel:
        x2 = x.reshape(-1, x.shape[-1])
        return matmul(x2, w, prefer_kernel=prefer_kernel).reshape(*lead,
                                                                  w.shape[-1])
    from repro.core.space import gemm_input
    M = 1
    for d in lead:
        M *= d
    _record("gemm", gemm_input(M, w.shape[-1], x.shape[-1],
                               _dtype_bits(x.dtype)))
    return jnp.dot(x, w)


def conv2d(i: jax.Array, f: jax.Array, *, prefer_kernel: bool = False
           ) -> jax.Array:
    from repro.core.space import conv_input
    N, H, W, C = i.shape
    R, S, _, K = f.shape
    inputs = conv_input(N, H, W, C, K, R, S, _dtype_bits(i.dtype))
    _record("conv", inputs)
    if on_tpu() or prefer_kernel:
        cfg = _tuned_cfg("conv", inputs)
        return ops.conv2d(i, f, cfg, interpret=not on_tpu())
    return ref.conv2d_ref(i, f)


def flash_attention(q, k, v, *, causal=True, q_offset=0,
                    prefer_kernel: bool = False):
    inputs = {"B": q.shape[0], "Hq": q.shape[1], "Hkv": k.shape[1],
              "Lq": q.shape[2], "Lkv": k.shape[2], "D": q.shape[3],
              "dtype_bits": _dtype_bits(q.dtype), "causal": int(causal)}
    _record("attention", inputs)
    if on_tpu() or prefer_kernel:
        cfg = _tuned_cfg("attention", inputs)
        return ops.flash_attention(q, k, v, cfg, causal=causal,
                                   q_offset=q_offset,
                                   interpret=not on_tpu())
    return ref.attention_ref(q, k, v, causal=causal, q_offset=q_offset)


def ssd_scan(x, dt, a, bm, cm, *, prefer_kernel: bool = False):
    inputs = {"B": x.shape[0], "L": x.shape[1], "H": x.shape[2],
              "P": x.shape[3], "S": bm.shape[-1],
              "dtype_bits": _dtype_bits(x.dtype)}
    _record("ssd", inputs)
    if on_tpu() or prefer_kernel:
        cfg = _tuned_cfg("ssd", inputs)
        return ops.ssd_scan(x, dt, a, bm, cm, cfg, interpret=not on_tpu())
    # CPU/dry-run path: chunked-but-pure-jnp SSD (identical math, XLA ops)
    return ref.ssd_ref(x, dt, a, bm, cm)


# ---------------------------------------------------------------------------
# Correctness gate used by InterpretBackend + tests
# ---------------------------------------------------------------------------

def check_config(space_name: str, cfg: Dict[str, int],
                 inputs: Dict[str, int], *, rtol: float = 2e-2,
                 seed: int = 0, max_dim: int = 512) -> None:
    """Run the Pallas kernel for `cfg` on a shrunken instance of `inputs`
    (interpret mode) and assert allclose against the jnp oracle.  Raises on
    mismatch.  Dims are capped at max_dim to keep interpret mode fast — the
    config's *structure* (splits, unrolls, block shapes) is exercised fully.
    """
    rng = np.random.default_rng(seed)
    dtype = jnp.bfloat16 if inputs.get("dtype_bits", 16) <= 16 else jnp.float32
    cap = lambda v: int(min(v, max_dim))

    if space_name == "gemm":
        M, N, K = cap(inputs["M"]), cap(inputs["N"]), cap(inputs["K"])
        a = jnp.asarray(rng.normal(size=(M, K)), dtype)
        b = jnp.asarray(rng.normal(size=(K, N)), dtype)
        got = ops.matmul(a, b, cfg)
        want = ref.matmul_ref(a, b)
    elif space_name == "conv":
        N, H, W = cap(inputs["N"]), cap(inputs["H"]), cap(inputs["W"])
        C, K = cap(inputs["C"]), cap(inputs["K"])
        R, S = inputs["R"], inputs["S"]
        i = jnp.asarray(rng.normal(size=(min(N, 2), min(H, 16), min(W, 16), C)),
                        dtype)
        f = jnp.asarray(rng.normal(size=(R, S, C, K)) / (R * S * C) ** 0.5,
                        dtype)
        got = ops.conv2d(i, f, cfg)
        want = ref.conv2d_ref(i, f)
    elif space_name == "attention":
        B, Hq, Hkv = min(inputs["B"], 2), min(inputs["Hq"], 4), inputs["Hkv"]
        Hkv = min(Hkv, Hq)
        while Hq % Hkv:
            Hkv -= 1
        Lq, Lkv, D = cap(inputs["Lq"]), cap(inputs["Lkv"]), min(inputs["D"], 128)
        causal = bool(inputs.get("causal", 1)) and Lq == Lkv
        q = jnp.asarray(rng.normal(size=(B, Hq, Lq, D)), dtype)
        k = jnp.asarray(rng.normal(size=(B, Hkv, Lkv, D)), dtype)
        v = jnp.asarray(rng.normal(size=(B, Hkv, Lkv, D)), dtype)
        got = ops.flash_attention(q, k, v, cfg, causal=causal)
        want = ref.attention_ref(q, k, v, causal=causal)
    elif space_name == "ssd":
        B, L = min(inputs["B"], 2), cap(inputs["L"])
        H, P, S = min(inputs["H"], 4), min(inputs["P"], 64), min(inputs["S"], 64)
        x = jnp.asarray(rng.normal(size=(B, L, H, P)), dtype)
        dt = jnp.asarray(rng.uniform(0.01, 0.1, size=(B, L, H)), dtype)
        a = -jnp.asarray(rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
        bm = jnp.asarray(rng.normal(size=(B, L, S)), dtype)
        cm = jnp.asarray(rng.normal(size=(B, L, S)), dtype)
        got = ops.ssd_scan(x, dt, a, bm, cm, cfg)
        want = ref.ssd_ref(x, dt, a, bm, cm)
    else:
        raise ValueError(space_name)

    g = np.asarray(got, np.float32)
    w = np.asarray(want, np.float32)
    scale = max(float(np.abs(w).max()), 1e-6)
    err = float(np.abs(g - w).max()) / scale
    if not np.isfinite(g).all():
        raise AssertionError(f"{space_name} cfg {cfg}: non-finite output")
    if err > rtol:
        raise AssertionError(
            f"{space_name} cfg {cfg}: rel err {err:.4f} > {rtol}")
