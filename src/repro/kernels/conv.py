"""Shifted-window implicit-GEMM convolution — the paper's §3.3 on TPU.

The paper scrambles I/F tiles into shared memory through an indirection
table so the inner loop is free of integer arithmetic.  TPUs want static
layouts instead (DESIGN.md §3): we keep the padded input slab resident in
VMEM and walk the (r, s) filter offsets as *statically shifted slices*, each
feeding one MXU matmul of the implicit-GEMM view
    (N*P*Q, C*R*S) x (C*R*S, K).

Tuning parameters (core/space.py):
  b_npq      output spatial block, realized as b_p = max(b_npq // Q, 1)
             full-width row bands (windows must stay contiguous)
  b_k        output-channel block
  b_c        input-channel slab per grid step
  c_split    parallel split of the C reduction (paper: C_G) — materialized
             partials, reduced by ops.conv2d
  rs_unroll  scheduling granularity of the fully-unrolled (r, s) walk; the
             kernel body unrolls completely (R, S are static), the parameter
             informs the performance model
  order/acc32/prefetch  as in matmul.py

Layouts: I (N, H, W, C), F (R, S, C, K), O (N, P, Q, K); SAME padding,
stride 1 (the DeepBench regime the paper evaluates).  ops.conv2d pads
spatially+channel-wise and slices the result.
"""

from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _conv_kernel(i_ref, f_ref, o_ref, acc_ref, *, c_steps: int, b_p: int,
                 Q: int, R: int, S: int):
    """One (b_p x Q, b_k) output block, accumulated over the C grid axis."""
    p = pl.program_id(2)
    c = pl.program_id(4)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    img = i_ref[0]                      # (Hp, Wp, b_c) padded slab in VMEM
    acc = acc_ref[...]                  # (b_p * Q, b_k)
    row0 = p * b_p
    for r in range(R):                  # fully-unrolled shifted-window walk
        for s in range(S):
            win = jax.lax.dynamic_slice(
                img, (row0 + r, s, 0),
                (b_p, Q, img.shape[-1]))                 # (b_p, Q, b_c)
            lhs = win.reshape(b_p * Q, img.shape[-1])
            rhs = f_ref[r, s]                            # (b_c, b_k)
            acc = acc + jnp.dot(lhs, rhs,
                                preferred_element_type=acc.dtype)
    acc_ref[...] = acc

    @pl.when(c == c_steps - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       .reshape(b_p, Q, acc_ref.shape[-1])
                       .astype(o_ref.dtype))


def conv2d_pallas(i_pad: jax.Array, f: jax.Array, cfg: Mapping[str, int], *,
                  P: int, Q: int, interpret: bool = True) -> jax.Array:
    """Aligned conv on pre-padded input.

    i_pad: (N, P + R - 1, Q + S - 1, C) — spatially SAME-padded, P % b_p == 0,
           C % (c_split * b_c) == 0, channels padded.
    f:     (R, S, C, K), K % b_k == 0.
    Returns (c_split, N, P, Q, K) partial outputs.
    """
    N, Hp, Wp, C = i_pad.shape
    R, S, C2, K = f.shape
    assert C == C2 and Hp == P + R - 1 and Wp == Q + S - 1
    b_k, b_c = cfg["b_k"], cfg["b_c"]
    cs = cfg.get("c_split", 1)
    acc32 = bool(cfg.get("acc32", 1))
    b_p = max(cfg["b_npq"] // Q, 1)
    if P % b_p:                        # ops guarantees this; double-check
        b_p = 1
    assert K % b_k == 0 and C % (cs * b_c) == 0, ((K, C), (b_k, b_c, cs))
    gp, gk = P // b_p, K // b_k
    cps = C // (cs * b_c)              # sequential C steps per split

    grid = (cs, N, gp, gk, cps)

    i_map = lambda s_, n, p, k, c: (n, 0, 0, s_ * cps + c)
    f_map = lambda s_, n, p, k, c: (0, 0, s_ * cps + c, k)
    o_map = lambda s_, n, p, k, c: (s_, n, p, 0, k)

    acc_dtype = jnp.float32 if acc32 else i_pad.dtype
    kernel = functools.partial(_conv_kernel, c_steps=cps, b_p=b_p, Q=Q,
                               R=R, S=S)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, b_c), i_map),
            pl.BlockSpec((R, S, b_c, b_k), f_map),
        ],
        out_specs=pl.BlockSpec((1, 1, b_p, Q, b_k), o_map),
        out_shape=jax.ShapeDtypeStruct((cs, N, P, Q, K), i_pad.dtype),
        scratch_shapes=[pltpu.VMEM((b_p * Q, b_k), acc_dtype)],
        interpret=interpret,
    )(i_pad, f)
