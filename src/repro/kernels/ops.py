"""Jit-ready wrappers around the Pallas kernels: padding, partial-sum
reduction, and config defaulting.  These are the public kernel entry points;
models call them through ``dispatch`` which injects tuned configurations.
"""

from __future__ import annotations

from typing import Mapping, Optional

import jax
import jax.numpy as jnp

from . import attention as _attention
from . import conv as _conv
from . import matmul as _matmul
from . import ssd as _ssd

DEFAULT_GEMM = {"bm": 128, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
                "order": 0, "acc32": 1, "prefetch": 2}
DEFAULT_CONV = {"b_npq": 128, "b_k": 128, "b_c": 128, "rs_unroll": 1,
                "c_split": 1, "order": 0, "acc32": 1, "prefetch": 2}
DEFAULT_ATTN = {"b_q": 128, "b_kv": 128, "acc32": 1, "prefetch": 2}
DEFAULT_SSD = {"chunk": 128, "b_heads": 1, "acc32": 1, "prefetch": 2}


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def matmul(a: jax.Array, b: jax.Array,
           cfg: Optional[Mapping[str, int]] = None, *,
           interpret: bool = True) -> jax.Array:
    """C = A @ B through the parameterized Pallas kernel (pads + reduces)."""
    cfg = {**DEFAULT_GEMM, **(cfg or {})}
    M, K = a.shape
    _, N = b.shape
    bm, bn, bk, ks = cfg["bm"], cfg["bn"], cfg["bk"], cfg["k_split"]
    # shrink blocks that exceed the (padded) problem — keeps any legal-ish
    # config runnable so the tuner can probe freely
    while bm > M and bm > 8:
        bm //= 2
    while bn > N and bn > 128:
        bn //= 2
    while bk * ks > K and bk > 128:
        bk //= 2
    while ks > 1 and bk * ks > max(K, bk):
        ks //= 2
    ku = cfg["k_unroll"]
    while ku > 1 and bk % (ku * 128):
        ku //= 2
    cfg = {**cfg, "bm": bm, "bn": bn, "bk": bk, "k_split": ks, "k_unroll": ku}
    a_p = _pad_to(_pad_to(a, 0, bm), 1, bk * ks)
    b_p = _pad_to(_pad_to(b, 0, bk * ks), 1, bn)
    parts = _matmul.matmul_pallas(a_p, b_p, cfg, interpret=interpret)
    out = parts.sum(axis=0) if ks > 1 else parts[0]
    return out[:M, :N]


def conv2d(i: jax.Array, f: jax.Array,
           cfg: Optional[Mapping[str, int]] = None, *,
           interpret: bool = True) -> jax.Array:
    """SAME/stride-1 conv i (N,H,W,C) * f (R,S,C,K) -> (N,H,W,K)."""
    cfg = {**DEFAULT_CONV, **(cfg or {})}
    N, H, W, C = i.shape
    R, S, _, K = f.shape
    P, Q = H, W
    b_k, b_c, cs = cfg["b_k"], cfg["b_c"], cfg["c_split"]
    while b_k > K and b_k > 128:
        b_k //= 2
    while b_c * cs > C and b_c > 32:
        b_c //= 2
    while cs > 1 and b_c * cs > max(C, b_c):
        cs //= 2
    b_p = max(min(cfg["b_npq"] // Q, P), 1)
    while P % b_p:
        b_p -= 1
    cfg = {**cfg, "b_k": b_k, "b_c": b_c, "c_split": cs}

    # SAME padding (odd filters center; even filters follow XLA's convention)
    pt = (R - 1) // 2
    pb = R - 1 - pt
    pl_ = (S - 1) // 2
    pr = S - 1 - pl_
    i_pad = jnp.pad(i, ((0, 0), (pt, pb), (pl_, pr), (0, 0)))
    i_pad = _pad_to(i_pad, 3, b_c * cs)
    f_p = _pad_to(_pad_to(f, 2, b_c * cs), 3, b_k)

    parts = _conv.conv2d_pallas(i_pad, f_p, cfg, P=P, Q=Q,
                                interpret=interpret)
    out = parts.sum(axis=0) if cs > 1 else parts[0]
    return out[:, :, :, :K]


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    cfg: Optional[Mapping[str, int]] = None, *,
                    causal: bool = True, q_offset: int = 0,
                    interpret: bool = True) -> jax.Array:
    """Padded flash attention; masks padded KV via the causal machinery."""
    cfg = {**DEFAULT_ATTN, **(cfg or {})}
    B, Hq, Lq, D = q.shape
    Lkv = k.shape[2]
    b_q = min(cfg["b_q"], max(Lq, 1))
    b_kv = min(cfg["b_kv"], max(Lkv, 1))
    q_p = _pad_to(q, 2, b_q)
    k_p = _pad_to(k, 2, b_kv)
    v_p = _pad_to(v, 2, b_kv)
    Lq_p, Lkv_p = q_p.shape[2], k_p.shape[2]
    eff_offset = q_offset if causal else 0
    if not causal and Lkv_p != Lkv:
        # non-causal with padded KV: mask pads by position (offset trick)
        causal, eff_offset = True, Lkv - 1 - (Lq - 1)
    out = _attention.flash_attention_pallas(
        q_p, k_p, v_p, {**cfg, "b_q": b_q, "b_kv": b_kv}, causal=causal,
        q_offset=eff_offset, interpret=interpret)
    return out[:, :, :Lq]


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
             cm: jax.Array, cfg: Optional[Mapping[str, int]] = None, *,
             interpret: bool = True) -> jax.Array:
    """Padded SSD chunk scan (pads L; padded steps have dt=0 => identity)."""
    cfg = {**DEFAULT_SSD, **(cfg or {})}
    B, L, H, P = x.shape
    chunk = min(cfg["chunk"], L)
    bh = cfg.get("b_heads", 1)
    while H % bh:
        bh //= 2
    x_p = _pad_to(x, 1, chunk)
    dt_p = _pad_to(dt, 1, chunk)
    bm_p = _pad_to(bm, 1, chunk)
    cm_p = _pad_to(cm, 1, chunk)
    out = _ssd.ssd_scan_pallas(x_p, dt_p, a, bm_p, cm_p,
                               {**cfg, "chunk": chunk, "b_heads": bh},
                               interpret=interpret)
    return out[:, :L]
