"""Pure-jnp reference oracles for every Pallas kernel.

Each function is the semantic ground truth the kernels are tested against
(tests/test_kernels.py sweeps shapes/dtypes/configs and asserts allclose).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)
                   ).astype(a.dtype)


def conv2d_ref(i: jax.Array, f: jax.Array) -> jax.Array:
    """SAME-padded stride-1 conv.  i (N,H,W,C), f (R,S,C,K) -> (N,H,W,K)."""
    dn = jax.lax.conv_dimension_numbers(i.shape, f.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    return jax.lax.conv_general_dilated(
        i.astype(jnp.float32), f.astype(jnp.float32),
        window_strides=(1, 1), padding="SAME",
        dimension_numbers=dn).astype(i.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, q_offset: int = 0) -> jax.Array:
    """GQA attention.  q (B,Hq,Lq,D), k/v (B,Hkv,Lkv,D)."""
    B, Hq, Lq, D = q.shape
    Hkv, Lkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf)
    s = s / (D ** 0.5)
    if causal:
        rows = q_offset + jnp.arange(Lq)[:, None]
        cols = jnp.arange(Lkv)[None, :]
        s = jnp.where(cols <= rows, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
            cm: jax.Array) -> jax.Array:
    """Sequential SSD recurrence — the exact (slow) oracle.

    state_{t} = exp(a*dt_t) * state_{t-1} + dt_t * x_t (outer) B_t
    y_t       = C_t . state_t
    x (B,L,H,P), dt (B,L,H), a (H,), bm/cm (B,L,S) -> y (B,L,H,P)
    """
    B, L, H, P = x.shape
    S = bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = bm.astype(jnp.float32)
    cf = cm.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp            # (B,H,P), (B,H), (B,S), (B,S)
        decay = jnp.exp(af[None, :] * dtt)                      # (B,H)
        contrib = jnp.einsum("bh,bhp,bs->bhps", dtt, xt, bt)
        state = state * decay[:, :, None, None] + contrib       # (B,H,P,S)
        y = jnp.einsum("bhps,bs->bhp", state, ct)
        return state, y

    state0 = jnp.zeros((B, H, P, S), jnp.float32)
    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          bf.transpose(1, 0, 2), cf.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
