"""Mamba-2 SSD (state-space duality) chunk scan — tunable Pallas kernel.

Beyond-paper op: the paper's tuner is extended to the SSD chunked scan
(DESIGN.md §5, mamba2/jamba architectures).  The chunked algorithm
(arXiv:2405.21060) splits the sequence into chunks of length `chunk`:
within a chunk the recurrence is a masked quadratic form (MXU-friendly),
across chunks a (P x S) state is carried — here in VMEM scratch across
sequential grid steps, the TPU-idiomatic substitute for the paper's GPU
inter-block communication.

Tunables (core/space.py SSD_SPACE): chunk, b_heads, acc32, prefetch.

Layouts: x (B, L, H, P), dt (B, L, H), A (H,), Bm/Cm (B, L, S) [ngroups=1],
y (B, L, H, P).  ops.ssd_scan pads L to a chunk multiple.
"""

from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)         # (chunk, bh, P)
    dt = dt_ref[0].astype(jnp.float32)       # (chunk, bh)
    a = a_ref[...].astype(jnp.float32)       # (bh,)
    bm = b_ref[0].astype(jnp.float32)        # (chunk, S)
    cm = c_ref[0].astype(jnp.float32)        # (chunk, S)

    adt = dt * a[None, :]                    # (chunk, bh) log-decay per step
    cum = jnp.cumsum(adt, axis=0)            # (chunk, bh)

    # -- intra-chunk: masked quadratic form (the 'duality' matmul) ---------
    # scores[i, j, h] = (C_i . B_j) * exp(cum[i,h] - cum[j,h]) for j <= i
    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)  # (c, c)
    decay = jnp.exp(cum[:, None, :] - cum[None, :, :])          # (c, c, bh)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = (jj <= ii)[:, :, None]
    scores = jnp.where(mask, cb[:, :, None] * decay, 0.0)       # (c, c, bh)
    xdt = x * dt[:, :, None]                                    # (c, bh, P)
    y_intra = jnp.einsum("ijh,jhp->ihp", scores, xdt)

    # -- inter-chunk: contribution of the carried state --------------------
    state = state_ref[...]                                      # (bh, P, S)
    y_inter = jnp.einsum("is,hps,ih->ihp", cm, state, jnp.exp(cum))

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # -- state update -------------------------------------------------------
    tail = jnp.exp(cum[-1][None, :] - cum)                      # (c, bh)
    contrib = jnp.einsum("jh,jhp,js->hps", tail * dt, x, bm)
    state_ref[...] = state * jnp.exp(cum[-1])[:, None, None] + contrib


def ssd_scan_pallas(x: jax.Array, dt: jax.Array, a: jax.Array,
                    bm: jax.Array, cm: jax.Array, cfg: Mapping[str, int], *,
                    interpret: bool = True) -> jax.Array:
    """Aligned SSD scan: L % chunk == 0, H % b_heads == 0 required."""
    B, L, H, P = x.shape
    S = bm.shape[-1]
    chunk = min(cfg["chunk"], L)
    bh = min(cfg.get("b_heads", 1), H)
    assert L % chunk == 0 and H % bh == 0, ((L, H), (chunk, bh))
    n_chunks = L // chunk
    gh = H // bh

    grid = (B, gh, n_chunks)                 # chunks innermost: sequential

    x_map = lambda b, h, c: (b, c, h, 0)
    dt_map = lambda b, h, c: (b, c, h)
    a_map = lambda b, h, c: (h,)
    bc_map = lambda b, h, c: (b, c, 0)
    y_map = lambda b, h, c: (b, c, h, 0)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bh, P), x_map),
            pl.BlockSpec((1, chunk, bh), dt_map),
            pl.BlockSpec((bh,), a_map),
            pl.BlockSpec((1, chunk, S), bc_map),
            pl.BlockSpec((1, chunk, S), bc_map),
        ],
        out_specs=pl.BlockSpec((1, chunk, bh, P), y_map),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((bh, P, S), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, bm, cm)
