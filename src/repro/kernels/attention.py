"""Flash attention with tunable blocks — beyond-paper op (paper §9 asks for a
front-end 'beyond GEMM and CONV'; attention is the modern bottleneck).

Online-softmax streaming over KV blocks; GQA handled by head-index mapping
(no KV replication in HBM).  Tunables (core/space.py ATTENTION_SPACE):
  b_q    query rows per block
  b_kv   KV rows streamed per grid step
  acc32  accumulator precision
  prefetch  perf-model pipeline depth (Pallas double-buffers automatically)

Layouts: q (B, Hq, Lq, D), k/v (B, Hkv, Lkv, D), out (B, Hq, Lq, D).
ops.flash_attention pads Lq/Lkv and handles the causal offset for decode
(Lq tokens attending to a Lkv >= Lq cache).
"""

from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 kv_steps: int, b_q: int, b_kv: int, causal: bool,
                 q_offset: int, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                      # (b_q, D)
    k = k_ref[0, 0]                      # (b_kv, D)
    v = v_ref[0, 0]                      # (b_kv, D)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        # global positions: query row iq*b_q + i (+ cache offset for decode),
        # key column ik*b_kv + j
        rows = q_offset + iq * b_q + jax.lax.broadcasted_iota(
            jnp.int32, (b_q, b_kv), 0)
        cols = ik * b_kv + jax.lax.broadcasted_iota(
            jnp.int32, (b_q, b_kv), 1)
        s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_ref[...]                  # (b_q, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * alpha
                    + jnp.dot(p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ik == kv_steps - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           cfg: Mapping[str, int], *, causal: bool = True,
                           q_offset: int = 0,
                           interpret: bool = True) -> jax.Array:
    """Aligned flash attention.  Lq % b_q == 0, Lkv % b_kv == 0 required."""
    B, Hq, Lq, D = q.shape
    _, Hkv, Lkv, _ = k.shape
    b_q = min(cfg["b_q"], Lq)
    b_kv = min(cfg["b_kv"], Lkv)
    assert Lq % b_q == 0 and Lkv % b_kv == 0, ((Lq, Lkv), (b_q, b_kv))
    assert Hq % Hkv == 0
    group = Hq // Hkv
    gq, gkv = Lq // b_q, Lkv // b_kv
    scale = 1.0 / (D ** 0.5)

    grid = (B, Hq, gq, gkv)

    q_map = lambda b, h, iq, ik: (b, h, iq, 0)
    kv_map = lambda b, h, iq, ik: (b, h // group, ik, 0)
    o_map = lambda b, h, iq, ik: (b, h, iq, 0)

    kernel = functools.partial(
        _attn_kernel, kv_steps=gkv, b_q=b_q, b_kv=b_kv, causal=causal,
        q_offset=q_offset, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, b_q, D), q_map),
            pl.BlockSpec((1, 1, b_kv, D), kv_map),
            pl.BlockSpec((1, 1, b_kv, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, b_q, D), o_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((b_q, 1), jnp.float32),      # running max
            pltpu.VMEM((b_q, 1), jnp.float32),      # running denominator
            pltpu.VMEM((b_q, D), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
