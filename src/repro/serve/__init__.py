from .engine import Engine, ServeConfig
from .flash_decode import flash_decode_attention

__all__ = ["Engine", "ServeConfig", "flash_decode_attention"]
