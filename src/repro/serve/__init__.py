from .engine import Engine, ServeConfig
from .flash_decode import flash_decode_attention, resolve_decode_splits
from .router import (ROUTER_POLICIES, RandomRouter, Replica,
                     RoundRobinRouter, Router, ShapeAffinityRouter,
                     make_router, plan_coverage)

__all__ = [
    "Engine", "ServeConfig",
    "flash_decode_attention", "resolve_decode_splits",
    "ROUTER_POLICIES", "RandomRouter", "Replica", "RoundRobinRouter",
    "Router", "ShapeAffinityRouter", "make_router", "plan_coverage",
]
