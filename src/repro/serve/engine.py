"""Batched serving engine: slot-based continuous batching over a shared
KV/state cache.

A fixed number of decode *slots* share one jitted decode_step.  Requests are
admitted into free slots (prefill fills the slot's cache region), every
decode tick advances all active slots together at their own per-slot cache
positions, and finished requests (EOS or length budget) free their slot for
the next queued request.  This is the vLLM-style throughput recipe reduced to
its TPU-idiomatic essence: static shapes, one compiled program per
{prompt-length, decode}, per-slot bookkeeping in numpy on the host.

Prefill runs at exact prompt length (compile-cached per distinct length):
padding a prompt would poison recurrent (mamba) state and conv caches, so
exactness is correctness, not merely efficiency, for hybrid/SSM archs.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import HBM_GBPS, PEAK_BF16_TFLOPS, PEAK_FP32_TFLOPS
from repro.models import ModelConfig, decode_step, init_cache, prefill


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 2048
    slots: int = 8                  # concurrent sequences
    eos_token: int = -1             # -1: never emitted (synthetic tokens)
    temperature: float = 0.0        # 0 => greedy
    seed: int = 0
    tunedb: Optional[str] = None    # warm-start: tuning-record store path
    # model artifacts dir for model-guided dispatch; None auto-discovers the
    # store's sibling `<tunedb>.models/` dir, "" disables the model tier
    tunedb_models: Optional[str] = None
    # pin dispatch lookups to one backend fingerprint (multi-backend stores);
    # None keeps the any-backend single-backend behavior
    tunedb_backend: Optional[str] = None
    # -- model-tier confidence gating (tunedb.model.ModelSet) ----------------
    # fall back to nearest-neighbor when the model's top-1 margin over top-2
    # is below this relative threshold (0 = trust every argmax) ...
    tunedb_margin: float = 0.0
    # ... or when the shape sits off the training manifold: any input
    # feature more than this many standard deviations from the featurizer's
    # training stats (0 disables the gate)
    tunedb_max_z: float = 6.0
    # -- continuous retuning (tunedb.controller.RetuneController) ------------
    retune: bool = False            # close the telemetry->tune->serve loop
    retune_interval: int = 64       # decode ticks between controller polls
    retune_drift: float = 0.25      # hot-shape mass TV distance trigger
    retune_untuned_mass: float = 0.5   # untuned fraction of window trigger
    retune_min_calls: int = 32      # window calls before a space is judged
    retune_top_k: int = 4           # novel hot shapes tuned per session
    retune_train: bool = True       # retrain + hot-swap regressors too
    # run triggered epochs on a background thread (submit-and-return polls)
    # instead of inline on the decode tick that tripped the threshold
    retune_async: bool = False
    # fleet directory to publish drift-triggered plans to (lease files for
    # external `fleet worker` processes); implies async submission
    retune_fleet: Optional[str] = None
    # cap retune epochs: engine ticks between sessions / sessions per window
    retune_cooldown_ticks: int = 0
    retune_max_sessions: int = 0    # per retune_window_s (0 = unlimited)
    retune_window_s: float = 600.0
    # skip epochs whose projected gain over the nearest-record tier is small
    retune_min_gain: float = 0.0
    # regression-sentry noise margin gating each retune's serving swap
    # (None disables the gate; see tunedb.obs.RegressionSentry)
    retune_sentry: Optional[float] = None
    # -- fleet-global telemetry + routing (tunedb.telemetry / serve.router) ---
    # > 0 (with retune_fleet set): export this engine's telemetry to the
    # fleet bus every N seconds (<fleet>/telemetry/<worker>/, cumulative
    # dumps) AND hand the retune controller the aggregated
    # FleetTelemetryView — retunes then trigger off fleet-wide hot-shape
    # mass instead of this one process's window; 0 stays process-local
    telemetry_export_s: float = 0.0
    # request-router policy for a multi-replica front-end: "affinity"
    # (route each request to the replica whose plan covers its shapes),
    # "round_robin" / "random" baselines; None disables routing.  The
    # engine registers itself as the first replica; peers are added via
    # engine.router.add_replica
    router: Optional[str] = None
    # -- golden plan artifacts (tunedb.plans; see docs/PLANS.md) --------------
    # load a persisted plan artifact directory at startup instead of
    # compiling one — the cold-start path that skips install-time model
    # scans entirely; a torn/unverifiable artifact warns and degrades to a
    # normal install-time compile
    plan_dir: Optional[str] = None
    # plan registry directory to FOLLOW: a PlanFollower daemon thread polls
    # it and atomically hot-swaps each newly published generation into this
    # engine's serving state (never a torn or stale-generation plan)
    follow: Optional[str] = None
    follow_interval_s: float = 2.0  # seconds between registry polls
    # sentry noise margin for the follower's plan-coverage diff before a
    # swap (None disables that refusal gate)
    follow_sentry: Optional[float] = 0.10
    # plan registry the retune controller publishes each successful swap's
    # compiled plan to — the coordinator half of the follow protocol
    retune_publish: Optional[str] = None
    # append per-decode-tick wall seconds to Engine.tick_times (benchmarks
    # and the fleet acceptance test; off in production serving)
    record_tick_times: bool = False
    # most recent ticks kept in Engine.tick_times (a bounded deque): a
    # long-running serve with record_tick_times on must not grow without
    # bound; 0 keeps every tick (short benchmark runs only)
    tick_times_cap: int = 4096
    # -- graceful degradation (docs/ROBUSTNESS.md) ----------------------------
    # per-request wall-clock deadline, enforced at decode-tick boundaries:
    # a request older than this retires with whatever tokens it has (active
    # slots) or is rejected unserved (still pending).  None disables.
    request_deadline_s: Optional[float] = None
    # admission backlog cap: while active + pending exceeds this, the
    # NEWEST pending requests are shed (rejected unserved, counted in
    # tunedb_requests_shed_total, /healthz answers 503).  None disables.
    shed_threshold: Optional[int] = None
    # -- admission policy -----------------------------------------------------
    # "fifo": admit pending requests in arrival order (the PR 1-4 behavior).
    # "store": store-aware admission — prefer requests whose prompt-length
    # prefill kernel shapes hit the frozen dispatch plan / tuned records,
    # and group equal lengths so compiled programs and plan entries are
    # reused back-to-back (every queued request is still served; only the
    # admission ORDER changes, never correctness)
    admission: str = "fifo"
    # -- observability (tunedb.obs) -------------------------------------------
    # run a StatusServer (/metrics, /status, /plan) inside this engine on
    # the given port; 0 binds an ephemeral port (Engine.status_server.port
    # says which), None disables the endpoint
    status_port: Optional[int] = None
    # -- tracing + wall-clock measurement (tunedb.obs.trace / tunedb.measure) -
    # fraction of trace roots (decode ticks, admissions) sampled into the
    # span tracer; 0 disables tracing entirely — the hot paths then make
    # zero instrument calls (E18).  Exported Chrome trace JSON loads in
    # Perfetto; see docs/OBSERVABILITY.md
    trace_sample: float = 0.0
    # §6 re-measurement backend for the model tier's top-k candidates:
    # "wallclock" times real kernels (falls back to the simulator with a
    # warn-once off TPU hardware), "sim" uses the analytic simulator, None
    # disables serving-path measurement.  Measurements are scheduled into
    # idle decode gaps (MeasureQueue), never inline on dispatch
    measure: Optional[str] = None


def _ceil_div(x: int, t: int) -> int:
    return -(-x // t)


def _roofline_time_s(space: str, cfg: Mapping[str, int],
                     inputs: Mapping[str, int]) -> Optional[float]:
    """``max(compute, HBM)`` time estimate for ``cfg`` at ``inputs``.

    A two-term roofline from the ``core.backend`` chip constants — peak
    MXU TFLOPS for the dtype against HBM bandwidth — with the *block
    schedule* charged the way the simulator charges it: compute covers the
    ceil-padded grid (``gm*bm x gn*bn x gk*bk``), and A/B traffic counts
    full blocks per grid step, so quantization waste inflates BOTH axes
    while the exact-size output write pads neither.  Secondary effects
    (MXU occupancy, DMA latency, launch overhead) cancel in the ratios the
    admission floor takes, so they are deliberately left out.  Returns
    ``None`` for spaces without a roofline model.
    """
    bits = int(inputs.get("dtype_bits", 16))
    bpe = max(bits // 8, 1)
    peak = (PEAK_BF16_TFLOPS if bits <= 16 else PEAK_FP32_TFLOPS) * 1e12
    hbm = HBM_GBPS * 1e9
    if space == "gemm":
        m, n, k = int(inputs["M"]), int(inputs["N"]), int(inputs["K"])
        bm = int(cfg.get("bm") or m)
        bn = int(cfg.get("bn") or n)
        bk = int(cfg.get("bk") or k)
        mp = _ceil_div(m, bm) * bm
        np_ = _ceil_div(n, bn) * bn
        kp = _ceil_div(k, bk) * bk
        t_c = 2.0 * mp * np_ * kp / peak
        a_bytes = _ceil_div(n, bn) * mp * kp * bpe      # A slab per N step
        b_bytes = _ceil_div(m, bm) * kp * np_ * bpe     # B slab per M step
        out_bytes = m * n * bpe
        t_m = (a_bytes + b_bytes + out_bytes) / hbm
        return max(t_c, t_m)
    if space == "attention":
        b = int(inputs.get("B", 1))
        hq = int(inputs.get("Hq", 1))
        hkv = int(inputs.get("Hkv", hq))
        lq, lkv = int(inputs["Lq"]), int(inputs["Lkv"])
        d = int(inputs.get("D", 64))
        frac = 0.5 if inputs.get("causal") else 1.0
        bq = int(cfg.get("b_q") or lq)
        bkv = int(cfg.get("b_kv") or lkv)
        lqp = _ceil_div(lq, bq) * bq
        lkvp = _ceil_div(lkv, bkv) * bkv
        t_c = 4.0 * b * hq * lqp * lkvp * d * frac / peak
        qo_bytes = 2 * b * hq * lq * d * bpe            # Q read + O write
        kv_bytes = 2 * b * hkv * lkv * d * bpe
        t_m = (qo_bytes + kv_bytes) / hbm
        return max(t_c, t_m)
    return None


def _useful_flops(space: str, inputs: Mapping[str, int]) -> Optional[float]:
    if space == "gemm":
        return 2.0 * inputs["M"] * inputs["N"] * inputs["K"]
    if space == "attention":
        frac = 0.5 if inputs.get("causal") else 1.0
        return (4.0 * inputs.get("B", 1) * inputs.get("Hq", 1)
                * inputs["Lq"] * inputs["Lkv"] * inputs.get("D", 64) * frac)
    return None


def _roofline_floor(space: str, near, inputs: Mapping[str, int]) -> float:
    """Projected TFLOPS of the nearest record's config at THIS shape.

    Anchored on the record's measured number: the analytic roofline only
    supplies the *ratio* between the config's throughput at the query
    shape and at the record's own shape, so chip-constant errors and every
    shape-independent effect divide out.  Falls back to the raw recorded
    TFLOPS (no penalty, the conservative choice) when the space has no
    roofline model.
    """
    t_q = _roofline_time_s(space, near.config, inputs)
    t_r = _roofline_time_s(space, near.config, near.inputs)
    u_q = _useful_flops(space, inputs)
    u_r = _useful_flops(space, near.inputs)
    if not t_q or not t_r or not u_q or not u_r:
        return near.tflops
    return near.tflops * (u_q / t_q) / (u_r / t_r)


def _count_admission(space: str, decision: str) -> None:
    """Padded-vs-native bucket decisions into the metrics registry."""
    try:
        from repro.tunedb.obs.metrics import get_registry
        get_registry().counter(
            "tunedb_admission_decisions_total",
            "store-aware admission bucket outcomes").inc(
                space=space, decision=decision)
    except Exception:
        pass    # observability never blocks admission


class StoreAwareAdmission:
    """Store-aware batch admission: prefer shapes the dispatch plan serves.

    Two decisions, both made from RECORDED numbers only (no measurement on
    the admission path):

    * :meth:`bucket` — for one dispatchable work shape, whether to pad its
      ``pad_dims`` up to a tuned record's shape.  Padding a GEMM's M (zero
      rows in, garbage rows sliced off) is mathematically exact, so the
      only question is throughput: the padded run delivers the record's
      measured TFLOPS scaled by the useful-work fraction, while the exact
      shape would be served by its nearest neighbor's config paying an
      analytic block-quantization penalty (``ceil(dim/block)`` waste — the
      same ``_align_eff`` structure the simulator charges).  Pad exactly
      when the recorded-TFLOPS arithmetic says the overhead beats the
      untuned config, never past ``max_pad`` relative extra work.

    * :meth:`pick` — which pending request the engine admits into a free
      slot next: prompt lengths whose captured prefill kernel shapes hit
      the frozen plan score highest, equal lengths group back-to-back
      (compiled-program and plan-entry reuse), unknown lengths sit in the
      middle (they must compile either way).  FIFO order breaks ties, and
      every request is still served — only the order changes.
    """

    def __init__(self, *, pad_dims=("M",), max_pad: float = 1.0):
        self.pad_dims = tuple(pad_dims)
        self.max_pad = max_pad
        self.padded = 0                   # bucket() decisions that padded
        self.exact = 0
        self._score_memo: Dict[tuple, float] = {}

    # -- shape bucketing ------------------------------------------------------
    def bucket(self, space: str, inputs: Mapping[str, int]
               ) -> Tuple[Dict[str, int], str]:
        """(dispatch shape, "hit"|"exact"|"padded") for one work item."""
        from repro.tunedb.store import serving_state
        state = serving_state()
        store = state.store
        if store is None:
            return dict(inputs), "exact"
        fp = state.fingerprint
        if store.contains(space, inputs, backend=fp):
            _count_admission(space, "hit")
            return dict(inputs), "hit"    # already tuned: nothing to decide
        # the untuned floor: what the nearest-neighbor tier would deliver —
        # its recorded TFLOPS rescaled by the compute/bandwidth roofline
        # ratio between this shape and the record's own (see
        # ``_roofline_floor``).  The record's measured number anchors the
        # estimate; the roofline only says how much MORE (or less) block
        # quantization its config pays here, on whichever axis — MXU peak
        # or HBM bandwidth — actually bounds the kernel.  This replaces the
        # blanket ``rel ** 0.5`` damping of PR 5, which split the regimes
        # by fiat instead of deriving the boundedness from chip constants.
        floor = 0.0
        near = store.nearest(space, inputs, backend=fp, count=False)
        if near is not None:
            floor = _roofline_floor(space, near, inputs)
        best_rec, best_eff = None, floor
        # candidates come from the store's comparable-shape group (same
        # dim names + exact-match values), not a full-store scan — the
        # cost per decision tracks the group size, not the index size
        for rec in store.neighbors(space, inputs):
            if fp is not None and rec.backend != fp:
                continue
            work, ok = 1.0, True
            for k, v in inputs.items():
                rv = rec.inputs[k]
                if k in self.pad_dims:
                    if rv < v:
                        ok = False
                        break
                    work *= v / rv
                elif rv != v:
                    ok = False
                    break
            # work is the useful fraction; 1/work - 1 is the pad overhead
            if not ok or work * (1.0 + self.max_pad) < 1.0:
                continue
            eff = rec.tflops * work       # recorded TFLOPS, usefully spent
            if eff > best_eff:
                best_rec, best_eff = rec, eff
        if best_rec is None:
            self.exact += 1
            _count_admission(space, "exact")
            return dict(inputs), "exact"
        self.padded += 1
        _count_admission(space, "padded")
        return dict(best_rec.inputs), "padded"

    # -- engine admission order -----------------------------------------------
    def _length_score(self, n: int, prefill_shapes: Mapping[int, list],
                      state) -> float:
        shapes = prefill_shapes.get(n)
        if not shapes:
            return 0.5                    # unknown length: must compile anyway
        memo_key = (state.generation, n)
        score = self._score_memo.get(memo_key)
        if score is not None:
            return score
        from repro.tunedb.store import shape_key
        hits = 0
        for space, inputs in shapes:
            entry = (state.plan.lookup(space, shape_key(inputs))
                     if state.plan is not None else None)
            if entry is not None or (
                    state.store is not None
                    and state.store.contains(space, inputs,
                                             backend=state.fingerprint)):
                hits += 1
        score = hits / len(shapes)
        if len(self._score_memo) > 1024:
            self._score_memo.clear()
        self._score_memo[memo_key] = score
        return score

    def pick(self, pending: list, prefill_shapes: Mapping[int, list],
             last_len: Optional[int] = None) -> int:
        """Index into ``pending`` of the request to admit next."""
        from repro.tunedb.store import serving_state
        state = serving_state()
        best_i, best_score = 0, -1.0
        for i, req in enumerate(pending):
            n = len(req.prompt)
            score = self._length_score(n, prefill_shapes, state)
            if last_len is not None and n == last_len:
                score += 0.25             # program + plan-entry reuse
            if score > best_score + 1e-9:  # stable: FIFO breaks ties
                best_i, best_score = i, score
        return best_i


# shared reusable no-op context: the untraced engine loop enters this one
# module-level object instead of allocating per tick
_NULL_CTX = contextlib.nullcontext()


class _TickTimes(list):
    """Bounded tick-time buffer: a real list (slicing and iteration work
    exactly as before) that keeps only the newest ``cap`` entries.  cap=0
    keeps everything — short benchmark runs that want the full series."""

    def __init__(self, cap: int = 0) -> None:
        super().__init__()
        self.cap = int(cap)

    def append(self, item) -> None:
        list.append(self, item)
        if self.cap and len(self) > self.cap:
            del self[: len(self) - self.cap]


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # (len,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    arrived_at: float = 0.0         # time.monotonic() at admission-queue entry
    shed: bool = False              # rejected unserved by load shedding
    deadline_exceeded: bool = False  # cut short / rejected by the deadline


class Engine:
    def __init__(self, cfg: ModelConfig, params: Any, serve_cfg: ServeConfig,
                 *, retune_tuners: Optional[Dict[str, Any]] = None):
        self.cfg, self.params, self.sc = cfg, params, serve_cfg
        # end-to-end tracing: install (or retune the sampling of) the
        # process-global span tracer BEFORE anything below runs, so install
        # paths, calibration measurements, and the first prefill all land
        # in the same trace stream.  trace_sample=0 leaves tracing exactly
        # as it was — usually disabled, costing zero instrument calls.
        self.tracer = None
        if serve_cfg.trace_sample > 0:
            from repro.tunedb.obs.trace import enable_tracing
            self.tracer = enable_tracing(serve_cfg.trace_sample)
        # Warm start (tunedb): install the record store + model artifacts so
        # kernel dispatch resolves tuned configs from day-one traffic without
        # any tuner (or its training cost) in the serving process.  Like
        # install_tuner, both are PROCESS-GLOBAL dispatch state: a later
        # Engine with a tunedb path retargets them, tunedb=None leaves them
        # untouched, and repro.tunedb.clear_store()/clear_models()
        # uninstalls.  A missing or fully-torn store file and unreadable
        # model artifacts DEGRADE (warn once, heuristics tier keeps serving)
        # instead of failing the engine.
        self.tunedb_store = None
        self.tunedb_models = None
        self._models_dir = None
        if serve_cfg.tunedb or serve_cfg.tunedb_models or serve_cfg.plan_dir:
            import pathlib
            import warnings

            from repro.tunedb.model import (ModelSet, default_models_dir,
                                            install_models)
            models_dir = serve_cfg.tunedb_models
            if serve_cfg.tunedb:
                from repro.tunedb import RecordStore, install_store
                store_path = pathlib.Path(serve_cfg.tunedb)
                if not store_path.exists():
                    warnings.warn(
                        f"tunedb store {store_path} does not exist; serving "
                        "starts with an empty store (heuristics fallback)",
                        RuntimeWarning, stacklevel=2)
                self.tunedb_store = RecordStore.open(store_path)
                if self.tunedb_store.n_skipped \
                        and not self.tunedb_store.n_lines:
                    warnings.warn(
                        f"tunedb store {store_path} is torn beyond the tail "
                        f"({self.tunedb_store.n_skipped} unreadable lines, 0 "
                        "records); serving degrades to heuristics",
                        RuntimeWarning, stacklevel=2)
                if serve_cfg.plan_dir is None:
                    install_store(self.tunedb_store,
                                  fingerprint=serve_cfg.tunedb_backend)
                if models_dir is None:       # auto-discover next to the store
                    models_dir = default_models_dir(store_path)
            elif serve_cfg.plan_dir is None:
                # models-only config: no store install runs, but the explicit
                # backend pin must still take effect — otherwise the model
                # tier serves the newest any-backend regressor (or a prior
                # engine's stale pin) despite `tunedb_backend`
                from repro.tunedb.store import install_serving
                install_serving(fingerprint=serve_cfg.tunedb_backend)
            models = ModelSet.load(models_dir) if models_dir else ModelSet()
            # serving policy lives on the ModelSet: confidence gating keeps a
            # confidently-wrong regressor from undercutting a nearby record
            models.margin_threshold = serve_cfg.tunedb_margin
            models.max_feature_z = serve_cfg.tunedb_max_z
            if len(models) or models.skipped:
                self.tunedb_models = models
            self._models_dir = models_dir or None
            if serve_cfg.plan_dir is not None:
                # golden cold start (docs/PLANS.md): ONE install carrying
                # store + models + the persisted plan, so no install-time
                # plan compile — and none of its model scans — ever runs;
                # a rejected artifact degrades to the normal compile
                from repro.tunedb.plans import (PlanArtifactError,
                                                check_freshness, load_plan,
                                                read_manifest)
                from repro.tunedb.store import install_serving
                plan = None
                try:
                    plan = load_plan(serve_cfg.plan_dir)
                    note = check_freshness(read_manifest(serve_cfg.plan_dir),
                                           self.tunedb_store)
                    if note:
                        warnings.warn(
                            f"plan artifact {serve_cfg.plan_dir}: {note}",
                            RuntimeWarning, stacklevel=2)
                except PlanArtifactError as e:
                    warnings.warn(
                        f"plan artifact {serve_cfg.plan_dir} rejected ({e}); "
                        "compiling a plan from the store instead",
                        RuntimeWarning, stacklevel=2)
                install_serving(store=self.tunedb_store,
                                models=models if len(models) else None,
                                fingerprint=serve_cfg.tunedb_backend,
                                plan=plan)
            else:
                # retarget the global model tier to THIS config's artifacts —
                # including installing None when there are none (or the tier
                # is disabled with tunedb_models="") so a previous Engine's
                # regressors never serve another store's traffic
                install_models(models if len(models) else None)
        # wall-clock measurer (paper §6 re-measurement, on the real clock):
        # the model tier's top-k candidates are re-measured by
        # ServingMeasurer — wall clock on TPU hardware, simulator fallback
        # (warn-once) off it — but never inline: predict() enqueues onto
        # the MeasureQueue and the controller poll drains it in idle
        # decode gaps (see maybe_retune).  One tiny calibration GEMM runs
        # now, proving the backend path (and firing the off-hardware
        # warning) before traffic arrives.
        self.measurer = None
        self._measure_queue = None
        if serve_cfg.measure:
            from repro.core.space import gemm_input
            from repro.tunedb.measure import MeasureQueue, ServingMeasurer
            from repro.tunedb.store import serving_state
            self.measurer = ServingMeasurer(serve_cfg.measure)
            self._measure_queue = MeasureQueue()
            live_models = serving_state().models
            if live_models is not None:
                live_models.measurer = self.measurer
                live_models.measure_queue = self._measure_queue
            try:
                self.measurer("gemm",
                              {"bm": 128, "bn": 128, "bk": 128,
                               "k_unroll": 1, "k_split": 1, "order": 0,
                               "acc32": 1, "prefetch": 2},
                              gemm_input(256, 256, 256, 16))
            except Exception:
                pass            # a failed calibration must not stop serving
        # startup dispatch probe: resolve each installed shape once through
        # the real dispatch path so the trace (and tier_latency) carries
        # tier attribution immediately — on TPU the decode compile would do
        # this anyway, but a CPU dev box's model path never enters the
        # Pallas kernels, and its /trace view should still show which tier
        # each tuned shape would serve from.
        if self.tracer is not None and (serve_cfg.tunedb
                                        or serve_cfg.plan_dir):
            self._probe_dispatch()
        self.cache = init_cache(cfg, serve_cfg.slots, serve_cfg.max_len)
        self.lengths = np.zeros(serve_cfg.slots, np.int64)
        self.slot_req: List[Optional[Request]] = [None] * serve_cfg.slots
        self._rng = jax.random.PRNGKey(serve_cfg.seed)
        self.ticks = 0

        self._decode = jax.jit(
            lambda p, t, c, i: decode_step(p, cfg, t, c, i))
        self._prefill_fns: Dict[int, Callable] = {}
        # jit tick telemetry: dispatch records at TRACE time only, so the
        # engine captures which kernel shapes each compiled program executes
        # and replays them per tick — true frequencies, not a compile census
        self._decode_shapes: Optional[List] = None
        self._prefill_shapes: Dict[int, List] = {}
        # per-decode-tick (start perf_counter, wall seconds, thread-CPU
        # seconds) when ServeConfig.record_tick_times — the fleet bench/test
        # reads this.  Thread CPU time is the de-noised "did THIS thread do
        # the work" clock: an inline retune session lands in it, scheduler
        # preemption and other threads' work do not.  Bounded: a week-long
        # serve with recording on keeps the newest tick_times_cap entries
        # instead of growing without limit (a real list subclass, so the
        # bench/test read surface — slicing, iteration — is unchanged).
        self.tick_times = _TickTimes(serve_cfg.tick_times_cap)
        # store-aware admission: reorder/group pending requests toward
        # plan-hit prefill shapes ("fifo" keeps arrival order)
        self.admission = (StoreAwareAdmission()
                          if serve_cfg.admission == "store" else None)
        self._last_admit_len: Optional[int] = None
        # graceful degradation counters (request_deadline_s/shed_threshold):
        # shedding flips while the backlog is over the cap and feeds the
        # /healthz probe, so balancers stop routing to a drowning replica
        self.shed_requests = 0
        self.deadline_retired = 0
        self.shedding = False
        # fleet-global telemetry: export this engine's counters to the bus
        # and aggregate every replica's dumps into one global view the
        # retune controller reads (drift/untuned-mass off FLEET-wide
        # traffic, not this process's window) — own dumps are excluded
        # from the aggregate so local counts never fold in twice
        self.exporter = None
        self._fleet_view = None
        if serve_cfg.retune_fleet and serve_cfg.telemetry_export_s > 0:
            from repro.tunedb.fleet import FleetDir
            from repro.tunedb.telemetry import (FleetTelemetryView,
                                                TelemetryExporter,
                                                get_telemetry)
            tel_dir = FleetDir(serve_cfg.retune_fleet).telemetry_dir()
            self.exporter = TelemetryExporter(
                get_telemetry(), tel_dir,
                interval_s=serve_cfg.telemetry_export_s).start()
            self._fleet_view = FleetTelemetryView(
                tel_dir, exclude={self.exporter.worker_id},
                refresh_s=serve_cfg.telemetry_export_s)
        self.controller = None
        self._next_retune_tick = 0
        if serve_cfg.retune or serve_cfg.retune_fleet:
            self._init_controller(retune_tuners)
        # shape-affinity request router: this engine registers itself as
        # the first routable replica (its live plan + active-slot load);
        # front-ends add peer replicas through engine.router.add_replica
        self.router = None
        if serve_cfg.router:
            from repro.tunedb.store import serving_state
            from .router import make_router
            self.router = make_router(serve_cfg.router)
            self.router.add_replica(
                "local",
                plan=lambda: serving_state().plan,
                load=lambda: sum(r is not None for r in self.slot_req))
        # plan follower: a daemon thread adopting golden plan generations a
        # coordinator publishes to the registry — each one digest-verified,
        # sentry-diffed, and swapped in atomically (docs/PLANS.md)
        self.follower = None
        if serve_cfg.follow:
            from repro.tunedb.plans import PlanFollower
            follow_sentry = None
            if serve_cfg.follow_sentry is not None:
                from repro.tunedb.obs import RegressionSentry
                follow_sentry = RegressionSentry(
                    noise_margin=serve_cfg.follow_sentry)
            self.follower = PlanFollower(
                serve_cfg.follow, store=self.tunedb_store,
                fingerprint=serve_cfg.tunedb_backend,
                poll_s=serve_cfg.follow_interval_s,
                sentry=follow_sentry).start()
        # in-process observability endpoint: /metrics, /status, /plan read
        # the live serving state this engine just installed (plus its
        # controller's retune history and fleet bus, when configured)
        self.status_server = None
        if serve_cfg.status_port is not None:
            from repro.tunedb.obs import StatusServer
            self.status_server = StatusServer(
                port=serve_cfg.status_port,
                controller=self.controller,
                fleet=serve_cfg.retune_fleet,
                follower=self.follower,
                router=self.router,
                tracer=self.tracer,
                health=self._health).start()

    def _health(self):
        """/healthz readiness: 503 while this replica is shedding load."""
        if self.shedding:
            return (False, "shedding load: admission backlog over "
                           "shed_threshold")
        return True

    @staticmethod
    def _count_degraded(kind: str, n: int = 1) -> None:
        try:
            from repro.tunedb.obs.metrics import get_registry
            reg = get_registry()
            if kind == "shed":
                reg.counter(
                    "tunedb_requests_shed_total",
                    "requests rejected unserved by admission load shedding",
                ).inc(n)
            else:
                reg.counter(
                    "tunedb_request_deadline_exceeded_total",
                    "requests cut short or rejected by request_deadline_s",
                ).inc(n, state=kind)
        except Exception:       # metrics must never break serving
            pass

    def _probe_dispatch(self, max_shapes: int = 8) -> None:
        """Resolve a few installed shapes through kernel dispatch under a
        ``dispatch.probe`` trace root (always kept — one per engine start).
        Purely observational: configs are resolved and discarded."""
        try:
            from repro.kernels.dispatch import _tuned_cfg
            from repro.tunedb.obs.trace import new_trace_id
            from repro.tunedb.store import serving_state
            store = serving_state().store
            if store is None:
                return
            seen = set()
            with self.tracer.root("dispatch.probe",
                                  trace_id=new_trace_id()):
                for rec in store.records():
                    key = (rec.space, tuple(sorted(rec.inputs.items())))
                    if key in seen:
                        continue
                    seen.add(key)
                    _tuned_cfg(rec.space, rec.inputs)
                    if len(seen) >= max_shapes:
                        break
        except Exception:
            pass                # a probe must never stop serving

    def _init_controller(self, retune_tuners: Optional[Dict[str, Any]]) -> None:
        """Close the loop in-process: drift-triggered sessions + hot-swap.

        Uses the warm-start store when one was configured; otherwise installs
        a fresh in-memory store so session results have somewhere to land
        (and exact-tier dispatch picks them up immediately)."""
        from repro.tunedb import RecordStore, install_store
        from repro.tunedb.controller import RetuneConfig, RetuneController
        from repro.tunedb.store import get_store
        sc = self.sc
        store = self.tunedb_store or get_store()
        if store is None:
            store = RecordStore()
            install_store(store, fingerprint=sc.tunedb_backend)
            self.tunedb_store = store
        self.controller = RetuneController(
            store,
            # the aggregated fleet view when telemetry export is on: drift
            # and untuned-mass judge GLOBAL hot-shape mass, so a shape no
            # single replica's window would trip on still triggers here
            telemetry=self._fleet_view,
            tuners=retune_tuners,
            models_dir=self._models_dir,
            async_mode=sc.retune_async,
            fleet_dir=sc.retune_fleet,
            measurer=self.measurer,
            measure_queue=self._measure_queue,
            cfg=RetuneConfig(
                drift_threshold=sc.retune_drift,
                untuned_mass_threshold=sc.retune_untuned_mass,
                min_calls=sc.retune_min_calls,
                top_k_shapes=sc.retune_top_k,
                retrain=sc.retune_train,
                cooldown_ticks=sc.retune_cooldown_ticks,
                max_sessions_per_window=sc.retune_max_sessions,
                session_window_s=sc.retune_window_s,
                min_gain=sc.retune_min_gain,
                sentry=sc.retune_sentry,
                publish=sc.retune_publish))
        self._next_retune_tick = sc.retune_interval

    def maybe_retune(self):
        """Poll the retune controller every ``retune_interval`` decode ticks.

        Returns the RetuneReport when a drift-triggered retune ran this
        tick, else None.  A no-trigger poll is a telemetry snapshot diff —
        microseconds against a multi-millisecond decode tick.  In async
        mode (``retune_async``/``retune_fleet``) a triggered poll only
        submits the epoch; the report surfaces on the first poll after the
        background session+merge+retrain completes its atomic swap.

        This is also the idle-decode-gap measurement slot: a few pending
        §6 re-measurements (MeasureQueue) drain here every tick — via the
        controller when one runs, directly otherwise — so measurements
        never sit inline on a dispatch resolution.
        """
        q = self._measure_queue
        if q is not None and len(q):
            if self.controller is not None:
                self.controller.process_measurements()
            else:
                from repro.tunedb.store import serving_state
                q.process(self.measurer, models=serving_state().models)
        if self.controller is None or self.ticks < self._next_retune_tick:
            return None
        self._next_retune_tick = self.ticks + self.sc.retune_interval
        return self.controller.maybe_retune(tick=self.ticks)

    # -- prefill ---------------------------------------------------------------
    def _prefill_one(self, slot: int, req: Request) -> None:
        from repro.tunedb.telemetry import get_telemetry

        cfg, sc = self.cfg, self.sc
        n = len(req.prompt)
        tokens = jnp.asarray(req.prompt[None])
        if n not in self._prefill_fns:
            def fn(params, tokens):
                single = init_cache(cfg, 1, sc.max_len)
                return prefill(params, cfg, {"tokens": tokens}, single)
            self._prefill_fns[n] = jax.jit(fn)
            # compiling call: capture the kernel shapes this prompt length
            # traces (the census count doubles as this execution's tick)
            with get_telemetry().capture() as cap:
                logits, single = self._prefill_fns[n](self.params, tokens)
            self._prefill_shapes[n] = cap.shapes
        else:
            logits, single = self._prefill_fns[n](self.params, tokens)
            if self._prefill_shapes.get(n):
                get_telemetry().record_ticks(self._prefill_shapes[n])

        def merge(big, small):
            # big (repeats, slots, ...); small (repeats, 1, ...)
            return jax.lax.dynamic_update_index_in_dim(big, small[:, 0],
                                                       slot, 1)
        self.cache = jax.tree_util.tree_map(merge, self.cache, single)
        self.lengths[slot] = n
        self.slot_req[slot] = req
        tok = int(self._sample(np.asarray(logits)[:, : cfg.vocab])[0])
        req.out.append(tok)

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.sc.temperature <= 0:
            return logits.argmax(-1)
        self._rng, k = jax.random.split(self._rng)
        return np.asarray(jax.random.categorical(
            k, jnp.asarray(logits) / self.sc.temperature))

    # -- main loop --------------------------------------------------------------
    def generate(self, prompts: List[np.ndarray], max_new: int = 32
                 ) -> List[List[int]]:
        """Continuous-batching loop: admit -> decode tick -> retire."""
        cfg, sc = self.cfg, self.sc
        t_arrive = time.monotonic()
        queue = [Request(np.asarray(p, np.int32), max_new,
                         arrived_at=t_arrive) for p in prompts]
        pending = list(queue)
        active = 0
        # tracing: each admission and each decode tick opens its own trace
        # root (sampled per trace_sample); router decisions, prefill,
        # dispatch-tier resolutions, and idle-gap measurements nest under
        # whichever root is open on this thread.  tr None = the untraced
        # path, byte-identical to before, zero instrument calls.
        tr = self.tracer

        while pending or active:
            # graceful degradation, both checks at tick/admit boundaries:
            # overdue PENDING requests are rejected unserved (their slot
            # time is already lost), and while the backlog is over
            # shed_threshold the NEWEST arrivals are shed so the oldest
            # still meet their deadlines.  A shed/expired request keeps
            # whatever tokens it has; its flags say why it stopped.
            if sc.request_deadline_s is not None and pending:
                now = time.monotonic()
                expired = [r for r in pending
                           if now - r.arrived_at > sc.request_deadline_s]
                if expired:
                    for req in expired:
                        req.deadline_exceeded = True
                    pending = [r for r in pending if not r.deadline_exceeded]
                    self.deadline_retired += len(expired)
                    self._count_degraded("rejected", len(expired))
            if sc.shed_threshold is not None:
                shed_now = 0
                while active + len(pending) > sc.shed_threshold:
                    req = pending.pop()          # newest arrival goes first
                    req.shed = True
                    shed_now += 1
                if shed_now:
                    self.shed_requests += shed_now
                    self.shedding = True
                    self._count_degraded("shed", shed_now)
                elif active + len(pending) < sc.shed_threshold:
                    self.shedding = False        # backlog drained: healthy
            while pending:                       # admit into free slots
                slot = next((i for i, r in enumerate(self.slot_req)
                             if r is None), None)
                if slot is None:
                    break
                nxt = 0
                if self.admission is not None and len(pending) > 1:
                    nxt = self.admission.pick(pending, self._prefill_shapes,
                                              last_len=self._last_admit_len)
                req = pending.pop(nxt)
                self._last_admit_len = len(req.prompt)
                n = len(req.prompt)
                with (tr.root("engine.admit", prompt_len=n)
                      if tr is not None else _NULL_CTX):
                    if self.router is not None:
                        # single-process engine: the decision is recorded
                        # (and scraped at /status) even though the only
                        # replica is us — a front-end holding the same
                        # router object over several engines gets real
                        # placement from this call
                        self.router.route(self._prefill_shapes.get(n, []))
                    with (tr.span("engine.prefill", prompt_len=n)
                          if tr is not None else _NULL_CTX):
                        self._prefill_one(slot, req)
                active += 1
            if active == 0:
                break

            # one decode tick for every slot (idle slots run on garbage that
            # is discarded — static shapes, zero recompiles)
            from repro.tunedb.telemetry import get_telemetry
            if sc.record_tick_times:
                t_tick, c_tick = time.perf_counter(), time.thread_time()
            with (tr.root("engine.tick", tick=self.ticks)
                  if tr is not None else _NULL_CTX):
                last = np.array([
                    (r.out[-1] if r is not None and r.out else 0)
                    for r in self.slot_req], np.int32)[:, None]
                idx = jnp.asarray(self.lengths, jnp.int32)  # slot position
                if self._decode_shapes is None:
                    # compiling tick: the trace-time census IS this tick's
                    # count
                    with get_telemetry().capture() as cap:
                        logits, self.cache = self._decode(
                            self.params, jnp.asarray(last), self.cache, idx)
                    self._decode_shapes = cap.shapes
                else:
                    logits, self.cache = self._decode(
                        self.params, jnp.asarray(last), self.cache, idx)
                    if self._decode_shapes:
                        get_telemetry().record_ticks(self._decode_shapes)
                toks = self._sample(np.asarray(logits)[:, : cfg.vocab])
                self.ticks += 1
                # fold this tick's lock-free telemetry rings into the
                # counters: one batched drain per tick instead of one lock
                # per kernel call
                get_telemetry().drain_pending()
                self.maybe_retune()

            now = (time.monotonic()
                   if sc.request_deadline_s is not None else 0.0)
            for s, req in enumerate(self.slot_req):
                if req is None:
                    continue
                self.lengths[s] += 1
                tok = int(toks[s])
                req.out.append(tok)
                overdue = (sc.request_deadline_s is not None
                           and now - req.arrived_at > sc.request_deadline_s)
                if overdue:
                    # deadline at the tick boundary: the request retires
                    # with the tokens it has instead of starving the queue
                    req.deadline_exceeded = True
                    self.deadline_retired += 1
                    self._count_degraded("retired", 1)
                if (overdue or tok == sc.eos_token
                        or len(req.out) >= req.max_new
                        or self.lengths[s] + 1 >= sc.max_len):
                    self.slot_req[s] = None
                    self.lengths[s] = 0
                    active -= 1
            if sc.record_tick_times:
                self.tick_times.append((t_tick,
                                        time.perf_counter() - t_tick,
                                        time.thread_time() - c_tick))
        return [r.out for r in queue]
