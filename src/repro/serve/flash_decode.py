"""Sequence-parallel decode attention (flash-decoding partial softmax).

For long-context decode (the 500k-token cells) a single query attends over a
KV cache too large — and too serial — for one chip.  Flash-decoding splits
the KV length into `n_splits` blocks, computes an independent partial softmax
(max, exp-sum, weighted accumulator) per block, and merges with the standard
log-sum-exp combine.  Expressed as batched jnp ops over a leading split axis
that the sharding rules place on the 'model' mesh axis ('seq' logical axis):
each chip reduces its local KV shard, and the combine is a tiny cross-chip
reduction — O(B*H*D) bytes instead of O(B*L*H*D).

This is mathematically identical to `_chunked_attention` (a flash combine is
a flash combine) but restructured from a sequential scan into a parallel
split + tree-combine, which is what makes it shardable.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain


def resolve_decode_splits(*, B: int, Hq: int, Hkv: int, Lkv: int, D: int,
                          dtype_bits: int, causal: int = 1,
                          default: int = 1) -> int:
    """Tuned KV-split count for one decode step, telemetry-fed.

    The split count is derived from the attention space's tuned ``b_kv``
    (KV block size) for the decode shape ``Lq=1``: ``n_splits = Lkv //
    b_kv`` — each split reduces one tuned-size KV block.  The shape is
    recorded into telemetry first, so decode-split traffic participates in
    hot-shape mining, frozen plans, and retunes like every other kernel
    call (ROADMAP item 3, first slice).  Falls back to ``default`` (the
    previously hard-coded caller value) when no tuned config resolves or
    the tuned block does not tile ``Lkv`` — behavior is unchanged for
    untuned processes.
    """
    from repro.kernels import dispatch
    inputs = {"B": int(B), "Hq": int(Hq), "Hkv": int(Hkv), "Lq": 1,
              "Lkv": int(Lkv), "D": int(D), "dtype_bits": int(dtype_bits),
              "causal": int(causal)}
    dispatch._record("attention", inputs)
    cfg = dispatch._tuned_cfg("attention", inputs)
    if cfg is None:
        return default
    b_kv = int(cfg.get("b_kv", 0))
    if b_kv <= 0 or Lkv % b_kv != 0:
        return default
    return max(1, Lkv // b_kv)


def flash_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           kv_len: jax.Array, *, n_splits: int
                           ) -> jax.Array:
    """q (B, Sq, H, D) with small Sq (decode); k/v (B, L, G, D); kv_len =
    number of valid cache entries (scalar).  Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    L, G = k.shape[1], k.shape[2]
    rep = H // G
    assert L % n_splits == 0, (L, n_splits)
    Ls = L // n_splits
    scale = 1.0 / math.sqrt(D)

    # (B, n_splits, Ls, G, D), split axis -> 'seq' logical axis (SP);
    # batch keeps its own sharding (constraining it to 'none' would gather
    # the whole cache over the batch axis — 2 GiB/layer/step at 405B).
    ks = constrain(k.reshape(B, n_splits, Ls, G, D),
                   "batch", "seq", "none", "none", "none")
    vs = constrain(v.reshape(B, n_splits, Ls, G, D),
                   "batch", "seq", "none", "none", "none")
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, G, rep, D)

    s = jnp.einsum("bqgrd,bnkgd->bngrqk", qf, ks.astype(jnp.float32))
    pos = (jnp.arange(n_splits)[:, None] * Ls
           + jnp.arange(Ls)[None, :])                     # (n, Ls)
    valid = pos[None] < jnp.asarray(kv_len).reshape(-1, 1, 1)
    s = jnp.where(valid[:, :, None, None, None, :], s, -1e30)

    m_loc = s.max(axis=-1)                                # (B,n,G,rep,Sq)
    p = jnp.exp(s - m_loc[..., None])
    l_loc = p.sum(axis=-1)
    acc_loc = jnp.einsum("bngrqk,bnkgd->bngrqd", p, vs.astype(jnp.float32))

    # combine across splits (the only cross-shard communication)
    m_glob = m_loc.max(axis=1, keepdims=True)
    corr = jnp.exp(m_loc - m_glob)
    l_glob = (l_loc * corr).sum(axis=1)
    acc = (acc_loc * corr[..., None]).sum(axis=1)         # (B,G,rep,Sq,D)
    out = acc / jnp.maximum(l_glob[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
    return out.astype(q.dtype)
