"""Shape-affinity request routing across serving replicas.

With fleet-global telemetry (PR 8) the coordinator publishes SMALL
per-replica plans — each replica specializes on one affinity class of the
global hot set ("A Few Fit Most" applied across processes).  That only
pays off if requests actually LAND on the replica whose plan covers their
shapes; this module is the front-end that makes it so.

``Router`` is the one interface: a set of :class:`Replica` handles (name
plus live plan/load probes) and ``route(shapes) -> Replica`` per pending
request.  Three policies:

``ShapeAffinityRouter``
    Scores every replica by :func:`plan_coverage` — the fraction of the
    request's (space, inputs) shapes the replica's installed
    :class:`~repro.tunedb.store.DispatchPlan` already resolves (the same
    ``shape_key`` probe the store-aware admission uses) — and assigns the
    request to the best-covering replica *within a load bound*: a replica
    more than ``max_imbalance`` requests above the least-loaded one is
    ineligible, so affinity can never pile every request onto one hot
    replica.  A request NO plan covers takes the no-starvation escape
    hatch: least-loaded replica, unconditionally — every request class is
    always served.  Decision outcomes:

    * ``affinity`` — the best-covering replica won outright;
    * ``balanced`` — the globally best-covering replica was excluded by
      the load bound and an eligible replica was taken instead;
    * ``escape``   — zero coverage everywhere; routed by load alone.

``RoundRobinRouter`` / ``RandomRouter``
    The baselines the E17 gate compares against (outcome ``baseline``).

Wired through ``ServeConfig(router=...)`` / ``launch.serve --router`` and
the ``tunedb fleet route`` CLI verb; decisions feed the
``tunedb_router_decisions_total{policy,outcome}`` metric family and the
``/status`` router section.
"""

from __future__ import annotations

import contextlib
import random
import threading
from typing import Callable, Dict, Iterable, List, Optional, Tuple, Union

from repro.tunedb.store import shape_key

# lazily bound trace module (False = unavailable); the per-route probe is
# one module-attribute read, so disabled tracing costs zero instrument
# calls on the routing path
_TRACE = None
_NULL_CTX = contextlib.nullcontext()

__all__ = [
    "ROUTER_POLICIES", "Replica", "Router", "RoundRobinRouter",
    "RandomRouter", "ShapeAffinityRouter", "make_router", "plan_coverage",
]

Shape = Tuple[str, Dict[str, int]]          # (space, inputs)


def plan_coverage(plan, shapes: Iterable[Shape]) -> float:
    """Fraction of ``(space, inputs)`` shapes ``plan`` already resolves.

    The same lock-free ``plan.lookup(space, shape_key(inputs))`` probe the
    store-aware admission scores with — a covered shape dispatches at
    zero resolution cost on that replica.  No plan or no shapes -> 0.0
    (nothing is known to be covered).
    """
    shapes = list(shapes)
    if plan is None or not shapes:
        return 0.0
    hits = 0
    for space, inputs in shapes:
        if plan.lookup(space, shape_key(inputs)) is not None:
            hits += 1
    return hits / len(shapes)


class Replica:
    """One routable replica: a name plus live plan and load probes.

    ``plan`` and ``load`` may be static values or zero-arg callables —
    an in-process engine hands in ``lambda: serving_state().plan`` and its
    active-slot counter; the CLI dry-run hands in plans pulled from the
    per-replica registries and a synthetic load of 0.
    """

    __slots__ = ("name", "_plan", "_load", "assigned")

    def __init__(self, name: str, *,
                 plan: Union[object, Callable[[], object], None] = None,
                 load: Union[float, Callable[[], float], None] = None):
        self.name = name
        self._plan = plan
        self._load = load
        self.assigned = 0               # router-side assignment counter

    def current_plan(self):
        return self._plan() if callable(self._plan) else self._plan

    def current_load(self) -> float:
        if callable(self._load):
            return float(self._load())
        if self._load is not None:
            return float(self._load)
        return float(self.assigned)     # default: what the router sent it

    def stats(self) -> Dict[str, object]:
        plan = self.current_plan()
        return {"name": self.name, "assigned": self.assigned,
                "load": self.current_load(),
                "plan_entries": (len(plan) if plan is not None else 0)}


class Router:
    """Policy-agnostic base: replica registry, accounting, metrics."""

    policy = "base"

    def __init__(self, replicas: Optional[Iterable[Replica]] = None):
        self._lock = threading.Lock()
        self.replicas: List[Replica] = list(replicas or [])
        self.decisions = 0
        self.outcomes: Dict[str, int] = {}

    def add_replica(self, name: str, *, plan=None, load=None) -> Replica:
        r = Replica(name, plan=plan, load=load)
        with self._lock:
            self.replicas.append(r)
        return r

    def route(self, shapes: Iterable[Shape] = ()) -> Replica:
        """Assign one pending request (its prefill/decode shapes) to a
        replica.  Every request gets a replica — policies may only bias
        the choice, never refuse it."""
        global _TRACE
        t = _TRACE
        if t is None:
            try:
                from repro.tunedb.obs import trace as t
            except Exception:
                t = False
            _TRACE = t
        tr = t._TRACER if t else None   # None: untraced, zero instruments
        with (tr.span("request.route", policy=self.policy)
              if tr is not None else _NULL_CTX) as sp:
            with self._lock:
                if not self.replicas:
                    raise RuntimeError("router has no replicas to route to")
                replica, outcome = self._pick(list(shapes))
                replica.assigned += 1
                self.decisions += 1
                self.outcomes[outcome] = self.outcomes.get(outcome, 0) + 1
            if sp is not None:
                sp.attrs["outcome"] = outcome
                sp.attrs["replica"] = replica.name
        self._count_decision(outcome)
        return replica

    def _pick(self, shapes: List[Shape]) -> Tuple[Replica, str]:
        raise NotImplementedError

    def _count_decision(self, outcome: str) -> None:
        try:
            from repro.tunedb.obs.metrics import get_registry
            get_registry().counter(
                "tunedb_router_decisions_total",
                "request routing decisions by policy and outcome").inc(
                    policy=self.policy, outcome=outcome)
        except Exception:               # metrics must never drop a request
            pass

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {"policy": self.policy, "decisions": self.decisions,
                    "outcomes": dict(self.outcomes),
                    "replicas": [r.stats() for r in self.replicas]}


class RoundRobinRouter(Router):
    """Baseline: cycle through replicas regardless of shape or load."""

    policy = "round_robin"

    def __init__(self, replicas: Optional[Iterable[Replica]] = None):
        super().__init__(replicas)
        self._next = 0

    def _pick(self, shapes: List[Shape]) -> Tuple[Replica, str]:
        r = self.replicas[self._next % len(self.replicas)]
        self._next += 1
        return r, "baseline"


class RandomRouter(Router):
    """Baseline: uniform random replica (seeded, reproducible)."""

    policy = "random"

    def __init__(self, replicas: Optional[Iterable[Replica]] = None, *,
                 seed: int = 0):
        super().__init__(replicas)
        self._rng = random.Random(seed)

    def _pick(self, shapes: List[Shape]) -> Tuple[Replica, str]:
        return self._rng.choice(self.replicas), "baseline"


class ShapeAffinityRouter(Router):
    """Route to the replica whose plan covers the request's shapes.

    ``max_imbalance`` is the load-balance bound: a replica whose current
    load exceeds the least-loaded replica's by more than this many
    requests is ineligible this decision, however good its coverage —
    affinity sharpens placement, it must not starve the rest of the fleet
    of work or melt one replica.  Ties on coverage break toward the
    less-loaded replica, then the registration order (deterministic).
    """

    policy = "affinity"

    def __init__(self, replicas: Optional[Iterable[Replica]] = None, *,
                 max_imbalance: float = 4.0):
        super().__init__(replicas)
        self.max_imbalance = float(max_imbalance)

    def _pick(self, shapes: List[Shape]) -> Tuple[Replica, str]:
        loads = [r.current_load() for r in self.replicas]
        floor = min(loads)
        coverage = [plan_coverage(r.current_plan(), shapes)
                    for r in self.replicas]
        eligible = [i for i, load in enumerate(loads)
                    if load - floor <= self.max_imbalance]
        best = max(eligible, key=lambda i: (coverage[i], -loads[i], -i))
        if coverage[best] <= 0.0:
            # no-starvation escape hatch: nobody covers this request
            # class, so place it purely by load — it is served NOW and its
            # shapes enter that replica's telemetry, which is what later
            # earns it a specialized plan
            idx = min(range(len(self.replicas)), key=lambda i: loads[i])
            return self.replicas[idx], "escape"
        if max(coverage) > coverage[best]:
            return self.replicas[best], "balanced"
        return self.replicas[best], "affinity"


ROUTER_POLICIES: Dict[str, type] = {
    "affinity": ShapeAffinityRouter,
    "round_robin": RoundRobinRouter,
    "random": RandomRouter,
}


def make_router(policy: str, **kwargs) -> Router:
    """Instantiate a router by policy name (the ``ServeConfig.router`` /
    ``--router`` / ``fleet route --policy`` values)."""
    try:
        cls = ROUTER_POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown router policy {policy!r}; "
            f"choose from {sorted(ROUTER_POLICIES)}") from None
    return cls(**kwargs)
