"""Version compatibility shims for the installed jax.

``shard_map`` moved twice across jax releases: it lives at
``jax.experimental.shard_map`` on 0.4.x, is a top-level ``jax.shard_map``
from 0.6, and its replication-check kwarg was renamed ``check_rep`` ->
``check_vma`` along the way.  Callers import :func:`shard_map` from here and
always use the modern ``check_vma=`` spelling; the shim translates for
whatever jax the container ships.
"""

from __future__ import annotations

import inspect

try:                                        # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map
except ImportError:                         # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_HAS_CHECK_VMA = "check_vma" in inspect.signature(_shard_map).parameters


def shard_map(f, *, check_vma=None, **kwargs):
    """jax.shard_map with the kwarg spelling of the installed jax."""
    if check_vma is not None:
        kwargs["check_vma" if _HAS_CHECK_VMA else "check_rep"] = check_vma
    return _shard_map(f, **kwargs)


_HAS_AXIS_TYPES = "axis_types" in inspect.signature(
    __import__("jax").make_mesh).parameters


def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kwargs):
    """jax.make_mesh; drops `axis_types` where the installed jax predates it
    (pre-AxisType meshes behave as Auto on every axis, which is what all
    call sites in this repo request)."""
    import jax

    if axis_types is not None and _HAS_AXIS_TYPES:
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def auto_axis_types(n: int):
    """(AxisType.Auto,) * n on jax that has AxisType, else None."""
    import jax

    at = getattr(jax.sharding, "AxisType", None)
    return None if at is None else (at.Auto,) * n
