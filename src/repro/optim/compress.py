"""int8 gradient compression with error feedback.

At multi-pod scale the cross-pod gradient all-reduce rides the slow DCI/ICI
links; quantizing gradients to int8 (per-tensor scale) quarters that traffic.
Error feedback (Seide et al.) accumulates the quantization residual locally
and re-adds it next step, preserving convergence.

The trainer applies this *around* the pod-axis reduction: grads are averaged
in-pod at full precision (fast links), compressed, all-reduced across pods,
decompressed.  Under jit the quantize/dequantize pair also teaches XLA that
the cross-pod collective payload is int8 (visible in the dry-run HLO).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Any, error: Any) -> Tuple[Any, Any, Any]:
    """(grads + error) -> (int8 tree, scale tree, new error tree)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = _quantize(corrected)
        new_e = corrected - _dequantize(q, s)
        return q, s, new_e
    flat, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    qs = [one(g, e) for g, e in zip(flat, flat_e)]
    unf = lambda i: jax.tree_util.tree_unflatten(treedef, [t[i] for t in qs])
    return unf(0), unf(1), unf(2)


def decompress_grads(q: Any, scales: Any) -> Any:
    return jax.tree_util.tree_map(_dequantize, q, scales)
