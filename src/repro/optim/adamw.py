"""AdamW with dtype-configurable optimizer states.

At 405B scale, fp32 (m, v) costs 3.2 TB; bf16 states with stochastic rounding
on the parameter update keep the dry-run memory budget inside v5e HBM
(DESIGN.md §7).  The update math always runs in fp32; only *storage* dtype is
reduced.  Pure-JAX (no optax dependency in this container).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32       # bf16 at 100B+ scale
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params: Any, cfg: AdamWConfig) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, cfg.state_dtype), params)
    zeros2 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, cfg.state_dtype), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros2)


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _stochastic_round(key: jax.Array, x: jax.Array, dtype) -> jax.Array:
    """Unbiased fp32 -> bf16 rounding: add uniform noise below the mantissa
    cut, then truncate.  Keeps bf16 params/states from stalling training."""
    if dtype == jnp.float32 or x.dtype != jnp.float32:
        return x.astype(dtype)
    # bf16 = top 16 bits of fp32: randomize the dropped 16 bits
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    noise = jax.random.randint(key, x.shape, 0, 1 << 16, jnp.uint32)
    return jax.lax.bitcast_convert_type(
        (bits + noise) & jnp.uint32(0xFFFF0000), jnp.float32).astype(dtype)


def adamw_update(params: Any, grads: Any, state: AdamWState,
                 cfg: AdamWConfig, *,
                 sr_key: Optional[jax.Array] = None
                 ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    """One AdamW step; returns (params, state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    keys = (jax.random.split(sr_key, len(flat_p)) if sr_key is not None
            else [None] * len(flat_p))

    new_p, new_m, new_v = [], [], []
    for p, g, m, v, k in zip(flat_p, flat_g, flat_m, flat_v, keys):
        gf = g.astype(jnp.float32) * scale
        mf = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * gf
        vf = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * gf * gf
        upd = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        if p.ndim >= 2:                      # decay matrices only
            upd = upd + cfg.weight_decay * pf
        pf = pf - lr * upd
        if k is not None and p.dtype != jnp.float32:
            new_p.append(_stochastic_round(k, pf, p.dtype))
        else:
            new_p.append(pf.astype(p.dtype))
        new_m.append(mf.astype(cfg.state_dtype))
        new_v.append(vf.astype(cfg.state_dtype))

    return (jax.tree_util.tree_unflatten(treedef, new_p),
            AdamWState(step=step,
                       m=jax.tree_util.tree_unflatten(treedef, new_m),
                       v=jax.tree_util.tree_unflatten(treedef, new_v)),
            {"grad_norm": gnorm, "lr": lr})
