"""Tuning sessions: mine hot shapes, tune them on a worker pool, commit.

A :class:`TuningSession` closes the telemetry -> search -> store loop (the
MITuna-style "session of jobs" organization): take the top-K shapes traffic
actually hit, run the input-aware tuner's runtime search for each on a small
worker pool, and append one :class:`TuneRecord` per shape to the store.  A
progress file makes long sessions resumable — re-running the same session
skips shapes already committed (or already marked done in the progress
file), so a killed fleet picks up where it stopped.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
from concurrent.futures import ThreadPoolExecutor, as_completed
from typing import Dict, List, Mapping, Optional, Tuple

from .store import (SAMPLE_SOURCE, RecordStore, TuneRecord, input_key,
                    normalize_inputs)
from .telemetry import ShapeTelemetry


def backend_fingerprint(backend) -> str:
    """Stable id of the measuring backend, recorded with every result."""
    name = type(backend).__name__
    attrs = []
    for field in ("noise", "seed", "warmup", "iters", "rtol"):
        v = getattr(backend, field, None)
        if v is not None and not callable(v):
            attrs.append(f"{field}={v}")
    return "/".join([name] + attrs) if attrs else name


def record_from_search(space: str, inputs: Mapping[str, int], result,
                       backend, source: str) -> TuneRecord:
    """Build the canonical TuneRecord for one SearchResult.

    The single place that decides measured-vs-predicted tflops, probes the
    backend for latency, and stamps the fingerprint — shared by the session
    runner and InputAwareTuner.best_config so their records never drift.
    """
    tflops = (result.measured_tflops if result.measured_tflops is not None
              else result.predicted_tflops)
    config = dict(result.best)
    latency = None
    time_us = getattr(backend, "time_us", None)
    if callable(time_us):
        latency = float(time_us(space, config, inputs))
    return TuneRecord(
        space=space, inputs=dict(inputs), config=config,
        tflops=float(tflops), latency_us=latency,
        backend=backend_fingerprint(backend), source=source)


@dataclasses.dataclass(frozen=True)
class TuneJob:
    """One unit of session work: tune one input shape."""

    space: str
    inputs: Dict[str, int]
    count: int                          # telemetry frequency (priority)

    @property
    def key(self) -> str:
        return input_key(self.space, self.inputs)


@dataclasses.dataclass
class SessionReport:
    space: str
    jobs: int
    tuned: int
    skipped: int
    failed: int
    wall_s: float
    records: List[TuneRecord] = dataclasses.field(default_factory=list)
    errors: List[str] = dataclasses.field(default_factory=list)


class TuningSession:
    """Drive the tuner over the hottest telemetry shapes into a store."""

    def __init__(self, tuner, store: RecordStore,
                 telemetry: Optional[ShapeTelemetry] = None, *,
                 top_k_shapes: int = 8, workers: int = 4,
                 remeasure: bool = True, skip_existing: bool = True,
                 collect_samples: bool = True,
                 progress_path: Optional[os.PathLike] = None,
                 source: str = "session"):
        self.tuner = tuner
        self.store = store
        self.telemetry = telemetry
        self.top_k_shapes = top_k_shapes
        self.workers = max(1, workers)
        self.remeasure = remeasure
        self.skip_existing = skip_existing
        # what the committed records' `source` field says; the controller
        # stamps "retune" so drift-triggered records are auditable in the log
        self.source = source
        # commit every top-k measurement (not only the winner) to the store
        # as source="sample" training data for the performance model
        self.collect_samples = collect_samples
        self.progress_path = (pathlib.Path(progress_path)
                              if progress_path else None)
        self._done: set = self._load_progress()

    # -- resumability ---------------------------------------------------------
    def _load_progress(self) -> set:
        if self.progress_path is None or not self.progress_path.exists():
            return set()
        try:
            return set(json.loads(self.progress_path.read_text())["done"])
        except (ValueError, KeyError):
            return set()

    def _save_progress(self) -> None:
        if self.progress_path is None:
            return
        self.progress_path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.progress_path.with_name(self.progress_path.name + ".tmp")
        tmp.write_text(json.dumps({"space": self.tuner.space.name,
                                   "done": sorted(self._done)}))
        os.replace(tmp, self.progress_path)

    # -- planning -------------------------------------------------------------
    def plan(self, shapes: Optional[List[Mapping[str, int]]] = None
             ) -> Tuple[List[TuneJob], int]:
        """Build the job list; returns (jobs, n_skipped).

        `shapes` overrides telemetry mining (explicit --shape CLI jobs).
        """
        space = self.tuner.space.name
        if shapes is not None:
            cand = [(normalize_inputs(s), 0) for s in shapes]
        elif self.telemetry is not None:
            cand = self.telemetry.hot_shapes(space, self.top_k_shapes)
        else:
            raise ValueError("need telemetry or explicit shapes to plan")
        # skip_existing is fingerprint-scoped: a shape tuned on another
        # backend still needs THIS session's backend to measure it, or a
        # serving process pinned to this fingerprint would never get a record
        fp = backend_fingerprint(self.tuner.backend)
        jobs, skipped = [], 0
        for inputs, count in cand:
            key = input_key(space, inputs)
            if key in self._done or (self.skip_existing
                                     and self.store.contains(space, inputs,
                                                             backend=fp)):
                skipped += 1
                continue
            jobs.append(TuneJob(space=space, inputs=inputs, count=count))
        return jobs, skipped

    # -- execution ------------------------------------------------------------
    def _run_job(self, job: TuneJob) -> Tuple[TuneRecord, List[TuneRecord]]:
        result = self.tuner.search(job.inputs, remeasure=self.remeasure)
        rec = record_from_search(job.space, job.inputs, result,
                                 self.tuner.backend, source=self.source)
        samples: List[TuneRecord] = []
        if self.collect_samples and result.measured:
            # the losing top-k measurements are still labeled data points —
            # exactly what the performance model trains on (model.harvest)
            samples = [
                TuneRecord(space=job.space, inputs=dict(job.inputs),
                           config=dict(cfg), tflops=float(tflops),
                           backend=rec.backend, source=SAMPLE_SOURCE)
                for cfg, tflops in result.measured if cfg != result.best]
        return rec, samples

    def run(self, shapes: Optional[List[Mapping[str, int]]] = None,
            verbose: bool = False) -> SessionReport:
        t0 = time.time()
        jobs, skipped = self.plan(shapes)
        report = SessionReport(space=self.tuner.space.name, jobs=len(jobs),
                               tuned=0, skipped=skipped, failed=0, wall_s=0.0)
        if jobs:
            # commit each result the moment it lands (as_completed, not map):
            # a crash mid-session must not discard jobs that already finished
            with ThreadPoolExecutor(max_workers=self.workers) as pool:
                futures = {pool.submit(self._guarded, j): j for j in jobs}
                for fut in as_completed(futures):
                    job = futures[fut]
                    out, err = fut.result()
                    if err is not None:
                        report.failed += 1
                        report.errors.append(f"{job.inputs}: {err}")
                        continue
                    rec, samples = out
                    self.store.add(rec)
                    for sample in samples:
                        self.store.add(sample)
                    self._done.add(job.key)
                    self._save_progress()
                    report.tuned += 1
                    report.records.append(rec)
                    if verbose:
                        print(f"[session:{job.space}] {job.inputs} -> "
                              f"{rec.tflops:.1f} TFLOPS (hits={job.count})")
        report.wall_s = time.time() - t0
        return report

    def _guarded(self, job: TuneJob):
        try:
            return self._run_job(job), None
        except Exception as e:       # noqa: BLE001 — job isolation is the point
            return None, f"{type(e).__name__}: {e}"
