"""Generation-diff regression sentry: refuse to promote slower records.

The tuning loop is optimistic by construction — a retune or a fleet merge
*replaces* the serving record for a ``(backend, space, shape)`` key with
whatever newer measurement arrives, and ``install_serving`` freezes the
result into the next :class:`~repro.tunedb.store.DispatchPlan`.  Nothing in
PRs 1–5 asked whether the replacement was actually *faster*.  One noisy
worker or a drifted simulator is enough to regress a hot shape and have the
plan lock the regression in for a whole generation.

:class:`RegressionSentry` closes that hole at the three promotion edges:

* ``tunedb diff <old> <new>`` — offline, record-by-record comparison of two
  store files (or two ``/plan`` snapshots); exits non-zero on regressions.
* ``install_serving(sentry=...)`` — the swap gate.  For a *new* store the
  sentry diffs it against the currently-serving store; for an in-place
  retune (same store object) it replays the store's supersession log since
  the serving plan's pinned ``store_version``.  A regressed generation is
  warned about, counted in the metrics registry, and **refused**: the
  previous :class:`~repro.tunedb.store.ServingState` stays installed and
  the caller sees an unchanged generation.
* ``Coordinator(sentry_margin=...)`` — the merge gate: shard records that
  would supersede a faster serving record are skipped (and counted) before
  they ever reach the parent store.

A record only counts as a regression when the newer record is slower than
the one it replaces by more than ``noise_margin`` (default 10%) — repeated
measurements of the same config jitter, and a sentry that cries wolf on
noise would just get disabled.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

__all__ = [
    "DEFAULT_NOISE_MARGIN", "Regression", "SentryReport", "RegressionSentry",
    "last_report",
]

DEFAULT_NOISE_MARGIN = 0.10


@dataclasses.dataclass(frozen=True)
class Regression:
    """One key whose replacement record is slower beyond the margin."""

    space: str
    backend: str
    inputs: Dict[str, int]
    old_tflops: float
    new_tflops: float
    old_config: Dict[str, int]
    new_config: Dict[str, int]

    @property
    def drop(self) -> float:
        """Fractional slowdown: 0.25 means the new record is 25% slower."""
        if self.old_tflops <= 0:
            return 0.0
        return 1.0 - self.new_tflops / self.old_tflops

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["drop"] = self.drop
        return d


@dataclasses.dataclass
class SentryReport:
    """Outcome of one sentry pass over a pair of generations."""

    checked: int = 0
    improved: int = 0
    unchanged: int = 0
    added: int = 0
    removed: int = 0
    noise_margin: float = DEFAULT_NOISE_MARGIN
    regressions: List[Regression] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.regressions

    def to_dict(self) -> Dict[str, object]:
        return {
            "checked": self.checked,
            "improved": self.improved,
            "unchanged": self.unchanged,
            "added": self.added,
            "removed": self.removed,
            "noise_margin": self.noise_margin,
            "ok": self.ok,
            "regressions": [r.to_dict() for r in self.regressions],
        }


_LAST_REPORT: Optional[SentryReport] = None


def last_report() -> Optional[SentryReport]:
    """The most recent report produced by an install/merge gate — the
    refused ``install_serving`` returns the old state, so callers that
    need the *why* read it here."""
    return _LAST_REPORT


class RegressionSentry:
    """Compares record generations and gates promotions.

    ``noise_margin`` is the fractional slowdown tolerated before a
    replacement is flagged: ``new < old * (1 - noise_margin)`` regresses.
    """

    def __init__(self, noise_margin: float = DEFAULT_NOISE_MARGIN) -> None:
        if not 0.0 <= noise_margin < 1.0:
            raise ValueError(f"noise_margin must be in [0, 1), "
                             f"got {noise_margin}")
        self.noise_margin = float(noise_margin)

    # -- record-level checks ------------------------------------------------
    def regresses(self, old_tflops: float, new_tflops: float) -> bool:
        return new_tflops < old_tflops * (1.0 - self.noise_margin)

    def check_record(self, old, new) -> Optional[Regression]:
        """``old``/``new`` are :class:`~repro.tunedb.store.TuneRecord`-likes
        for the same ``(backend, space, shape)`` key."""
        if not self.regresses(old.tflops, new.tflops):
            return None
        return Regression(
            space=new.space, backend=new.backend, inputs=dict(new.inputs),
            old_tflops=old.tflops, new_tflops=new.tflops,
            old_config=dict(old.config), new_config=dict(new.config))

    # -- store-level diff ---------------------------------------------------
    def diff_stores(self, old_store, new_store) -> SentryReport:
        """Record-by-record diff of two stores on the shared serving keys.

        Only *serving* records participate (training samples are never
        promoted); keys present on one side only count as added/removed,
        not regressions — the sentry guards replacements, not coverage.
        """
        report = SentryReport(noise_margin=self.noise_margin)
        old_index = _serving_index(old_store)
        new_index = _serving_index(new_store)
        for key, new_rec in new_index.items():
            old_rec = old_index.get(key)
            if old_rec is None:
                report.added += 1
                continue
            report.checked += 1
            reg = self.check_record(old_rec, new_rec)
            if reg is not None:
                report.regressions.append(reg)
            elif new_rec.tflops > old_rec.tflops:
                report.improved += 1
            else:
                report.unchanged += 1
        report.removed = sum(1 for key in old_index if key not in new_index)
        return report

    def check_supersessions(self, store, since_version: int) -> SentryReport:
        """Replay the store's supersession log after ``since_version``.

        This is the in-place path: a retune appends into the *serving*
        store, so there is no second store to diff — but the store records
        every index replacement (see ``RecordStore._admit``), and any
        replacement since the serving plan was compiled is exactly the set
        of records the next ``install_serving`` would freeze in.
        """
        report = SentryReport(noise_margin=self.noise_margin)
        seen: Dict[Tuple, Regression] = {}
        for sup in getattr(store, "supersessions", ()):
            if sup.version <= since_version:
                continue
            report.checked += 1
            reg = self.check_record(sup.old, sup.new)
            key = (sup.new.backend, sup.new.key)
            if reg is not None:
                seen[key] = reg
            else:
                # a later good replacement clears an earlier regression
                seen.pop(key, None)
                if sup.new.tflops > sup.old.tflops:
                    report.improved += 1
                else:
                    report.unchanged += 1
        report.regressions = list(seen.values())
        return report

    # -- promotion gates ----------------------------------------------------
    def check_install(self, cur_state, new_store) -> Optional[SentryReport]:
        """Gate for ``install_serving``: returns a report when there is
        something to compare, ``None`` when the sentry has no baseline."""
        global _LAST_REPORT
        if new_store is None:
            return None
        if cur_state.store is None:
            return None
        if new_store is cur_state.store:
            plan = cur_state.plan
            if plan is None:
                return None
            report = self.check_supersessions(
                new_store, since_version=plan.store_version)
        else:
            report = self.diff_stores(cur_state.store, new_store)
        _LAST_REPORT = report
        return report

    def blocks_install(self, cur_state, new_store) -> bool:
        """True when the swap must be refused.  Warns and publishes
        ``tunedb_sentry_*`` metrics as a side effect."""
        report = self.check_install(cur_state, new_store)
        if report is None or report.ok:
            return False
        import warnings

        from .metrics import get_registry

        reg = get_registry()
        reg.counter("tunedb_sentry_regressions_total",
                    "records flagged as regressed by the sentry").inc(
                        len(report.regressions), where="install")
        reg.counter("tunedb_sentry_blocked_total",
                    "generation promotions refused by the sentry").inc(
                        where="install")
        worst = max(report.regressions, key=lambda r: r.drop)
        warnings.warn(
            f"regression sentry refused serving swap: "
            f"{len(report.regressions)} regressed record(s) beyond "
            f"{self.noise_margin:.0%} noise margin (worst: {worst.space} "
            f"{worst.inputs} {worst.old_tflops:.1f}->{worst.new_tflops:.1f} "
            f"TFLOP/s, -{worst.drop:.0%}); keeping previous generation",
            RuntimeWarning, stacklevel=3)
        return True

    # -- plan-snapshot diff (coverage-level) --------------------------------
    def diff_plans(self, old_plan: Dict, new_plan: Dict) -> SentryReport:
        """Structural diff of two ``/plan`` JSON snapshots.

        Plan entries carry configs but no measured TFLOP/s, so the sentry
        checks *coverage*: a shape that was planned in ``old`` but is gone
        from ``new`` (it will fall back to slower tiers) is flagged as a
        regression with zeroed perf fields; config changes count as
        checked/unchanged.
        """
        report = SentryReport(noise_margin=self.noise_margin)
        old_entries = {_plan_key(e): e for e in old_plan.get("entries", [])}
        new_entries = {_plan_key(e): e for e in new_plan.get("entries", [])}
        for key, entry in old_entries.items():
            new_entry = new_entries.get(key)
            if new_entry is None:
                report.removed += 1
                report.regressions.append(Regression(
                    space=entry.get("space", "?"),
                    backend=old_plan.get("fingerprint", "?"),
                    inputs=dict(entry.get("inputs", {})),
                    old_tflops=0.0, new_tflops=0.0,
                    old_config=dict(entry.get("config", {})),
                    new_config={}))
                continue
            report.checked += 1
            if new_entry.get("config") == entry.get("config"):
                report.unchanged += 1
            else:
                report.improved += 1    # changed, perf unknowable offline
        report.added = sum(1 for k in new_entries if k not in old_entries)
        return report


def _serving_index(store) -> Dict[Tuple, object]:
    """``(backend, space, shape_key) -> latest serving record`` for a
    :class:`RecordStore` — mirrors the store's own ``_admit`` policy."""
    from ..store import SAMPLE_SOURCE

    index: Dict[Tuple, object] = {}
    for rec in store.records():         # latest-first: first seen wins,
        if rec.source == SAMPLE_SOURCE:  # matching _admit's newest-wins
            continue
        key = (rec.backend, rec.key)
        if key not in index:
            index[key] = rec
    return index


def _plan_key(entry: Dict) -> Tuple:
    return (entry.get("space"),
            tuple(sorted((entry.get("inputs") or {}).items())))
