"""End-to-end request tracing: spans, sampling, Chrome trace-event export.

The metrics layer (PR 6) answers *how much* — aggregate counters and
quantiles.  This module answers *where did this request's time go*: a
span-based tracer that follows one request through router decision →
admission/prefill → decode ticks → dispatch-tier resolution → the retune
submit→swap window → fleet tuning jobs → plan-follower installs, and lays
the tuner's real wall-clock kernel measurements on the same clock.  The
export is Chrome trace-event JSON, loadable directly in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``.

Design rules, same priority order as :mod:`.metrics`:

1. **Disabled costs zero instrument calls.**  The module global
   :data:`_TRACER` is ``None`` until :func:`enable_tracing` runs; every
   instrumented call site reads that one attribute and, finding ``None``,
   executes the byte-identical untraced path.  E18 (bench_trace.py)
   monkeypatch-proves no ``Tracer`` method runs when tracing is off.

2. **Sampling is decided once, at the trace root.**  ``sample=0.01``
   keeps every 100th root (deterministic stride, so benches and tests are
   reproducible); an unsampled root costs one counter bump and pushes no
   context, so every child ``span()`` under it is a no-op returning the
   shared :data:`_NULL_SPAN`.  A root opened with an **explicit**
   ``trace_id`` (a fleet worker adopting the id carried in the job JSON)
   is always kept — the sampling decision was made upstream by whoever
   minted the id.

3. **Finished spans ride the telemetry ``_Ring``.**  Completing a span
   appends to the calling thread's lock-free SPSC ring (owner writes
   ``head`` + slots, the drainer owns ``tail`` — see
   :class:`repro.tunedb.telemetry._Ring`); :meth:`Tracer.drain` folds
   rings into a bounded deque at export/scrape time.  A full ring falls
   back to the locked store — spans degrade to locked, never dropped;
   only the retention cap (``max_spans``) evicts, counted in
   ``overflow``.

Cross-process linking: trace ids are plain strings.  The controller
stamps the active id into ``FleetJob.trace_id``; a worker opens its
tuning-session root with that id and dumps finished spans to
``<fleet>/traces/<worker>.jsonl`` (:meth:`Tracer.export_jsonl`), which
:func:`collect_fleet_spans` merges back — a torn/partial file or line is
skipped, never raised, because a crashed worker must not take down the
exporter.
"""

from __future__ import annotations

import json
import os
import pathlib
import threading
import time
import uuid
from collections import deque
from typing import Deque, Dict, Iterable, List, Optional, Tuple

from ..telemetry import _Ring

__all__ = [
    "Span", "Tracer", "chrome_trace", "collect_fleet_spans",
    "enable_tracing", "get_tracer", "load_span_file", "reset_tracing",
    "summarize_spans",
]

TRACE_SCHEMA_VERSION = 1
SPAN_RING_SIZE = 2048       # finished spans buffered per writer thread
MAX_SPANS = 20000           # retained finished spans (process-wide cap)
FLEET_TRACE_DIR = "traces"  # <fleet>/traces/<worker>.jsonl span dumps

# Span-name taxonomy (docs/OBSERVABILITY.md documents the tree):
#   request.route     router decision            engine.admit      admission
#   engine.prefill    prefill compile+run        engine.tick       decode tick
#   dispatch.resolve  tier resolution            retune.epoch      submit->swap
#   fleet.job         worker tuning session      fleet.merge       coordinator
#   plan.install      follower install attempt   measure.*         wall-clock /
#                                                                  sim measure
SPAN_DISPATCH = "dispatch.resolve"


def new_trace_id() -> str:
    """Mint a trace id.  Opening a root with an explicit id bypasses
    sampling — used for spans that must always be kept (measurements,
    adopted fleet-job traces)."""
    return uuid.uuid4().hex[:16]


class Span:
    """One timed operation.  ``t0``/``dur`` are ``time.perf_counter``
    seconds — every span in a process shares that clock, which is the
    whole point of putting serving ticks and tuner measurements in one
    Perfetto view."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "tid",
                 "t0", "dur", "attrs")

    def __init__(self, name: str, trace_id: str, span_id: str,
                 parent_id: str = "", tid: int = 0) -> None:
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.tid = tid or threading.get_ident()
        self.t0 = 0.0
        self.dur = 0.0
        self.attrs: Dict[str, object] = {}

    def set(self, **attrs: object) -> "Span":
        self.attrs.update(attrs)
        return self

    def to_json(self) -> Dict[str, object]:
        return {"name": self.name, "trace_id": self.trace_id,
                "span_id": self.span_id, "parent_id": self.parent_id,
                "tid": self.tid, "t0": self.t0, "dur": self.dur,
                "attrs": dict(self.attrs)}

    @classmethod
    def from_json(cls, d: Dict) -> "Span":
        sp = cls(str(d["name"]), str(d["trace_id"]), str(d["span_id"]),
                 str(d.get("parent_id", "")), int(d.get("tid", 0)))
        sp.t0 = float(d["t0"])
        sp.dur = float(d["dur"])
        attrs = d.get("attrs") or {}
        if not isinstance(attrs, dict):
            raise ValueError("span attrs must be a dict")
        sp.attrs = attrs
        return sp


class _NullSpan:
    """Shared reusable no-op context manager: what ``span()`` returns when
    there is no sampled trace on the thread.  One module-level instance —
    the unsampled path allocates nothing."""

    __slots__ = ()

    def __enter__(self) -> None:
        return None

    def __exit__(self, *exc: object) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _SpanCtx:
    """Context manager that pushes a live span on the thread stack,
    stamps ``t0`` on enter and ``dur`` on exit, then hands the finished
    span to the tracer's ring."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span) -> None:
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        self._tracer._push(self._span)
        self._span.t0 = time.perf_counter()
        return self._span

    def __exit__(self, et, ev, tb) -> bool:
        sp = self._span
        sp.dur = time.perf_counter() - sp.t0
        if et is not None:
            sp.attrs.setdefault("error", et.__name__)
        self._tracer._finish(sp)
        return False


class Tracer:
    """Process-wide span recorder with stride sampling and ring buffers."""

    def __init__(self, sample: float = 1.0,
                 max_spans: int = MAX_SPANS) -> None:
        self._lock = threading.Lock()
        self._drain_lock = threading.Lock()     # nests drain -> lock
        self._spans: Deque[Span] = deque(maxlen=max_spans)
        self.max_spans = max_spans
        self.sampled = 0        # roots kept
        self.dropped = 0        # roots skipped by sampling
        self.overflow = 0       # finished spans evicted by the cap
        self._roots = 0         # stride counter
        self._next_id = 0
        self._tls = threading.local()
        self._rings: List[Tuple[object, _Ring]] = []
        self.sample = 1.0
        self._stride = 1
        self.set_sample(sample)

    # -- sampling ---------------------------------------------------------
    def set_sample(self, sample: float) -> None:
        sample = min(max(float(sample), 0.0), 1.0)
        self.sample = sample
        self._stride = int(round(1.0 / sample)) if sample > 0 else 0

    # -- thread context ---------------------------------------------------
    def current_trace_id(self) -> Optional[str]:
        stack = getattr(self._tls, "stack", None)
        return stack[-1].trace_id if stack else None

    def _push(self, span: Span) -> None:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        stack.append(span)

    def _new_span_id(self) -> str:
        with self._lock:
            self._next_id += 1
            return f"s{self._next_id:x}"

    # -- span lifecycle ---------------------------------------------------
    def root(self, name: str, trace_id: Optional[str] = None,
             **attrs: object):
        """Open a new trace.  ``trace_id=None`` mints an id and applies
        the sampling stride; an explicit id adopts an upstream-sampled
        trace and is always kept."""
        if trace_id is None:
            with self._lock:
                self._roots += 1
                keep = self._stride > 0 and self._roots % self._stride == 0
                if keep:
                    self.sampled += 1
                else:
                    self.dropped += 1
            if not keep:
                return _NULL_SPAN
            trace_id = uuid.uuid4().hex[:16]
        else:
            with self._lock:
                self.sampled += 1
        sp = Span(name, trace_id, self._new_span_id())
        sp.attrs.update(attrs)
        return _SpanCtx(self, sp)

    def span(self, name: str, **attrs: object):
        """Child span under the thread's current trace; no-op (shared
        :data:`_NULL_SPAN`) when no sampled trace is open here."""
        stack = getattr(self._tls, "stack", None)
        if not stack:
            return _NULL_SPAN
        parent = stack[-1]
        sp = Span(name, parent.trace_id, self._new_span_id(),
                  parent_id=parent.span_id)
        sp.attrs.update(attrs)
        return _SpanCtx(self, sp)

    def begin(self, name: str, trace_id: Optional[str] = None,
              parent_id: str = "", **attrs: object) -> Optional[Span]:
        """Start a *detached* span — finished later (possibly from another
        thread) with :meth:`end`.  Used for windows that outlive the
        opening frame, like the retune submit→swap window.  Does not touch
        the thread context.  Returns ``None`` when sampling drops it."""
        if trace_id is None:
            with self._lock:
                self._roots += 1
                keep = self._stride > 0 and self._roots % self._stride == 0
                if keep:
                    self.sampled += 1
                else:
                    self.dropped += 1
            if not keep:
                return None
            trace_id = uuid.uuid4().hex[:16]
        sp = Span(name, trace_id, self._new_span_id(), parent_id=parent_id)
        sp.attrs.update(attrs)
        sp.t0 = time.perf_counter()
        return sp

    def end(self, span: Optional[Span], **attrs: object) -> None:
        """Finish a detached span from :meth:`begin` (None-safe).  The
        finisher may be any thread, so this takes the locked store path
        rather than a ring — detached windows are rare by construction."""
        if span is None:
            return
        span.dur = time.perf_counter() - span.t0
        span.attrs.update(attrs)
        with self._lock:
            self._store_locked(span)

    def _finish(self, span: Span) -> None:
        """Owner-thread completion: pop the context stack, publish the
        finished span to this thread's lock-free ring."""
        stack = getattr(self._tls, "stack", None)
        if stack and stack[-1] is span:
            stack.pop()
        elif stack:                                 # tolerate misnesting
            try:
                stack.remove(span)
            except ValueError:
                pass
        ring = getattr(self._tls, "ring", None)
        if ring is None:
            import weakref
            ring = self._tls.ring = _Ring(SPAN_RING_SIZE)
            with self._lock:
                self._rings.append(
                    (weakref.ref(threading.current_thread()), ring))
        if ring.head - ring.tail >= len(ring.buf):  # drain-starved
            with self._lock:
                self._store_locked(span)
            return
        ring.buf[ring.head % len(ring.buf)] = span
        ring.head += 1

    def _store_locked(self, span: Span) -> None:
        if len(self._spans) >= self.max_spans:
            self.overflow += 1
        self._spans.append(span)

    # -- draining / reading ----------------------------------------------
    def drain(self) -> int:
        """Fold every thread's pending ring into the retained deque;
        prune rings whose owner thread died.  Returns spans folded."""
        drained = 0
        with self._drain_lock:
            with self._lock:
                rings = list(self._rings)
            batch: List[Span] = []
            for _ref, ring in rings:
                head = ring.head                    # snapshot the publish
                size = len(ring.buf)
                while ring.tail < head:
                    batch.append(ring.buf[ring.tail % size])
                    ring.tail += 1
            with self._lock:
                for sp in batch:
                    self._store_locked(sp)
                self._rings = [(r, ring) for r, ring in self._rings
                               if r() is not None and r().is_alive()
                               or ring.head > ring.tail]
            drained = len(batch)
        return drained

    def buffered(self) -> int:
        """Spans sitting in per-thread rings, not yet drained."""
        with self._lock:
            rings = list(self._rings)
        return sum(max(0, ring.head - ring.tail) for _ref, ring in rings)

    def spans(self) -> List[Span]:
        self.drain()
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        self.drain()
        with self._lock:
            self._spans.clear()

    # -- reporting --------------------------------------------------------
    def tier_latency(self) -> Dict[str, Dict[str, float]]:
        """Per-tier dispatch resolution latency attribution, from the
        retained ``dispatch.resolve`` spans (sampled traffic only)."""
        out: Dict[str, Dict[str, float]] = {}
        for sp in self.spans():
            if sp.name != SPAN_DISPATCH:
                continue
            tier = str(sp.attrs.get("tier", "unknown"))
            ent = out.setdefault(tier, {"count": 0, "total_us": 0.0})
            ent["count"] += 1
            ent["total_us"] += sp.dur * 1e6
        for ent in out.values():
            ent["mean_us"] = (ent["total_us"] / ent["count"]
                              if ent["count"] else 0.0)
        return out

    def stats(self) -> Dict[str, object]:
        """The ``trace`` section of ``status_snapshot()``."""
        buffered = self.buffered()
        with self._lock:
            retained = len(self._spans)
            sampled, dropped = self.sampled, self.dropped
            overflow = self.overflow
        return {"enabled": True, "sample": self.sample,
                "sampled": sampled, "dropped": dropped,
                "spans": retained, "buffered": buffered,
                "overflow": overflow, "max_spans": self.max_spans,
                "tiers": self.tier_latency()}

    # -- export -----------------------------------------------------------
    def export(self, path) -> int:
        """Write retained spans as Chrome trace-event JSON (atomic
        tmp+rename).  Returns the event count."""
        spans = self.spans()
        doc = chrome_trace(spans)
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(path.suffix + f".tmp{os.getpid()}")
        tmp.write_text(json.dumps(doc))
        tmp.replace(path)
        return len(spans)

    def export_jsonl(self, path) -> int:
        """Append retained spans as one-JSON-per-line records (the fleet
        bus dump format), then drop them from retention so repeated dumps
        don't duplicate.  A reader tolerates a torn final line."""
        spans = self.spans()
        if not spans:
            return 0
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        buf = "".join(json.dumps(sp.to_json()) + "\n" for sp in spans)
        from .. import chaos               # obs is imported by tunedb: lazy
        io = chaos._IO
        with open(path, "a") as f:
            if io is None:
                f.write(buf)
            else:                          # torn dump = torn final line,
                io.file_write(f, buf, "trace.export")  # readers tolerate it

        with self._lock:
            self._spans.clear()
        return len(spans)


# ---------------------------------------------------------------------------
# Chrome trace-event assembly + torn-tolerant loading

def chrome_trace(spans: Iterable[Span], pid: Optional[int] = None) -> Dict:
    """Spans → the Chrome trace-event JSON object Perfetto loads.

    Every span becomes one complete ("ph": "X") event; trace/span/parent
    ids ride in ``args`` so linked spans stay linked across process
    merges."""
    events = []
    for sp in spans:
        events.append({
            "name": sp.name, "cat": "tunedb", "ph": "X",
            "ts": sp.t0 * 1e6, "dur": max(sp.dur, 0.0) * 1e6,
            "pid": int(pid if pid is not None else os.getpid()),
            "tid": int(sp.tid),
            "args": {"trace_id": sp.trace_id, "span_id": sp.span_id,
                     "parent_id": sp.parent_id, **sp.attrs},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"schema": TRACE_SCHEMA_VERSION}}


def _span_from_event(ev: Dict) -> Span:
    args = ev.get("args") or {}
    sp = Span(str(ev["name"]), str(args.get("trace_id", "")),
              str(args.get("span_id", "")),
              str(args.get("parent_id", "")), int(ev.get("tid", 0)))
    sp.t0 = float(ev["ts"]) / 1e6
    sp.dur = float(ev.get("dur", 0.0)) / 1e6
    sp.attrs = {k: v for k, v in args.items()
                if k not in ("trace_id", "span_id", "parent_id")}
    return sp


def load_span_file(path) -> List[Span]:
    """Read spans from a ``.jsonl`` dump or a Chrome trace JSON file.

    Torn, partial, or junk content — a worker died mid-write, a file is
    mid-rename — is SKIPPED, never raised: a bad line drops that line, an
    unparseable whole-file document drops that file.  The fleet exporter
    must survive any bytes the bus can contain."""
    path = pathlib.Path(path)
    try:
        text = path.read_text()
    except OSError:
        return []
    spans: List[Span] = []
    # Chrome trace document?  Both formats open with "{", so decide by
    # whether the WHOLE text parses to a dict carrying traceEvents — a
    # multi-line JSONL dump fails that parse and falls through below.
    try:
        doc = json.loads(text)
    except ValueError:
        doc = None
    if isinstance(doc, dict) and "traceEvents" in doc:
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            return []
        for ev in events:
            try:
                spans.append(_span_from_event(ev))
            except (KeyError, TypeError, ValueError):
                continue                            # bad event: skip it
        return spans
    for line in text.splitlines():                  # span JSONL dump
        line = line.strip()
        if not line:
            continue
        try:
            spans.append(Span.from_json(json.loads(line)))
        except (KeyError, TypeError, ValueError):
            continue                                # torn line: skip it
    return spans


def collect_fleet_spans(fleet_dir) -> List[Span]:
    """Merge every worker span dump under ``<fleet>/traces/`` (plus any
    Chrome exports dropped there), skipping unreadable files."""
    root = pathlib.Path(fleet_dir) / FLEET_TRACE_DIR
    spans: List[Span] = []
    if not root.is_dir():
        return spans
    for p in sorted(root.iterdir()):
        if p.suffix in (".jsonl", ".json"):
            spans.extend(load_span_file(p))
    return spans


def summarize_spans(spans: Iterable[Span]) -> Dict[str, object]:
    """Per-name counts/latencies + per-tier dispatch attribution — the
    ``tunedb trace summary`` payload."""
    names: Dict[str, Dict[str, float]] = {}
    tiers: Dict[str, Dict[str, float]] = {}
    traces = set()
    n = 0
    for sp in spans:
        n += 1
        traces.add(sp.trace_id)
        ent = names.setdefault(sp.name, {"count": 0, "total_us": 0.0,
                                         "max_us": 0.0})
        us = sp.dur * 1e6
        ent["count"] += 1
        ent["total_us"] += us
        ent["max_us"] = max(ent["max_us"], us)
        if sp.name == SPAN_DISPATCH:
            tier = str(sp.attrs.get("tier", "unknown"))
            t = tiers.setdefault(tier, {"count": 0, "total_us": 0.0})
            t["count"] += 1
            t["total_us"] += us
    for ent in names.values():
        ent["mean_us"] = ent["total_us"] / ent["count"]
    for ent in tiers.values():
        ent["mean_us"] = ent["total_us"] / ent["count"]
    return {"spans": n, "traces": len(traces), "names": names,
            "tiers": tiers}


# ---------------------------------------------------------------------------
# process-global tracer.  None == disabled: instrumented call sites read
# this single attribute (``trace._TRACER``) and take the untraced path —
# no method call, no allocation (the E18 zero-instrument-call gate).

_TRACER: Optional[Tracer] = None
_TRACER_LOCK = threading.Lock()


def get_tracer() -> Optional[Tracer]:
    return _TRACER


def enable_tracing(sample: float = 1.0,
                   max_spans: int = MAX_SPANS) -> Tracer:
    """Install (or retune the sampling of) the process-global tracer."""
    global _TRACER
    with _TRACER_LOCK:
        if _TRACER is None:
            _TRACER = Tracer(sample=sample, max_spans=max_spans)
        else:
            _TRACER.set_sample(sample)
    return _TRACER


def reset_tracing() -> None:
    """Disable tracing and discard the tracer (tests / benchmarks)."""
    global _TRACER
    with _TRACER_LOCK:
        _TRACER = None
