"""Serving observability: metrics registry, status endpoint, sentry.

The layer every subsystem from PRs 1–5 publishes into and every dashboard
reads out of:

``metrics``
    Process-wide :class:`MetricsRegistry` — counters/gauges with lock-free
    per-thread shards, ring-buffer-quantile histograms, and scrape-time
    collectors over the serving stack's existing counters (so the dispatch
    hot path stays untouched).  Prometheus text + JSON rendering.

``snapshot``
    :func:`status_snapshot` / :func:`plan_snapshot` — the ONE serializer
    behind ``/status``, ``/plan``, ``tunedb stats --json`` and
    ``tunedb fleet status --json``.

``server``
    :class:`StatusServer` — stdlib HTTP endpoint (``/metrics``,
    ``/status``, ``/plan``); embedded in ``Engine`` via
    ``ServeConfig(status_port=...)`` or run standalone with
    ``python -m repro.tunedb serve-status``.

``sentry``
    :class:`RegressionSentry` — generation diffs that gate promotion at
    ``install_serving``, ``Coordinator`` merge, and the ``tunedb diff``
    CLI: a record slower than the one it replaces beyond the noise margin
    is reported and refused, never silently frozen into the next plan.

``trace``
    :class:`Tracer` — span-based end-to-end request tracing with
    deterministic sampling and Chrome trace-event (Perfetto) export;
    enabled via ``ServeConfig(trace_sample=...)`` / ``enable_tracing``,
    surfaced at ``/trace`` and ``tunedb trace {export,summary}``.
"""

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      get_registry, reset_metrics)
from .sentry import (DEFAULT_NOISE_MARGIN, Regression, RegressionSentry,
                     SentryReport, last_report)
from .server import StatusServer
from .snapshot import plan_snapshot, status_snapshot
from .trace import (Span, Tracer, chrome_trace, collect_fleet_spans,
                    enable_tracing, get_tracer, load_span_file,
                    new_trace_id, reset_tracing, summarize_spans)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "get_registry", "reset_metrics",
    "DEFAULT_NOISE_MARGIN", "Regression", "RegressionSentry", "SentryReport",
    "last_report",
    "StatusServer",
    "plan_snapshot", "status_snapshot",
    "Span", "Tracer", "chrome_trace", "collect_fleet_spans",
    "enable_tracing", "get_tracer", "load_span_file", "new_trace_id",
    "reset_tracing", "summarize_spans",
]
