"""Process-wide metrics registry: counters, gauges, ring-buffer histograms.

Design goals, in priority order:

1. **Zero hot-path cost for dispatch.**  The serving tiers (plan probe /
   exact / model / nearest) already keep their own cheap integer counters
   (``DispatchPlan.hits``, ``RecordStore.hits`` ...).  Rather than add a
   second increment to the nanosecond-budget dispatch path, the registry
   supports *collectors*: callables sampled at scrape time that read those
   existing counters and emit samples.  The E15 gate (<2% overhead vs the
   E14 plan-probe path) is honest because the hot path is byte-identical
   with metrics on or off.

2. **Lock-free direct instruments for warm paths.**  Events that happen
   off the dispatch fast path (degradations, admission decisions, sentry
   blocks, retunes, shard merges) increment real counters.  A
   :class:`Counter` keeps one shard dict *per writer thread* — the same
   single-writer discipline as telemetry's ``_Ring`` (PR 2): the owning
   thread is the only mutator of its shard, CPython dict item writes are
   atomic under the GIL, and readers merge ``list(shard.items())``
   snapshots (a single C call, so never a torn view).  No increment is
   ever lost and no lock is taken on the write side.

3. **Histograms reuse the ``_Ring`` pattern literally.**  A
   :class:`Histogram` keeps a per-thread ring of recent observations
   (imported from :mod:`repro.tunedb.telemetry`) plus owner-written
   count/sum; quantiles are computed at scrape time over the merged rings,
   so they reflect a recent window rather than all of history — exactly
   what you want for "did the last retune make swap latency worse".

Rendering: :meth:`MetricsRegistry.render_prometheus` emits the Prometheus
text exposition format (histograms as ``summary`` with quantile labels);
:meth:`MetricsRegistry.snapshot` emits the same data as JSON-able dicts
for ``/status`` and the ``--json`` CLIs.
"""

from __future__ import annotations

import threading
import weakref
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Tuple

from ..telemetry import _Ring

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Sample",
    "get_registry", "reset_metrics",
]

LabelKey = Tuple[Tuple[str, str], ...]

HIST_RING_SIZE = 1024       # recent observations kept per writer thread
QUANTILES = (0.5, 0.9, 0.99)


def _label_key(labels: Mapping[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Sample:
    """One exported time-series point: ``name{labels} value``."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: LabelKey, value: float) -> None:
        self.name = name
        self.labels = labels
        self.value = value


class _Metric:
    """Shared bookkeeping: name, help text, Prometheus type string."""

    kind = "untyped"

    def __init__(self, name: str, help: str) -> None:
        self.name = name
        self.help = help

    def samples(self) -> List[Sample]:  # pragma: no cover - interface
        raise NotImplementedError


class Counter(_Metric):
    """Monotonic counter with lock-free per-thread shards.

    ``inc()`` touches only the calling thread's own dict — the single-
    writer rule from telemetry's ``_Ring`` — so concurrent writers never
    contend and never lose increments.  Shards of dead threads are folded
    into ``_base`` at read time (the owner is gone, so the fold is the
    only writer left).
    """

    kind = "counter"

    def __init__(self, name: str, help: str = "") -> None:
        super().__init__(name, help)
        self._tls = threading.local()
        self._lock = threading.Lock()               # shard registry only
        self._shards: List[Tuple[weakref.ref, Dict[LabelKey, float]]] = []
        self._base: Dict[LabelKey, float] = {}      # folded dead shards

    def inc(self, n: float = 1.0, **labels: str) -> None:
        shard = getattr(self._tls, "shard", None)
        if shard is None:
            shard = self._tls.shard = {}
            with self._lock:
                self._shards.append((weakref.ref(threading.current_thread()),
                                     shard))
        key = _label_key(labels)
        shard[key] = shard.get(key, 0.0) + n        # owner-thread only

    def value(self, **labels: str) -> float:
        key = _label_key(labels)
        return dict((s.labels, s.value) for s in self.samples()).get(key, 0.0)

    def samples(self) -> List[Sample]:
        with self._lock:
            totals = dict(self._base)
            live: List[Tuple[weakref.ref, Dict[LabelKey, float]]] = []
            for ref, shard in self._shards:
                # list(d.items()) is one C call: an atomic snapshot even
                # while the owning thread keeps incrementing.
                for key, val in list(shard.items()):
                    totals[key] = totals.get(key, 0.0) + val
                if ref() is not None and ref().is_alive():
                    live.append((ref, shard))
                else:                               # owner dead: fold & drop
                    for key, val in list(shard.items()):
                        self._base[key] = self._base.get(key, 0.0) + val
            self._shards = live
        return [Sample(self.name, k, v) for k, v in sorted(totals.items())]


class Gauge(_Metric):
    """Last-write-wins value per label set (plain dict under a tiny lock —
    gauges are set from control paths, never the dispatch path)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "") -> None:
        super().__init__(name, help)
        self._lock = threading.Lock()
        self._values: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels: str) -> None:
        with self._lock:
            self._values[_label_key(labels)] = float(value)

    def value(self, **labels: str) -> Optional[float]:
        with self._lock:
            return self._values.get(_label_key(labels))

    def samples(self) -> List[Sample]:
        with self._lock:
            items = sorted(self._values.items())
        return [Sample(self.name, k, v) for k, v in items]


class _HistShard:
    """One writer thread's slice of a histogram: a telemetry ``_Ring`` of
    recent observations plus owner-written count/sum."""

    __slots__ = ("ring", "count", "total")

    def __init__(self) -> None:
        self.ring = _Ring(HIST_RING_SIZE)
        self.count = 0
        self.total = 0.0


class Histogram(_Metric):
    """Observation stream with ring-buffer quantiles.

    Rendered as a Prometheus ``summary``: ``name{quantile="0.5"}`` over a
    sliding window of the last ``HIST_RING_SIZE`` observations per writer
    thread, plus exact monotonic ``name_count`` / ``name_sum``.
    """

    kind = "summary"

    def __init__(self, name: str, help: str = "") -> None:
        super().__init__(name, help)
        self._tls = threading.local()
        self._lock = threading.Lock()
        self._shards: List[Tuple[weakref.ref, _HistShard]] = []
        self._base_count = 0
        self._base_total = 0.0

    def observe(self, value: float) -> None:
        shard = getattr(self._tls, "shard", None)
        if shard is None:
            shard = self._tls.shard = _HistShard()
            with self._lock:
                self._shards.append((weakref.ref(threading.current_thread()),
                                     shard))
        ring = shard.ring
        ring.buf[ring.head % len(ring.buf)] = float(value)
        ring.head += 1                              # publish after the slot
        shard.count += 1
        shard.total += value

    def _window(self) -> List[float]:
        out: List[float] = []
        with self._lock:
            shards = list(self._shards)
        for _ref, shard in shards:
            ring = shard.ring
            head, size = ring.head, len(ring.buf)
            for i in range(max(0, head - size), head):
                v = ring.buf[i % size]
                if v is not None:
                    out.append(v)
        return out

    def quantiles(self, qs: Iterable[float] = QUANTILES) -> Dict[float, float]:
        window = sorted(self._window())
        if not window:
            return {q: 0.0 for q in qs}
        last = len(window) - 1
        return {q: window[min(last, int(round(q * last)))] for q in qs}

    def stats(self) -> Tuple[int, float]:
        count, total = self._base_count, self._base_total
        with self._lock:
            live: List[Tuple[weakref.ref, _HistShard]] = []
            for ref, shard in self._shards:
                count += shard.count
                total += shard.total
                if ref() is not None and ref().is_alive():
                    live.append((ref, shard))
                else:
                    self._base_count += shard.count
                    self._base_total += shard.total
            self._shards = live
        return count, total

    def samples(self) -> List[Sample]:
        count, total = self.stats()
        out = [Sample(self.name, (("quantile", f"{q:g}"),), v)
               for q, v in sorted(self.quantiles().items())]
        out.append(Sample(self.name + "_count", (), float(count)))
        out.append(Sample(self.name + "_sum", (), total))
        return out


Collector = Callable[[], Iterable[Tuple[str, str, Mapping[str, str], float]]]
"""A collector yields ``(name, kind, labels, value)`` tuples at scrape time."""


class MetricsRegistry:
    """Named instruments + scrape-time collectors, one per process."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}
        self._collectors: List[Collector] = []

    # -- instrument factories (get-or-create, idempotent) -----------------
    def _get(self, cls, name: str, help: str) -> _Metric:
        with self._lock:
            metric = self._metrics.get(name)
            if metric is None:
                metric = self._metrics[name] = cls(name, help)
            elif not isinstance(metric, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(metric).__name__}")
            return metric

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)       # type: ignore[return-value]

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)         # type: ignore[return-value]

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)     # type: ignore[return-value]

    def register_collector(self, fn: Collector) -> None:
        with self._lock:
            if fn not in self._collectors:
                self._collectors.append(fn)

    # -- scraping ----------------------------------------------------------
    def _collected(self) -> List[Tuple[str, str, LabelKey, float]]:
        with self._lock:
            collectors = list(self._collectors)
        out: List[Tuple[str, str, LabelKey, float]] = []
        for fn in collectors:
            try:
                for name, kind, labels, value in fn():
                    out.append((name, kind, _label_key(labels), float(value)))
            except Exception:                       # a broken collector must
                continue                            # never break the scrape
        return out

    def snapshot(self) -> Dict[str, Dict]:
        """JSON-able view: ``{name: {"kind":..., "samples": [...]}}``."""
        out: Dict[str, Dict] = {}
        with self._lock:
            metrics = list(self._metrics.values())
        for metric in metrics:
            out[metric.name] = {
                "kind": metric.kind,
                "help": metric.help,
                "samples": [{"labels": dict(s.labels), "value": s.value}
                            for s in metric.samples()],
            }
        for name, kind, labels, value in self._collected():
            entry = out.setdefault(name, {"kind": kind, "help": "",
                                          "samples": []})
            entry["samples"].append({"labels": dict(labels), "value": value})
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: List[str] = []
        seen_types: set = set()

        def emit(name: str, kind: str, help: str, labels: LabelKey,
                 value: float) -> None:
            family = name[:-6] if name.endswith("_count") else (
                name[:-4] if name.endswith("_sum") else name)
            if family not in seen_types:
                seen_types.add(family)
                if help:
                    lines.append(f"# HELP {family} {help}")
                lines.append(f"# TYPE {family} {kind}")
            if labels:
                body = ",".join(f'{k}="{_escape(v)}"' for k, v in labels)
                lines.append(f"{name}{{{body}}} {_fmt(value)}")
            else:
                lines.append(f"{name} {_fmt(value)}")

        with self._lock:
            metrics = list(self._metrics.values())
        for metric in metrics:
            for s in metric.samples():
                emit(s.name, metric.kind, metric.help, s.labels, s.value)
        for name, kind, labels, value in self._collected():
            emit(name, kind, "", labels, value)
        return "\n".join(lines) + "\n"


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


def _fmt(v: float) -> str:
    return str(int(v)) if float(v).is_integer() else repr(float(v))


# ---------------------------------------------------------------------------
# process-global registry

_REGISTRY = MetricsRegistry()
_REGISTRY_LOCK = threading.Lock()


def get_registry() -> MetricsRegistry:
    return _REGISTRY


def reset_metrics() -> MetricsRegistry:
    """Fresh registry (tests / benchmarks).  Default collectors that read
    the live serving state are re-registered on the new registry."""
    global _REGISTRY
    with _REGISTRY_LOCK:
        _REGISTRY = MetricsRegistry()
        _register_default_collectors(_REGISTRY)
    return _REGISTRY


# ---------------------------------------------------------------------------
# default collectors: read the counters the serving stack already keeps.
# Imports happen lazily inside the collector so obs never creates an import
# cycle with store/telemetry, and a half-initialised stack just yields
# nothing instead of failing the scrape.

def _serving_collector():
    from ..store import serving_state
    from ..telemetry import get_telemetry

    state = serving_state()
    out = []
    out.append(("tunedb_serving_generation", "gauge", {},
                float(state.generation)))
    store = state.store
    if store is not None:
        out.append(("tunedb_store_lookups_total", "counter",
                    {"tier": "exact"}, float(store.hits)))
        out.append(("tunedb_store_lookups_total", "counter",
                    {"tier": "nearest"}, float(store.nearest_hits)))
        out.append(("tunedb_store_lookups_total", "counter",
                    {"tier": "miss"}, float(store.misses)))
        out.append(("tunedb_store_records", "gauge", {},
                    float(len(store))))
        out.append(("tunedb_store_version", "gauge", {},
                    float(store.version)))
    models = state.models
    if models is not None:
        for attr, result in (("hits", "hit"), ("misses", "miss"),
                             ("gated", "gated")):
            out.append(("tunedb_model_lookups_total", "counter",
                        {"result": result},
                        float(getattr(models, attr, 0))))
    plan = state.plan
    if plan is not None:
        ps = plan.stats()
        out.append(("tunedb_plan_source", "gauge",
                    {"source": str(getattr(plan, "source", "compiled"))},
                    1.0))
        out.append(("tunedb_plan_lookups_total", "counter",
                    {"result": "hit"}, float(ps.get("hits", 0))))
        out.append(("tunedb_plan_lookups_total", "counter",
                    {"result": "miss"}, float(ps.get("misses", 0))))
        out.append(("tunedb_plan_entries", "gauge",
                    {"origin": "built"}, float(ps.get("entries", 0))))
        out.append(("tunedb_plan_entries", "gauge",
                    {"origin": "promoted"}, float(ps.get("promoted", 0))))
        out.append(("tunedb_plan_generation", "gauge", {},
                    float(ps.get("generation", 0))))
        out.append(("tunedb_plan_store_version", "gauge", {},
                    float(plan.store_version)))
        for tier, n in (ps.get("tiers") or {}).items():
            out.append(("tunedb_plan_tier_entries", "gauge",
                        {"tier": str(tier)}, float(n)))
    tele = get_telemetry()
    ts = tele.stats()
    out.append(("tunedb_telemetry_epoch", "gauge", {},
                float(ts.get("epoch", 0))))
    for space, ticks in (ts.get("ticks") or {}).items():
        out.append(("tunedb_telemetry_ticks_total", "counter",
                    {"space": space}, float(ticks)))
    for space, info in (ts.get("spaces") or {}).items():
        out.append(("tunedb_telemetry_calls_total", "counter",
                    {"space": space}, float(info.get("calls", 0))))
        out.append(("tunedb_telemetry_shapes", "gauge",
                    {"space": space}, float(info.get("shapes", 0))))
    return out


def _follower_collector():
    """Plan-follower state (tunedb.plans.PlanFollower) at scrape time.

    Followers register themselves in a process-global list; reading their
    counters here keeps the poll path instrumentation-free, like every
    other pull-model family.  ``lag_generations`` does one small CURRENT
    pointer read per follower per scrape — the actual distribution lag a
    fleet dashboard alerts on."""
    from ..plans import active_followers

    out = []
    for f in active_followers():
        labels = {"follower": f.name}
        out.append(("tunedb_follower_generation", "gauge", labels,
                    float(f.generation)))
        out.append(("tunedb_follower_lag_generations", "gauge", labels,
                    float(f.lag_generations())))
        if f.lag_s is not None:
            out.append(("tunedb_follower_lag_seconds", "gauge", labels,
                        float(f.lag_s)))
        out.append(("tunedb_follower_polls_total", "counter", labels,
                    float(f.polls)))
        out.append(("tunedb_follower_installs_total", "counter", labels,
                    float(f.installs)))
        for reason, n in (("digest", f.refused_digest),
                          ("stale", f.refused_stale),
                          ("sentry", f.refused_sentry)):
            out.append(("tunedb_follower_refusals_total", "counter",
                        {**labels, "reason": reason}, float(n)))
    return out


def _register_default_collectors(registry: MetricsRegistry) -> None:
    registry.register_collector(_serving_collector)
    registry.register_collector(_follower_collector)


_register_default_collectors(_REGISTRY)
