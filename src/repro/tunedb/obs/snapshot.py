"""One serializer for every status surface.

``/status`` (HTTP), ``tunedb stats --json``, and ``tunedb fleet status
--json`` all call :func:`status_snapshot` — there is exactly one place the
schema lives, so dashboards written against the CLI output work unchanged
against the endpoint (and vice versa).  Every section is present in every
snapshot; a subsystem that is not running serializes to ``None`` rather
than disappearing, so consumers never need existence checks.

Schema (version 1)::

    {
      "schema": 1,
      "serving":  {generation, fingerprint, store, models, plan} | nulls,
      "tiers":    {counts per tier, "rates" per tier, "total"},
      "telemetry": ShapeTelemetry.stats() | null,
      "retune":   RetuneController.stats() (incl. "history") | null,
      "fleet":    {FleetDir.status() + "report"} | null,
      "follower": PlanFollower.stats() | null,
      "router":   Router.stats() | null,
      "trace":    Tracer.stats() (sampled/dropped/buffered counts +
                  per-tier dispatch latency attribution) | null,
      "metrics":  MetricsRegistry.snapshot(),
    }
"""

from __future__ import annotations

from typing import Dict, List, Optional

__all__ = ["SCHEMA_VERSION", "status_snapshot", "plan_snapshot"]

SCHEMA_VERSION = 1

PLAN_SNAPSHOT_CAP = 2000    # /plan entry cap: a plan can hold thousands


def status_snapshot(*, store=None, telemetry=None, controller=None,
                    fleet: Optional[str] = None, models=None,
                    registry=None, follower=None,
                    router=None, tracer=None) -> Dict[str, object]:
    """Build the shared status document.

    With no arguments, reads the process's live serving state (what the
    HTTP endpoint inside an :class:`~repro.serve.engine.Engine` does);
    explicit ``store``/``telemetry``/``fleet`` override for the offline
    CLIs that inspect a store file or a fleet bus from outside.
    """
    from ..store import serving_state
    from ..telemetry import get_telemetry
    from .metrics import get_registry

    state = serving_state()
    if store is None:
        store = state.store
    if models is None:
        models = state.models
    if telemetry is None:
        telemetry = get_telemetry()
    if registry is None:
        registry = get_registry()
    plan = state.plan

    store_stats = store.stats() if store is not None else None
    model_stats = models.stats() if models is not None else None
    plan_stats = None
    if plan is not None:
        plan_stats = dict(plan.stats())
        plan_stats["fingerprint"] = plan.fingerprint
        plan_stats["store_version"] = plan.store_version
    if follower is None:
        # an engine-owned follower is also discoverable process-globally
        from ..plans import active_followers
        live = active_followers()
        follower = live[0] if live else None
    if tracer is None:
        from .trace import get_tracer
        tracer = get_tracer()

    # flush pending lock-free ring buffers before serializing: without this
    # a snapshot taken between drains under-reports shapes recorded via
    # record_buffered (duck-typed: fleet views drain their local leg only)
    drain = getattr(telemetry, "drain_pending", None)
    if callable(drain):
        drain()

    snapshot: Dict[str, object] = {
        "schema": SCHEMA_VERSION,
        "serving": {
            "generation": state.generation,
            "fingerprint": state.fingerprint,
            "store": store_stats,
            "models": model_stats,
            "plan": plan_stats,
        },
        "tiers": _tier_rates(store, models, plan),
        "telemetry": telemetry.stats() if telemetry is not None else None,
        "retune": controller.stats() if controller is not None else None,
        "fleet": _fleet_section(fleet) if fleet else None,
        "follower": follower.stats() if follower is not None else None,
        "router": router.stats() if router is not None else None,
        "trace": tracer.stats() if tracer is not None else None,
        "metrics": registry.snapshot(),
    }
    return snapshot


def _tier_rates(store, models, plan) -> Dict[str, object]:
    """Per-tier resolution counts + hit-rate fractions.

    Tier counts come from the counters each tier already maintains (plan
    hits credit the originating tier's store/model counters too — see
    ``_tuned_cfg`` — so store/model counts are the authoritative per-tier
    totals and the plan's own hits are reported separately).
    """
    counts = {
        "exact": getattr(store, "hits", 0) if store is not None else 0,
        "nearest": getattr(store, "nearest_hits", 0)
        if store is not None else 0,
        "model": getattr(models, "hits", 0) if models is not None else 0,
        "model_gated": getattr(models, "gated", 0)
        if models is not None else 0,
        "miss": getattr(store, "misses", 0) if store is not None else 0,
    }
    total = counts["exact"] + counts["nearest"] + counts["model"] \
        + counts["miss"]
    rates = {tier: (counts[tier] / total if total else 0.0)
             for tier in ("exact", "nearest", "model", "miss")}
    out: Dict[str, object] = {"counts": counts, "rates": rates,
                              "total": total}
    if plan is not None:
        out["plan"] = {"hits": plan.hits, "misses": plan.misses}
    return out


def _fleet_section(fleet: str) -> Optional[Dict[str, object]]:
    import json
    from pathlib import Path

    from ..fleet.lease import REPORT, FleetDir

    root = Path(fleet)
    if not root.exists():
        return None
    fd = FleetDir(root)
    try:
        section: Dict[str, object] = dict(fd.status())
    except FileNotFoundError:
        # a telemetry-only bus: exporters may land dumps before any
        # `fleet start` writes the manifest — still a real fleet surface
        section = {"root": str(root), "store": None, "counts": None,
                   "draining": False, "lease_age_s": {},
                   "shard_records": {}}
    tel_dir = fd.telemetry_dir()
    if tel_dir.is_dir():
        from ..telemetry import FleetTelemetryView, ShapeTelemetry
        section["telemetry_replicas"] = FleetTelemetryView(
            tel_dir, local=ShapeTelemetry(), refresh_s=0.0).replicas()
    report_path = root / REPORT
    report = None
    if report_path.exists():
        try:
            report = json.loads(report_path.read_text())
        except (OSError, ValueError):
            report = None
    section["report"] = report
    return section


def plan_snapshot(plan=None, *, cap: int = PLAN_SNAPSHOT_CAP
                  ) -> Dict[str, object]:
    """The active :class:`DispatchPlan` as a JSON table (``/plan``).

    Entries carry the shape, chosen config, resolving tier, and whether the
    entry was compiled in (``built``) or frozen at serving time
    (``promoted``).  Output is diffable by ``tunedb diff`` across
    generations (coverage-level — plan entries carry no TFLOP/s).
    """
    from ..store import serving_state

    if plan is None:
        plan = serving_state().plan
    if plan is None:
        return {"generation": None, "fingerprint": None,
                "store_version": None, "source": None, "digest": None,
                "entries": [], "truncated": False}

    entries: List[Dict[str, object]] = []
    truncated = False
    for origin, table in (("built", plan._table),
                          ("promoted", plan._overlay)):
        for (space, key), (config, tier) in list(table.items()):
            if len(entries) >= cap:
                truncated = True
                break
            entries.append({
                "space": space,
                "inputs": {k: v for k, v in key},
                "config": dict(config),
                "tier": tier,
                "origin": origin,
            })
    return {
        "generation": plan.generation,
        "fingerprint": plan.fingerprint,
        "store_version": plan.store_version,
        "source": getattr(plan, "source", "compiled"),
        "digest": getattr(plan, "digest", None),
        "entries": entries,
        "truncated": truncated,
    }
