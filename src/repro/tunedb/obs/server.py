"""``serve-status``: a stdlib HTTP endpoint over the observability layer.

Three routes, all read-only:

* ``/metrics`` — Prometheus text exposition (scrape target).
* ``/status``  — the JSON document from :func:`~.snapshot.status_snapshot`.
* ``/plan``    — the active :class:`DispatchPlan` table
  (:func:`~.snapshot.plan_snapshot`), save-able and diffable with
  ``tunedb diff``.
* ``/trace``   — the tracer's retained spans as Chrome trace-event JSON
  (:func:`~.trace.chrome_trace`): save the body to a file and open it in
  Perfetto.  404 while tracing is disabled.
* ``/healthz`` — liveness/readiness probe.  Without a ``health`` callable
  it always answers ``200 ok``; with one (the Engine passes its shedding
  state) it answers ``503`` plus the reason while the process is degraded,
  so load balancers stop routing to a replica that is shedding requests.

The server is a ``ThreadingHTTPServer`` on a daemon thread: scrapes ride
their own threads and never block serving, and an abandoned server dies
with the process.  ``port=0`` binds an ephemeral port (tests, and the
default for ``ServeConfig.status_port=0``); the bound port is ``.port``
after :meth:`StatusServer.start`.

Run standalone against a store file::

    python -m repro.tunedb serve-status --store tunedb.jsonl --port 9177

or inside a serving process via ``ServeConfig(status_port=...)``.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from .metrics import get_registry
from .snapshot import plan_snapshot, status_snapshot

__all__ = ["StatusServer"]


class StatusServer:
    """Owns the HTTP server lifecycle and the snapshot context.

    ``controller`` / ``fleet`` / ``store`` / ``telemetry`` are optional
    context handles threaded into every ``/status`` build; whatever is
    omitted falls back to the process's live serving state, so an Engine
    only needs to pass its controller.
    """

    def __init__(self, *, host: str = "127.0.0.1", port: int = 0,
                 controller=None, fleet: Optional[str] = None,
                 store=None, telemetry=None, models=None,
                 follower=None, router=None, tracer=None,
                 health=None) -> None:
        self.host = host
        self.port = port
        self.controller = controller
        self.fleet = fleet
        self.store = store
        self.telemetry = telemetry
        self.models = models
        self.follower = follower
        self.router = router
        self.tracer = tracer
        # health() -> truthy (healthy) | falsy | (False, "reason"); exceptions
        # count as unhealthy — a probe must never report ok by accident
        self.health = health
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    # -- payload builders (also used directly by tests/benchmarks) ---------
    def metrics_text(self) -> str:
        return get_registry().render_prometheus()

    def status_json(self) -> dict:
        return status_snapshot(store=self.store, telemetry=self.telemetry,
                               controller=self.controller, fleet=self.fleet,
                               models=self.models, follower=self.follower,
                               router=self.router, tracer=self.tracer)

    def plan_json(self) -> dict:
        return plan_snapshot()

    def health_check(self) -> tuple:
        """(ok, reason) from the ``health`` callable; no callable = ok."""
        if self.health is None:
            return True, "ok"
        try:
            out = self.health()
        except Exception as exc:
            return False, f"health probe failed: {exc}"
        if isinstance(out, tuple):
            ok = bool(out[0])
            reason = str(out[1]) if len(out) > 1 else "degraded"
            return ok, reason
        return (True, "ok") if out else (False, "degraded")

    def trace_json(self) -> Optional[dict]:
        """Retained spans as a Chrome trace-event document, or None while
        tracing is disabled (the route turns that into a 404)."""
        from .trace import chrome_trace, get_tracer
        tracer = self.tracer if self.tracer is not None else get_tracer()
        if tracer is None:
            return None
        return chrome_trace(tracer.spans())

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "StatusServer":
        if self._httpd is not None:
            return self
        server = self

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self) -> None:       # noqa: N802 (http.server API)
                path = self.path.split("?", 1)[0].rstrip("/") or "/"
                try:
                    if path == "/metrics":
                        body = server.metrics_text().encode()
                        ctype = "text/plain; version=0.0.4; charset=utf-8"
                    elif path in ("/status", "/"):
                        body = (json.dumps(server.status_json(), indent=1,
                                           sort_keys=True, default=str)
                                + "\n").encode()
                        ctype = "application/json"
                    elif path == "/plan":
                        body = (json.dumps(server.plan_json(), indent=1,
                                           sort_keys=True, default=str)
                                + "\n").encode()
                        ctype = "application/json"
                    elif path == "/trace":
                        doc = server.trace_json()
                        if doc is None:
                            self.send_error(404, "tracing disabled")
                            return
                        body = (json.dumps(doc) + "\n").encode()
                        ctype = "application/json"
                    elif path == "/healthz":
                        ok, reason = server.health_check()
                        if not ok:
                            self.send_error(503, reason)
                            return
                        body, ctype = b"ok\n", "text/plain"
                    else:
                        self.send_error(404, "unknown route")
                        return
                except Exception as exc:    # surface, don't kill the thread
                    self.send_error(500, f"snapshot failed: {exc}")
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args) -> None:   # quiet by default
                pass

        self._httpd = ThreadingHTTPServer((self.host, self.port), _Handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="tunedb-status",
            daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def __enter__(self) -> "StatusServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
