"""Continuous retuning: the telemetry -> tune -> train -> serve loop, closed.

PR 1 built the pieces as manual CLI steps (mine telemetry, run a session,
train models, restart serving with the new artifacts); this module runs them
*in-process*.  A :class:`RetuneController` keeps an epoch baseline snapshot
of the global :class:`~repro.tunedb.telemetry.ShapeTelemetry` and, on every
``maybe_retune()`` poll (the serving engine calls it every
``ServeConfig.retune_interval`` decode ticks):

  1. **detect** — ``telemetry.diff(baseline)`` yields per-space hot-shape
     mass drift (total-variation distance between the baseline distribution
     and the traffic window since it) plus the window's shapes; the
     controller adds *untuned hot mass* — the fraction of window calls
     landing on shapes with no store record under the active fingerprint.
     This is the staleness signal MLKAPS (arXiv:2501.05811) samples
     adaptively against, and that the model-driven adaptive-library line
     (arXiv:1806.07060) closes with an online update loop.
  2. **tune** — when drift or untuned mass crosses its threshold (and the
     window has enough calls to mean anything), a
     :class:`~repro.tunedb.session.TuningSession` runs over the window's
     novel hot shapes and commits ``source="retune"`` records (plus the
     measured top-k as training samples).
  3. **train** — the affected ``(space, backend)`` regressors retrain from
     the grown measurement log (``train_models``); untouched regressors are
     carried over unchanged.
  4. **swap** — ``install_serving`` flips the process-global
     (store, ModelSet, fingerprint) to a new generation in ONE atomic
     assignment: dispatch never sees a torn store/model pair, per-shape
     memos are invalidated, and the warn-once degradation latches re-arm.
     The baseline snapshot advances, opening the next epoch.

A no-trigger poll is a snapshot diff over the telemetry dict (microseconds
against a multi-millisecond decode tick — bench_retune.py gates it at <2%).
Triggered epochs come in two execution modes:

  * **inline** (the PR 3 behavior): session + retrain run on the polling
    thread — the decode tick that trips the threshold pays for the epoch.
  * **async** (``async_mode=True``): the poll only *submits* the epoch and
    returns immediately; a background thread runs the plan — through a
    fleet directory (``fleet_dir``: jobs published as lease files for
    external ``fleet worker`` processes, shards merged back by the
    coordinator) or an in-process session when no fleet is attached — and
    performs the same atomic ``install_serving`` swap when merge+retrain
    complete.  ``maybe_retune()`` never stalls a decode tick; the next
    poll after completion returns the finished report.

Epoch admission is budgeted: ``cooldown_ticks`` spaces retunes out along
the engine's tick clock, ``max_sessions_per_window`` caps them per
wall-clock window, and ``min_gain`` skips epochs whose projected win
(model-predicted TFLOPS vs what the nearest record already serves) is too
small to pay for a session.
"""

from __future__ import annotations

import collections
import dataclasses
import logging
import threading
import time
import warnings
from typing import Callable, Dict, List, Mapping, Optional, Set, Tuple

from .session import SessionReport, TuningSession, backend_fingerprint
from .store import RecordStore, input_key, install_serving, serving_state
from .telemetry import ShapeTelemetry, SpaceDrift, get_telemetry

log = logging.getLogger(__name__)

# lazily bound trace module (False = unavailable): the controller probes
# one module attribute per epoch, so disabled tracing costs zero
# instrument calls on the retune path
_TRACE = None


def _tracer():
    global _TRACE
    t = _TRACE
    if t is None:
        try:
            from .obs import trace as t
        except Exception:   # noqa: BLE001 — tracing is strictly optional
            t = False
        _TRACE = t
    return t._TRACER if t else None


def _default_tuner_factory(space_name: str):
    """Train a small input-aware tuner on demand (serving processes that
    enable retuning without shipping one in).  Deliberately modest sizes:
    the controller runs inside a serving loop, not a tuning fleet."""
    from repro.core.backend import SimulatedTPUBackend
    from repro.core.space import SPACES
    from repro.core.tuner import InputAwareTuner
    return InputAwareTuner.train(
        SPACES[space_name], n_samples=4000, hidden=(32, 64, 32), epochs=12,
        backend=SimulatedTPUBackend(noise=0.02), seed=0)


HISTORY_CAP = 64        # retune-history entries kept for /status


@dataclasses.dataclass(frozen=True)
class RetuneConfig:
    """Thresholds and session/retrain knobs for the retune loop."""

    drift_threshold: float = 0.25        # TV distance that counts as a shift
    untuned_mass_threshold: float = 0.5  # window mass on record-less shapes
    min_calls: int = 32                  # window calls before a space is judged
    top_k_shapes: int = 4                # novel hot shapes per session
    workers: int = 2
    remeasure: bool = True               # session top-k re-measurement (§6)
    retrain: bool = True                 # retrain regressors after a session
    min_train_samples: int = 24
    train_epochs: int = 20
    seed: int = 0
    # -- epoch budget ---------------------------------------------------------
    # engine ticks a freshly retuned epoch blocks the next trigger for
    # (0 = no cooldown; needs the caller to pass its tick clock)
    cooldown_ticks: int = 0
    # retune sessions allowed per `session_window_s` wall-clock window
    # (0 = unlimited)
    max_sessions_per_window: int = 0
    session_window_s: float = 600.0
    # skip epochs whose projected relative gain — best model-predicted
    # TFLOPS over what the nearest record already serves — is below this
    # (0 = tune whenever triggered).  Shapes with no record AND no model
    # prediction count as unbounded gain: nothing serves them today.
    min_gain: float = 0.0
    # regression-sentry noise margin gating the end-of-epoch serving swap:
    # None disables; a float arms a RegressionSentry(noise_margin=sentry)
    # so an epoch whose supersessions regress a serving record beyond the
    # margin is reported and REFUSED instead of installed (the blocked
    # epoch shows up in stats()["sentry_blocked"] and the retune history).
    sentry: Optional[float] = None
    # plan registry directory (tunedb.plans.PlanRegistry): after a
    # SUCCESSFUL swap, the epoch's compiled DispatchPlan is published there
    # as the next golden generation for serving replicas to follow.  None
    # keeps retunes process-local.  A refused publish (e.g. a racing append
    # made the plan stale) warns and counts in stats()["publish_failed"] —
    # the local swap already happened and stays.
    publish: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class SpaceDecision:
    """One space's verdict for one controller poll."""

    space: str
    drift: float
    untuned_mass: float
    window_calls: int
    novel_shapes: List[Dict[str, int]]   # hot window shapes with no record
    trigger: bool
    reason: str                          # "drift" | "untuned" | ""
    # best (model-predicted - nearest-served) / nearest-served over the novel
    # shapes; None when no shape had both sides to compare (unbounded gain)
    projected_gain: Optional[float] = None


@dataclasses.dataclass
class RetuneReport:
    """What one triggered retune epoch did."""

    epoch: int                           # epoch number this retune OPENED
    generation: int                      # serving generation after the swap
    decisions: Dict[str, SpaceDecision]
    sessions: Dict[str, object]          # space -> SessionReport
    retrained: List[str]                 # "space/backend" regressors replaced
    wall_s: float = 0.0
    mode: str = "inline"                 # inline | async | fleet

    @property
    def tuned(self) -> int:
        return sum(r.tuned for r in self.sessions.values())


class RetuneController:
    """Drift-triggered sessions + retrain + atomic serving hot-swap.

    ``tuners`` maps space name -> a trained tuner (anything with ``.search``
    / ``.backend`` / ``.space``, i.e. ``InputAwareTuner``); spaces without
    one fall back to ``tuner_factory`` (trained once, cached).  ``store``
    is where sessions commit — normally the installed serving store, so
    exact-tier hits appear the moment a record lands.  ``models_dir`` (when
    set) persists every retrained ModelSet, keeping on-disk artifacts in
    step with the hot-swapped in-process ones.

    ``async_mode`` moves triggered epochs off the polling thread: the poll
    submits and returns, a daemon thread runs the plan and performs the
    atomic swap when it completes.  ``fleet_dir`` routes the plan through a
    :class:`~repro.tunedb.fleet.Coordinator` instead of an in-process
    session — external ``fleet worker`` processes do the tuning, the
    coordinator merges their shards into ``store`` (provenance intact),
    and the swap happens only after merge+retrain report complete.
    """

    def __init__(self, store: RecordStore, *,
                 telemetry: Optional[ShapeTelemetry] = None,
                 tuners: Optional[Mapping[str, object]] = None,
                 tuner_factory: Optional[Callable[[str], object]] = None,
                 models_dir=None,
                 cfg: Optional[RetuneConfig] = None,
                 baseline=None,
                 async_mode: bool = False,
                 fleet_dir=None,
                 fleet_lease_timeout_s: float = 30.0,
                 fleet_timeout_s: float = 600.0,
                 fleet_poll_s: float = 0.25,
                 measurer=None,
                 measure_queue=None,
                 verbose: bool = False):
        self.store = store
        # deferred §6 re-measurement plumbing (tunedb.measure): the engine
        # hands in its ServingMeasurer + MeasureQueue so the controller
        # poll drains re-measurements in idle decode gaps
        self.measurer = measurer
        self.measure_queue = measure_queue
        self.telemetry = telemetry if telemetry is not None else get_telemetry()
        self.cfg = cfg or RetuneConfig()
        self.models_dir = models_dir
        self.verbose = verbose
        self.async_mode = async_mode or fleet_dir is not None
        self.fleet_dir = fleet_dir
        self.fleet_lease_timeout_s = fleet_lease_timeout_s
        self.fleet_timeout_s = fleet_timeout_s
        self.fleet_poll_s = fleet_poll_s
        self._tuners: Dict[str, object] = dict(tuners or {})
        self._tuner_factory = tuner_factory or _default_tuner_factory
        self._lock = threading.Lock()        # one retune at a time
        self.epoch = 0
        self.checks = 0                      # polls (triggered or not)
        self.retunes = 0                     # epochs that actually retuned
        self.sentry_blocked = 0              # swaps refused by the sentry
        self.published_plans = 0             # golden generations published
        self.publish_failed = 0              # refused/errored publishes
        self.last_report: Optional[RetuneReport] = None
        # bounded per-epoch history for /status and `stats --json`
        self.history: collections.deque = collections.deque(
            maxlen=HISTORY_CAP)
        # async state: at most one in-flight background epoch
        self._async: Optional[threading.Thread] = None
        self._async_report: Optional[RetuneReport] = None
        self.async_submits = 0
        self.async_submit_t: Optional[float] = None   # perf_counter stamps
        self.async_done_t: Optional[float] = None
        # every background epoch's [submit, done] perf_counter window —
        # observability for "did any tick overlap a session" analyses
        self.async_windows: List[List[Optional[float]]] = []
        # watchdog: a background epoch still running session_window_s after
        # submit is cancelled (the fleet wait observes this event) so a hung
        # fleet can never wedge the engine's only in-flight epoch slot
        self._async_cancel = threading.Event()
        self.watchdog_cancels = 0
        # epoch budget state
        self._last_retune_tick: Optional[int] = None
        self._session_starts: List[float] = []
        # (space, key, generation) -> projected gain (min_gain planning memo)
        self._gain_memo: Dict[tuple, Optional[float]] = {}
        # (space, key) pairs a session already worked on: a shape whose
        # committed record can never serve (e.g. a fingerprint pin the
        # session backend does not match) must not re-trigger forever
        self._attempted: set = set()
        self._warned_pins: set = set()
        # `baseline` lets the CLI resume an epoch across processes (a saved
        # TelemetrySnapshot); in-process callers start at "now"
        self._baseline = (baseline if baseline is not None
                          else self.telemetry.snapshot())

    # -- detection ------------------------------------------------------------
    def _projected_gain(self, space: str, novel: List[Dict[str, int]],
                        fingerprint: Optional[str]) -> Optional[float]:
        """Best relative win a session could plausibly buy over the novel
        shapes: model-predicted achievable TFLOPS vs what the nearest-record
        tier already serves.  None when no shape has both sides — an
        un-projectable epoch is unbounded upside, not zero.
        """
        state = serving_state()
        models = state.models
        best: Optional[float] = None
        for inputs in novel:
            # memoized per serving generation: a low-gain epoch that keeps
            # polling must not re-pay the exhaustive model scan every time
            memo_key = (space, input_key(space, inputs), state.generation)
            if memo_key in self._gain_memo:
                gain = self._gain_memo[memo_key]
            else:
                gain = None
                near = self.store.nearest(space, inputs, backend=fingerprint,
                                          count=False)    # planning probe
                pm = models.resolve_model(space, fingerprint) \
                    if models is not None else None
                if near is not None and near.tflops > 0 and pm is not None:
                    try:
                        res = pm.predict_config(inputs, top_k=1)
                        gain = ((float(res.predicted_tflops) - near.tflops)
                                / near.tflops)
                    except Exception:   # noqa: BLE001 — no legal cfg
                        gain = None
                if len(self._gain_memo) > 1024:
                    self._gain_memo.clear()
                self._gain_memo[memo_key] = gain
            if gain is None:
                return None         # a shape nothing serves or projects today
            if best is None or gain > best:
                best = gain
        return best

    def _decide(self, drift: SpaceDrift, fingerprint: Optional[str]
                ) -> SpaceDecision:
        cfg = self.cfg
        untuned_calls = 0
        novel: List[Dict[str, int]] = []
        for inputs, count in drift.window_shapes:
            if not self.store.contains(drift.space, inputs,
                                       backend=fingerprint):
                untuned_calls += count      # honest mass, attempted or not
                if (len(novel) < cfg.top_k_shapes
                        and (drift.space, input_key(drift.space, inputs))
                        not in self._attempted):
                    novel.append(dict(inputs))
        mass = (untuned_calls / drift.window_calls
                if drift.window_calls else 0.0)
        reason = ""
        if drift.window_calls >= cfg.min_calls and novel:
            if drift.drift >= cfg.drift_threshold:
                reason = "drift"
            elif mass >= cfg.untuned_mass_threshold:
                reason = "untuned"
        gain: Optional[float] = None
        if reason and cfg.min_gain > 0:
            gain = self._projected_gain(drift.space, novel, fingerprint)
            if gain is not None and gain < cfg.min_gain:
                # the nearest tier already serves within min_gain of what
                # the model thinks is achievable: a session is not worth
                # its wall clock — spend the window, keep the records
                log.debug(
                    "retune[%s]: skipping %s epoch, projected gain %.3f "
                    "< min_gain %.3f over %d novel shape(s)",
                    drift.space, reason, gain, cfg.min_gain, len(novel))
                reason = ""
        return SpaceDecision(
            space=drift.space, drift=drift.drift, untuned_mass=mass,
            window_calls=drift.window_calls, novel_shapes=novel,
            trigger=bool(reason), reason=reason, projected_gain=gain)

    def reset_baseline(self) -> None:
        """Open a fresh epoch at "now" without retuning — callers that know
        the accumulated telemetry is already served (warm-up, benches)."""
        self._baseline = self.telemetry.snapshot()

    def check(self) -> Dict[str, SpaceDecision]:
        """Detection only — no sessions, no swap, baseline untouched."""
        self.checks += 1
        fp = serving_state().fingerprint
        decisions = {
            space: self._decide(drift, fp)
            for space, drift in self.telemetry.diff(self._baseline).items()}
        try:        # publish the drift view every poll (control path)
            from .obs.metrics import get_registry
            reg = get_registry()
            drift_g = reg.gauge("tunedb_drift_score",
                                "telemetry TV-distance per space vs the "
                                "epoch baseline")
            mass_g = reg.gauge("tunedb_untuned_mass",
                               "window traffic fraction on record-less "
                               "shapes per space")
            for space, d in decisions.items():
                drift_g.set(d.drift, space=space)
                mass_g.set(d.untuned_mass, space=space)
        except Exception:
            pass    # observability never blocks detection
        return decisions

    # -- the loop -------------------------------------------------------------
    def _tuner_for(self, space: str):
        tuner = self._tuners.get(space)
        if tuner is None:
            tuner = self._tuners[space] = self._tuner_factory(space)
        return tuner

    def tuners(self) -> Dict[str, object]:
        """The per-space tuner cache (factory-trained ones included) — a
        caller that rebuilds controllers (the CLI watch loop) carries this
        across instances instead of re-training per poll."""
        return dict(self._tuners)

    # -- epoch budget ---------------------------------------------------------
    def _budget_blocks(self, tick: Optional[int]) -> Optional[str]:
        """Why the budget refuses a retune right now (None = go ahead)."""
        cfg = self.cfg
        if (cfg.cooldown_ticks > 0 and tick is not None
                and self._last_retune_tick is not None
                and tick - self._last_retune_tick < cfg.cooldown_ticks):
            return (f"cooldown: {tick - self._last_retune_tick} of "
                    f"{cfg.cooldown_ticks} ticks since the last retune")
        if cfg.max_sessions_per_window > 0:
            horizon = time.time() - cfg.session_window_s
            self._session_starts = [t for t in self._session_starts
                                    if t >= horizon]
            if len(self._session_starts) >= cfg.max_sessions_per_window:
                return (f"budget: {len(self._session_starts)} sessions in "
                        f"the last {cfg.session_window_s:.0f}s "
                        f"(cap {cfg.max_sessions_per_window})")
        return None

    def _note_session_start(self, tick: Optional[int]) -> None:
        self._session_starts.append(time.time())
        if tick is not None:
            self._last_retune_tick = tick

    # -- deferred measurements ------------------------------------------------
    def process_measurements(self, max_items: int = 2) -> int:
        """Drain a few deferred §6 top-k re-measurements (the engine calls
        this from idle decode gaps, via ``maybe_retune``'s poll).  Returns
        shapes processed; 0 when no queue/measurer is attached."""
        q, m = self.measure_queue, self.measurer
        if q is None or m is None or not len(q):
            return 0
        return q.process(m, models=serving_state().models,
                         max_items=max_items)

    # -- async plumbing -------------------------------------------------------
    def async_active(self) -> bool:
        """True while a submitted background epoch is still running."""
        th = self._async
        return th is not None and th.is_alive()

    def wait_async(self, timeout: Optional[float] = None
                   ) -> Optional[RetuneReport]:
        """Block until the in-flight background epoch (if any) finishes and
        return its report — tests and orderly shutdowns."""
        th = self._async
        if th is None:
            return None
        th.join(timeout)
        if th.is_alive():
            return None
        self._async = None
        report, self._async_report = self._async_report, None
        return report

    def _submit_async(self, decisions: Dict[str, SpaceDecision],
                      triggered: Dict[str, SpaceDecision], t0: float,
                      tick: Optional[int]) -> None:
        """Launch the epoch on a daemon thread; the poll returns at once.

        The swap at the end of the thread is the same atomic
        ``install_serving`` flip as the inline path — the polling thread
        only ever sees the old generation or the complete new one.
        """
        fleet_dir = self.fleet_dir
        if fleet_dir is not None and self.store.path is None:
            if "fleet-store" not in self._warned_pins:
                self._warned_pins.add("fleet-store")
                warnings.warn(
                    "fleet retunes need a disk-backed store (workers shard "
                    "next to it); falling back to the in-process async "
                    "session", RuntimeWarning, stacklevel=3)
            fleet_dir = None
        self._note_session_start(tick)
        self.async_submits += 1
        # perf_counter, not wall time: consumers correlate these with other
        # perf_counter stamps (the engine's per-tick times)
        self.async_submit_t = time.perf_counter()
        self.async_done_t = None
        self._async_cancel.clear()       # fresh epoch, fresh watchdog
        window = [self.async_submit_t, None]
        self.async_windows.append(window)
        # the submit→swap window as ONE detached span: begun here on the
        # submitting (decode) thread — adopting its live trace when one is
        # open, minting an always-kept id otherwise so epochs never vanish
        # from traces — and ended by the background thread at swap time
        tr = _tracer()
        epoch_span = None
        trace_id = ""
        if tr is not None:
            trace_id = tr.current_trace_id() or _TRACE.new_trace_id()
            epoch_span = tr.begin(
                "retune.epoch", trace_id=trace_id,
                spaces=",".join(sorted(triggered)),
                mode="fleet" if fleet_dir is not None else "async")

        def body():
            try:
                with self._lock:
                    if fleet_dir is not None:
                        self._async_report = self._retune_fleet(
                            decisions, triggered, t0, fleet_dir,
                            trace_id=trace_id,
                            parent_id=(epoch_span.span_id
                                       if epoch_span is not None else ""))
                    else:
                        report = self._retune(decisions, triggered, t0)
                        report.mode = "async"
                        self._async_report = report
            except Exception:   # noqa: BLE001 — a dead thread must be seen
                log.exception("async retune epoch failed")
                self._async_report = None
            finally:
                self.async_done_t = window[1] = time.perf_counter()
                if tr is not None:
                    rep = self._async_report
                    tr.end(epoch_span,
                           outcome="failed" if rep is None else "swapped",
                           tuned=0 if rep is None else rep.tuned)

        th = threading.Thread(target=body, name="tunedb-retune", daemon=True)
        self._async = th
        th.start()

    def maybe_retune(self, decisions: Optional[Dict[str, SpaceDecision]]
                     = None, *, tick: Optional[int] = None
                     ) -> Optional[RetuneReport]:
        """One poll: detect, and when triggered, tune + retrain + hot-swap.

        Returns the :class:`RetuneReport` when a triggered epoch ran, else
        ``None``.  ``decisions`` lets a caller that already ran ``check()``
        (the CLI prints them first) skip the second detection pass.
        ``tick`` is the caller's decode-tick clock — the ``cooldown_ticks``
        budget is keyed to it (no tick, no cooldown).

        In async mode a triggered poll *submits* the epoch and returns
        ``None`` immediately; the first poll after the background run
        completes returns its report.  At most one epoch is in flight.
        """
        if self.async_mode:
            if self.async_active():
                # watchdog: an epoch older than the session window is hung
                # (a stalled fleet, a wedged worker) — cancel its wait so
                # the thread publishes what landed and frees the slot
                if (self.async_submit_t is not None
                        and not self._async_cancel.is_set()
                        and time.perf_counter() - self.async_submit_t
                        > self.cfg.session_window_s):
                    self._async_cancel.set()
                    self.watchdog_cancels += 1
                    log.warning(
                        "retune watchdog: background epoch exceeded "
                        "session_window_s=%.0fs, cancelling its fleet wait",
                        self.cfg.session_window_s)
                    try:
                        from .obs.metrics import get_registry
                        get_registry().counter(
                            "tunedb_retune_watchdog_cancels_total",
                            "async retune epochs cancelled for exceeding "
                            "session_window_s").inc()
                    except Exception:
                        pass
                return None              # one in-flight epoch at a time
            done = self.wait_async()
            if done is not None:
                return done              # reap exactly once
        blocked = self._budget_blocks(tick)
        if blocked is not None:
            log.debug("retune poll skipped (%s)", blocked)
            return None
        t0 = time.time()
        if decisions is None:
            decisions = self.check()
        triggered = {s: d for s, d in decisions.items() if d.trigger}
        if not triggered:
            return None
        if self.async_mode:
            self._submit_async(decisions, triggered, t0, tick)
            return None
        with self._lock:
            self._note_session_start(tick)
            return self._retune(decisions, triggered, t0)

    def force_retune(self, decisions: Optional[Dict[str, SpaceDecision]]
                     = None) -> Optional[RetuneReport]:
        """Retune every space with novel hot window shapes, thresholds be
        damned (the CLI ``retune --force`` path).  Always inline."""
        with self._lock:
            t0 = time.time()
            if decisions is None:
                decisions = self.check()
            forced = {s: d for s, d in decisions.items() if d.novel_shapes}
            if not forced:
                return None
            self._note_session_start(None)
            return self._retune(decisions, forced, t0)

    def _retune(self, decisions: Dict[str, SpaceDecision],
                triggered: Dict[str, SpaceDecision], t0: float
                ) -> RetuneReport:
        cfg = self.cfg
        state = serving_state()
        sessions: Dict[str, object] = {}
        affected_backends = set()
        for space, dec in triggered.items():
            tuner = self._tuner_for(space)
            session_fp = backend_fingerprint(tuner.backend)
            if (state.fingerprint is not None
                    and session_fp != state.fingerprint
                    and (space, session_fp) not in self._warned_pins):
                self._warned_pins.add((space, session_fp))
                warnings.warn(
                    f"retune session for {space!r} commits records under "
                    f"backend {session_fp!r}, which the active fingerprint "
                    f"pin {state.fingerprint!r} will never serve from the "
                    "exact tier; give the controller a tuner measuring "
                    "under the pinned backend", RuntimeWarning, stacklevel=3)
            session = TuningSession(
                tuner, self.store, None, workers=cfg.workers,
                remeasure=cfg.remeasure, skip_existing=True,
                collect_samples=True, source="retune")
            report = session.run(shapes=dec.novel_shapes,
                                 verbose=self.verbose)
            sessions[space] = report
            # never re-plan these shapes: if their records cannot serve
            # (pin mismatch) or their jobs keep failing, retriggering every
            # poll would churn generations without changing anything
            for inputs in dec.novel_shapes:
                self._attempted.add((space, input_key(space, inputs)))
            affected_backends.add((space, session_fp))
            if self.verbose:
                print(f"[retune:{space}] {dec.reason}: drift {dec.drift:.2f}, "
                      f"untuned mass {dec.untuned_mass:.2f} -> "
                      f"{report.tuned} tuned, {report.failed} failed")
        return self._finish_epoch(decisions, sessions, affected_backends,
                                  t0, state, "inline")

    def _retune_fleet(self, decisions: Dict[str, SpaceDecision],
                      triggered: Dict[str, SpaceDecision], t0: float,
                      fleet_dir, trace_id: str = "",
                      parent_id: str = "") -> RetuneReport:
        """Run one triggered epoch through the fleet bus.

        Jobs are published as lease files for external worker processes;
        the coordinator requeues crashed workers' leases, merges completed
        shards into the serving store (provenance intact), and only then —
        merge done, regressors retrained — does the epoch publish the new
        generation.  A fleet that never finishes within ``fleet_timeout_s``
        still publishes whatever landed (partial progress serves; the
        leftover jobs stay queued for the fleet to finish later).
        """
        from .fleet import Coordinator, FleetJob

        state = serving_state()
        coord = Coordinator(fleet_dir, self.store,
                            lease_timeout_s=self.fleet_lease_timeout_s)
        # markers left by PREVIOUS fleet runs of this directory must not be
        # credited (or debited) to this epoch's plan
        stale_done = {m.name for m in coord.fleet.done.glob("*.json")}
        stale_failed = {m.name for m in coord.fleet.failed.glob("*.json")}
        jobs: List[FleetJob] = []
        for space, dec in triggered.items():
            for inputs in dec.novel_shapes:
                # the telemetry count rides in the job file so workers can
                # claim the hottest shapes first (priority-aware claiming)
                # trace_id rides in the job JSON: the worker opens its
                # tuning-session root with it, so its spans link back to
                # this coordinator epoch in the merged trace
                jobs.append(FleetJob(space=space, inputs=dict(inputs),
                                     count=self.telemetry.count(space, inputs),
                                     source="retune", trace_id=trace_id))
                self._attempted.add((space, input_key(space, inputs)))
        published = coord.publish(jobs)
        if self.verbose:
            print(f"[retune:fleet] published {published} job(s) "
                  f"-> {fleet_dir}")
        finished = coord.wait(timeout_s=self.fleet_timeout_s,
                              poll_s=self.fleet_poll_s,
                              verbose=self.verbose,
                              cancel=self._async_cancel)
        if not finished:
            warnings.warn(
                f"fleet retune timed out after {self.fleet_timeout_s:.0f}s "
                f"with {coord.outstanding()} job(s) outstanding; publishing "
                "the records that did land", RuntimeWarning, stacklevel=2)
            # the stragglers stay queued for the fleet — let them COUNT AS
            # NOVEL again, so the epoch that their traffic eventually
            # re-triggers republishes (idempotent) and merges their
            # late-landing shard records into the serving store
            done_now = {m.name for m in coord.fleet.done.glob("*.json")}
            fail_now = {m.name for m in coord.fleet.failed.glob("*.json")}
            for job in jobs:
                name = f"{job.job_id}.json"
                if name not in done_now and name not in fail_now:
                    self._attempted.discard(
                        (job.space, input_key(job.space, job.inputs)))
        tr = _tracer()
        merge_span = (tr.begin("fleet.merge", trace_id=trace_id,
                               parent_id=parent_id, jobs=published)
                      if tr is not None and trace_id else None)
        coord.poll()                     # final merge after the last worker
        if merge_span is not None:
            tr.end(merge_span, outstanding=coord.outstanding())
        if (state.fingerprint is not None and coord.affected
                and all(b != state.fingerprint for _, b in coord.affected)
                and ("fleet", state.fingerprint) not in self._warned_pins):
            self._warned_pins.add(("fleet", state.fingerprint))
            warnings.warn(
                f"fleet workers committed records under backends "
                f"{sorted({b for _, b in coord.affected})}, none matching "
                f"the active fingerprint pin {state.fingerprint!r}; the "
                "exact tier will not serve them", RuntimeWarning,
                stacklevel=2)
        # synthesize per-space session reports from the fleet outcome so
        # RetuneReport reads the same in both execution modes (only markers
        # that appeared during THIS epoch count)
        done_ids = {p.stem for p in coord.fleet.done.glob("*.json")
                    if p.name not in stale_done}
        failed_ids = {p.stem for p in coord.fleet.failed.glob("*.json")
                      if p.name not in stale_failed}
        sessions: Dict[str, object] = {}
        for space, dec in triggered.items():
            ids = [j.job_id for j in jobs if j.space == space]
            tuned = sum(1 for i in ids if i in done_ids)
            failed = sum(1 for i in ids if i in failed_ids)
            sessions[space] = SessionReport(
                space=space, jobs=len(ids), tuned=tuned,
                skipped=len(dec.novel_shapes) - len(ids), failed=failed,
                wall_s=time.time() - t0)
        affected = set(coord.affected)
        report = self._finish_epoch(decisions, sessions, affected, t0,
                                    state, "fleet")
        coord.report(retrained=report.retrained, wall_s=report.wall_s)
        return report

    def _finish_epoch(self, decisions: Dict[str, SpaceDecision],
                      sessions: Dict[str, object],
                      affected_backends: Set[Tuple[str, str]], t0: float,
                      entry_state, mode: str) -> RetuneReport:
        cfg = self.cfg
        if not any(r.tuned for r in sessions.values()):
            # nothing landed — there is no serving change to publish, so do
            # NOT flip the generation (that would invalidate every memo for
            # a no-op); just open the next epoch so this window is spent
            self._baseline = self.telemetry.snapshot()
            self.epoch += 1
            self.last_report = RetuneReport(
                epoch=self.epoch, generation=entry_state.generation,
                decisions=decisions, sessions=sessions, retrained=[],
                wall_s=time.time() - t0, mode=mode)
            self._observe_epoch(self.last_report)
            return self.last_report

        fresh = None
        retrained: List[str] = []
        if cfg.retrain:                  # at least one session tuned here
            from .model import train_models
            for space, fp in sorted(affected_backends):
                part = train_models(
                    self.store, space=space, backend=fp,
                    min_samples=cfg.min_train_samples,
                    epochs=cfg.train_epochs, seed=cfg.seed)
                fresh = part if fresh is None else fresh.merged_with(part)
            if fresh is not None and not len(fresh):
                fresh = None
            if fresh is not None:
                retrained = [f"{s}/{b}" for s, b in sorted(fresh.models)]

        # ONE atomic generation flip: store + models; the fingerprint pin is
        # deliberately left untouched.  Merge and swap against the state
        # CURRENT at swap time, not the entry snapshot — the session/retrain
        # above can take a while, and an install_serving made meanwhile
        # (say, a new Engine retargeting the store) must not be silently
        # reverted by this read-modify-write.
        cur = serving_state()
        if cur.store is not None and cur.store is not self.store:
            warnings.warn(
                "serving was retargeted to a different store during the "
                "retune; skipping the hot-swap (the session results stay in "
                "the controller's store)", RuntimeWarning, stacklevel=3)
            new_state = cur
        else:
            new_models = cur.models
            if fresh is not None:
                new_models = (cur.models.merged_with(fresh)
                              if cur.models is not None else fresh)
                if self.models_dir:
                    new_models.save(self.models_dir)
            sentry = None
            if cfg.sentry is not None:
                from .obs.sentry import RegressionSentry
                sentry = RegressionSentry(noise_margin=cfg.sentry)
            new_state = install_serving(store=self.store, models=new_models,
                                        sentry=sentry)
            if new_state.generation == cur.generation:
                # the sentry refused the swap: the epoch's records stay in
                # the store (a later, faster remeasure supersedes them) but
                # the previous generation keeps serving
                self.sentry_blocked += 1
            else:
                self.retunes += 1
                if cfg.publish and new_state.plan is not None:
                    self._publish_plan(new_state.plan)
        self._baseline = self.telemetry.snapshot()
        self.epoch += 1
        self.last_report = RetuneReport(
            epoch=self.epoch, generation=new_state.generation,
            decisions=decisions, sessions=sessions, retrained=retrained,
            wall_s=time.time() - t0, mode=mode)
        self._observe_epoch(self.last_report)
        return self.last_report

    def _publish_plan(self, plan) -> None:
        """Push the freshly-swapped generation's plan to the golden-plan
        registry (cfg.publish) so follower replicas pull it.  Best-effort
        by design: the LOCAL swap already happened; a refused or failed
        publish (racing append made the plan stale, unwritable registry)
        warns and counts, and the next successful epoch publishes again."""
        try:
            from .plans import PlanRegistry
            manifest = PlanRegistry(self.cfg.publish).publish(
                plan, store=self.store)
            self.published_plans += 1
            if self.verbose:
                print(f"[retune] published plan generation "
                      f"{manifest.generation} ({manifest.n_entries} "
                      f"entries) -> {self.cfg.publish}")
        except Exception as e:
            self.publish_failed += 1
            warnings.warn(f"plan publish to {self.cfg.publish} failed: {e}",
                          RuntimeWarning, stacklevel=3)

    # -- reporting ------------------------------------------------------------
    def _observe_epoch(self, report: RetuneReport) -> None:
        """Append to the bounded history + publish the epoch's metrics.

        Latency is submit→swap: for an async epoch the perf_counter window
        the submit stamped (the fleet/background round-trip the ISSUE
        cares about), for an inline epoch the epoch's own wall time.
        """
        tuned = [s for s, r in report.sessions.items()
                 if getattr(r, "tuned", 0)]
        latency = report.wall_s
        if (self.async_submit_t is not None and self.async_done_t is not None
                and self.async_done_t >= self.async_submit_t):
            latency = self.async_done_t - self.async_submit_t
        self.history.append({
            "epoch": report.epoch,
            "generation": report.generation,
            "mode": report.mode,
            "tuned": tuned,
            "retrained": list(report.retrained),
            "wall_s": report.wall_s,
            "latency_s": latency,
            "sentry_blocked": self.sentry_blocked,
            "t": time.time(),
        })
        try:
            from .obs.metrics import get_registry
            reg = get_registry()
            reg.counter("tunedb_retune_epochs_total",
                        "controller epochs closed (tuned or not)").inc(
                            mode=report.mode)
            if tuned:
                reg.counter("tunedb_retunes_total",
                            "epochs that committed new tuning records").inc(
                                mode=report.mode)
                reg.histogram("tunedb_retune_latency_seconds",
                              "retune submit->swap latency").observe(latency)
            reg.gauge("tunedb_retune_sentry_blocked",
                      "serving swaps refused by the regression sentry").set(
                          self.sentry_blocked)
        except Exception:
            pass    # observability never blocks the retune loop

    def stats(self) -> Dict[str, object]:
        return {
            "epoch": self.epoch,
            "checks": self.checks,
            "retunes": self.retunes,
            # "fleet" when the controller reads a FleetTelemetryView —
            # retunes then trigger off aggregated multi-replica mass
            "telemetry_scope": getattr(self.telemetry, "scope", "process"),
            "sentry_blocked": self.sentry_blocked,
            "published_plans": self.published_plans,
            "publish_failed": self.publish_failed,
            "history": list(self.history),
            "generation": serving_state().generation,
            "config": dataclasses.asdict(self.cfg),
            "measure": (None if self.measurer is None else {
                **self.measurer.stats(),
                "queue": (None if self.measure_queue is None
                          else self.measure_queue.stats()),
            }),
            "async": {
                "enabled": self.async_mode,
                "fleet_dir": (None if self.fleet_dir is None
                              else str(self.fleet_dir)),
                "submits": self.async_submits,
                "in_flight": self.async_active(),
                "watchdog_cancels": self.watchdog_cancels,
            },
            "last": None if self.last_report is None else {
                "epoch": self.last_report.epoch,
                "tuned": self.last_report.tuned,
                "retrained": list(self.last_report.retrained),
                "wall_s": self.last_report.wall_s,
                "mode": self.last_report.mode,
            },
        }
