"""Continuous retuning: the telemetry -> tune -> train -> serve loop, closed.

PR 1 built the pieces as manual CLI steps (mine telemetry, run a session,
train models, restart serving with the new artifacts); this module runs them
*in-process*.  A :class:`RetuneController` keeps an epoch baseline snapshot
of the global :class:`~repro.tunedb.telemetry.ShapeTelemetry` and, on every
``maybe_retune()`` poll (the serving engine calls it every
``ServeConfig.retune_interval`` decode ticks):

  1. **detect** — ``telemetry.diff(baseline)`` yields per-space hot-shape
     mass drift (total-variation distance between the baseline distribution
     and the traffic window since it) plus the window's shapes; the
     controller adds *untuned hot mass* — the fraction of window calls
     landing on shapes with no store record under the active fingerprint.
     This is the staleness signal MLKAPS (arXiv:2501.05811) samples
     adaptively against, and that the model-driven adaptive-library line
     (arXiv:1806.07060) closes with an online update loop.
  2. **tune** — when drift or untuned mass crosses its threshold (and the
     window has enough calls to mean anything), a
     :class:`~repro.tunedb.session.TuningSession` runs over the window's
     novel hot shapes and commits ``source="retune"`` records (plus the
     measured top-k as training samples).
  3. **train** — the affected ``(space, backend)`` regressors retrain from
     the grown measurement log (``train_models``); untouched regressors are
     carried over unchanged.
  4. **swap** — ``install_serving`` flips the process-global
     (store, ModelSet, fingerprint) to a new generation in ONE atomic
     assignment: dispatch never sees a torn store/model pair, per-shape
     memos are invalidated, and the warn-once degradation latches re-arm.
     The baseline snapshot advances, opening the next epoch.

The controller is deliberately synchronous and cheap when idle: a no-trigger
poll is a snapshot diff over the telemetry dict (microseconds against a
multi-millisecond decode tick — bench_retune.py gates it at <2%).
"""

from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from typing import Callable, Dict, List, Mapping, Optional

from .session import TuningSession, backend_fingerprint
from .store import RecordStore, input_key, install_serving, serving_state
from .telemetry import ShapeTelemetry, SpaceDrift, get_telemetry


def _default_tuner_factory(space_name: str):
    """Train a small input-aware tuner on demand (serving processes that
    enable retuning without shipping one in).  Deliberately modest sizes:
    the controller runs inside a serving loop, not a tuning fleet."""
    from repro.core.backend import SimulatedTPUBackend
    from repro.core.space import SPACES
    from repro.core.tuner import InputAwareTuner
    return InputAwareTuner.train(
        SPACES[space_name], n_samples=4000, hidden=(32, 64, 32), epochs=12,
        backend=SimulatedTPUBackend(noise=0.02), seed=0)


@dataclasses.dataclass(frozen=True)
class RetuneConfig:
    """Thresholds and session/retrain knobs for the retune loop."""

    drift_threshold: float = 0.25        # TV distance that counts as a shift
    untuned_mass_threshold: float = 0.5  # window mass on record-less shapes
    min_calls: int = 32                  # window calls before a space is judged
    top_k_shapes: int = 4                # novel hot shapes per session
    workers: int = 2
    remeasure: bool = True               # session top-k re-measurement (§6)
    retrain: bool = True                 # retrain regressors after a session
    min_train_samples: int = 24
    train_epochs: int = 20
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class SpaceDecision:
    """One space's verdict for one controller poll."""

    space: str
    drift: float
    untuned_mass: float
    window_calls: int
    novel_shapes: List[Dict[str, int]]   # hot window shapes with no record
    trigger: bool
    reason: str                          # "drift" | "untuned" | ""


@dataclasses.dataclass
class RetuneReport:
    """What one triggered retune epoch did."""

    epoch: int                           # epoch number this retune OPENED
    generation: int                      # serving generation after the swap
    decisions: Dict[str, SpaceDecision]
    sessions: Dict[str, object]          # space -> SessionReport
    retrained: List[str]                 # "space/backend" regressors replaced
    wall_s: float = 0.0

    @property
    def tuned(self) -> int:
        return sum(r.tuned for r in self.sessions.values())


class RetuneController:
    """Drift-triggered sessions + retrain + atomic serving hot-swap.

    ``tuners`` maps space name -> a trained tuner (anything with ``.search``
    / ``.backend`` / ``.space``, i.e. ``InputAwareTuner``); spaces without
    one fall back to ``tuner_factory`` (trained once, cached).  ``store``
    is where sessions commit — normally the installed serving store, so
    exact-tier hits appear the moment a record lands.  ``models_dir`` (when
    set) persists every retrained ModelSet, keeping on-disk artifacts in
    step with the hot-swapped in-process ones.
    """

    def __init__(self, store: RecordStore, *,
                 telemetry: Optional[ShapeTelemetry] = None,
                 tuners: Optional[Mapping[str, object]] = None,
                 tuner_factory: Optional[Callable[[str], object]] = None,
                 models_dir=None,
                 cfg: Optional[RetuneConfig] = None,
                 baseline=None,
                 verbose: bool = False):
        self.store = store
        self.telemetry = telemetry if telemetry is not None else get_telemetry()
        self.cfg = cfg or RetuneConfig()
        self.models_dir = models_dir
        self.verbose = verbose
        self._tuners: Dict[str, object] = dict(tuners or {})
        self._tuner_factory = tuner_factory or _default_tuner_factory
        self._lock = threading.Lock()        # one retune at a time
        self.epoch = 0
        self.checks = 0                      # polls (triggered or not)
        self.retunes = 0                     # epochs that actually retuned
        self.last_report: Optional[RetuneReport] = None
        # (space, key) pairs a session already worked on: a shape whose
        # committed record can never serve (e.g. a fingerprint pin the
        # session backend does not match) must not re-trigger forever
        self._attempted: set = set()
        self._warned_pins: set = set()
        # `baseline` lets the CLI resume an epoch across processes (a saved
        # TelemetrySnapshot); in-process callers start at "now"
        self._baseline = (baseline if baseline is not None
                          else self.telemetry.snapshot())

    # -- detection ------------------------------------------------------------
    def _decide(self, drift: SpaceDrift, fingerprint: Optional[str]
                ) -> SpaceDecision:
        cfg = self.cfg
        untuned_calls = 0
        novel: List[Dict[str, int]] = []
        for inputs, count in drift.window_shapes:
            if not self.store.contains(drift.space, inputs,
                                       backend=fingerprint):
                untuned_calls += count      # honest mass, attempted or not
                if (len(novel) < cfg.top_k_shapes
                        and (drift.space, input_key(drift.space, inputs))
                        not in self._attempted):
                    novel.append(dict(inputs))
        mass = (untuned_calls / drift.window_calls
                if drift.window_calls else 0.0)
        reason = ""
        if drift.window_calls >= cfg.min_calls and novel:
            if drift.drift >= cfg.drift_threshold:
                reason = "drift"
            elif mass >= cfg.untuned_mass_threshold:
                reason = "untuned"
        return SpaceDecision(
            space=drift.space, drift=drift.drift, untuned_mass=mass,
            window_calls=drift.window_calls, novel_shapes=novel,
            trigger=bool(reason), reason=reason)

    def reset_baseline(self) -> None:
        """Open a fresh epoch at "now" without retuning — callers that know
        the accumulated telemetry is already served (warm-up, benches)."""
        self._baseline = self.telemetry.snapshot()

    def check(self) -> Dict[str, SpaceDecision]:
        """Detection only — no sessions, no swap, baseline untouched."""
        self.checks += 1
        fp = serving_state().fingerprint
        return {space: self._decide(drift, fp)
                for space, drift in self.telemetry.diff(self._baseline).items()}

    # -- the loop -------------------------------------------------------------
    def _tuner_for(self, space: str):
        tuner = self._tuners.get(space)
        if tuner is None:
            tuner = self._tuners[space] = self._tuner_factory(space)
        return tuner

    def tuners(self) -> Dict[str, object]:
        """The per-space tuner cache (factory-trained ones included) — a
        caller that rebuilds controllers (the CLI watch loop) carries this
        across instances instead of re-training per poll."""
        return dict(self._tuners)

    def maybe_retune(self, decisions: Optional[Dict[str, SpaceDecision]]
                     = None) -> Optional[RetuneReport]:
        """One poll: detect, and when triggered, tune + retrain + hot-swap.

        Returns the :class:`RetuneReport` when a triggered epoch ran, else
        ``None``.  ``decisions`` lets a caller that already ran ``check()``
        (the CLI prints them first) skip the second detection pass.
        """
        with self._lock:
            t0 = time.time()
            if decisions is None:
                decisions = self.check()
            triggered = {s: d for s, d in decisions.items() if d.trigger}
            if not triggered:
                return None
            return self._retune(decisions, triggered, t0)

    def force_retune(self, decisions: Optional[Dict[str, SpaceDecision]]
                     = None) -> Optional[RetuneReport]:
        """Retune every space with novel hot window shapes, thresholds be
        damned (the CLI ``retune --force`` path)."""
        with self._lock:
            t0 = time.time()
            if decisions is None:
                decisions = self.check()
            forced = {s: d for s, d in decisions.items() if d.novel_shapes}
            if not forced:
                return None
            return self._retune(decisions, forced, t0)

    def _retune(self, decisions: Dict[str, SpaceDecision],
                triggered: Dict[str, SpaceDecision], t0: float
                ) -> RetuneReport:
        cfg = self.cfg
        state = serving_state()
        sessions: Dict[str, object] = {}
        affected_backends = set()
        for space, dec in triggered.items():
            tuner = self._tuner_for(space)
            session_fp = backend_fingerprint(tuner.backend)
            if (state.fingerprint is not None
                    and session_fp != state.fingerprint
                    and (space, session_fp) not in self._warned_pins):
                self._warned_pins.add((space, session_fp))
                warnings.warn(
                    f"retune session for {space!r} commits records under "
                    f"backend {session_fp!r}, which the active fingerprint "
                    f"pin {state.fingerprint!r} will never serve from the "
                    "exact tier; give the controller a tuner measuring "
                    "under the pinned backend", RuntimeWarning, stacklevel=3)
            session = TuningSession(
                tuner, self.store, None, workers=cfg.workers,
                remeasure=cfg.remeasure, skip_existing=True,
                collect_samples=True, source="retune")
            report = session.run(shapes=dec.novel_shapes,
                                 verbose=self.verbose)
            sessions[space] = report
            # never re-plan these shapes: if their records cannot serve
            # (pin mismatch) or their jobs keep failing, retriggering every
            # poll would churn generations without changing anything
            for inputs in dec.novel_shapes:
                self._attempted.add((space, input_key(space, inputs)))
            affected_backends.add((space, session_fp))
            if self.verbose:
                print(f"[retune:{space}] {dec.reason}: drift {dec.drift:.2f}, "
                      f"untuned mass {dec.untuned_mass:.2f} -> "
                      f"{report.tuned} tuned, {report.failed} failed")

        if not any(r.tuned for r in sessions.values()):
            # nothing landed — there is no serving change to publish, so do
            # NOT flip the generation (that would invalidate every memo for
            # a no-op); just open the next epoch so this window is spent
            self._baseline = self.telemetry.snapshot()
            self.epoch += 1
            self.last_report = RetuneReport(
                epoch=self.epoch, generation=state.generation,
                decisions=decisions, sessions=sessions, retrained=[],
                wall_s=time.time() - t0)
            return self.last_report

        fresh = None
        retrained: List[str] = []
        if cfg.retrain:                  # at least one session tuned here
            from .model import train_models
            for space, fp in sorted(affected_backends):
                part = train_models(
                    self.store, space=space, backend=fp,
                    min_samples=cfg.min_train_samples,
                    epochs=cfg.train_epochs, seed=cfg.seed)
                fresh = part if fresh is None else fresh.merged_with(part)
            if fresh is not None and not len(fresh):
                fresh = None
            if fresh is not None:
                retrained = [f"{s}/{b}" for s, b in sorted(fresh.models)]

        # ONE atomic generation flip: store + models; the fingerprint pin is
        # deliberately left untouched.  Merge and swap against the state
        # CURRENT at swap time, not the entry snapshot — the session/retrain
        # above can take a while, and an install_serving made meanwhile
        # (say, a new Engine retargeting the store) must not be silently
        # reverted by this read-modify-write.
        cur = serving_state()
        if cur.store is not None and cur.store is not self.store:
            warnings.warn(
                "serving was retargeted to a different store during the "
                "retune; skipping the hot-swap (the session results stay in "
                "the controller's store)", RuntimeWarning, stacklevel=3)
            new_state = cur
        else:
            new_models = cur.models
            if fresh is not None:
                new_models = (cur.models.merged_with(fresh)
                              if cur.models is not None else fresh)
                if self.models_dir:
                    new_models.save(self.models_dir)
            new_state = install_serving(store=self.store, models=new_models)
            self.retunes += 1
        self._baseline = self.telemetry.snapshot()
        self.epoch += 1
        self.last_report = RetuneReport(
            epoch=self.epoch, generation=new_state.generation,
            decisions=decisions, sessions=sessions, retrained=retrained,
            wall_s=time.time() - t0)
        return self.last_report

    # -- reporting ------------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        return {
            "epoch": self.epoch,
            "checks": self.checks,
            "retunes": self.retunes,
            "generation": serving_state().generation,
            "config": dataclasses.asdict(self.cfg),
            "last": None if self.last_report is None else {
                "epoch": self.last_report.epoch,
                "tuned": self.last_report.tuned,
                "retrained": list(self.last_report.retrained),
                "wall_s": self.last_report.wall_s,
            },
        }
