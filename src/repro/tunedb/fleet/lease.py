"""Filesystem lease protocol: the coordination bus of the tuning fleet.

No network dependency, no database, no daemon: a shared directory IS the
queue, exactly like the record store is a shared JSONL file.  The MITuna
production shape (a coordinator leasing jobs to a worker fleet writing
independent shards) reduced to portable filesystem primitives:

  * **publish** — the coordinator writes one ``queue/<job_id>.json`` per
    :class:`FleetJob` (atomic tmp+rename, so a reader never sees a torn
    job file).  ``job_id`` is derived from the (space, inputs) key, so
    re-publishing the same plan is idempotent.
  * **claim-by-atomic-rename** — a worker claims a job by renaming
    ``queue/<id>.json`` to ``leases/<id>.json``.  ``os.rename`` of one
    source path succeeds for exactly one racer (POSIX); every loser gets
    ``FileNotFoundError`` and moves on to the next queue entry.
  * **heartbeat** — the claiming worker refreshes the lease file's mtime
    (``os.utime``) while it tunes.  A heartbeat on a vanished lease tells
    the worker it lost the job (expired and reclaimed).
  * **expiry** — the coordinator requeues any lease whose mtime is older
    than ``lease_timeout_s``: a crashed (or wedged) worker's job goes back
    to the queue with ``attempts`` bumped, and lands in ``failed/`` once
    ``max_attempts`` is exhausted.
  * **completion** — the worker appends its records to its own shard store
    (``<store>.shards/<worker_id>.jsonl`` — no write contention by
    construction), writes a ``done/<id>.json`` marker, then drops the
    lease.  The done marker is authoritative: a lease or queue entry whose
    job is already done is swept, never re-run.
  * **drain** — a ``DRAIN`` marker tells workers to exit once the queue is
    empty instead of idling for more work.

Durability contract: every transition is ATOMIC (rename / tmp+replace) but
not fsynced — the bus recovers worker/coordinator *process* crashes (the
appends and markers are already in the kernel when the next transition
depends on them), while a host power loss may drop in-flight jobs' markers
or results.  That is the right trade for a tuning fleet: lost work is
re-queued by lease expiry or republished by the next ``fleet start``; the
authoritative parent store re-establishes its own fsync durability at
merge time.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
from typing import Dict, List, Mapping, Optional, Tuple

from .. import chaos
from ..chaos import retry_io
from ..store import input_key, normalize_inputs

FLEET_SCHEMA_VERSION = 1

QUEUE, LEASES, DONE, FAILED = "queue", "leases", "done", "failed"
MANIFEST, DRAIN_MARKER, REPORT = "manifest.json", "DRAIN", "report.json"


def job_id_for(space: str, inputs: Mapping[str, int]) -> str:
    """Stable job id: one job per (space, inputs) — republish is idempotent."""
    return f"{space}-{input_key(space, inputs)}"


@dataclasses.dataclass(frozen=True)
class FleetJob:
    """One leased unit of fleet work: tune one input shape."""

    space: str
    inputs: Dict[str, int]
    count: int = 0                      # telemetry frequency (priority hint)
    source: str = "fleet"               # what the committed record's tag says
    attempts: int = 0                   # times this job was leased so far
    created_at: float = 0.0
    # trace id of the coordinator epoch that published this job ("" = not
    # traced): a worker adopts it for its tuning-session spans, so the
    # merged trace links worker tuning back to the submit→swap window.
    # from_json filters unknown fields, so old/new job files interoperate.
    trace_id: str = ""

    @property
    def job_id(self) -> str:
        return job_id_for(self.space, self.inputs)

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["schema_version"] = FLEET_SCHEMA_VERSION
        return json.dumps(d, sort_keys=True)

    @classmethod
    def from_json(cls, line: str) -> "FleetJob":
        d = json.loads(line)
        if not isinstance(d, dict) or "space" not in d or "inputs" not in d:
            raise ValueError(f"not a FleetJob: {line[:80]!r}")
        if int(d.get("schema_version", 1)) > FLEET_SCHEMA_VERSION:
            raise ValueError(
                f"job schema v{d['schema_version']} > v{FLEET_SCHEMA_VERSION}")
        known = {f.name for f in dataclasses.fields(cls)}
        d = {k: v for k, v in d.items() if k in known}
        d["inputs"] = normalize_inputs(d["inputs"])
        return cls(**d)


def _atomic_write(path: pathlib.Path, text: str, *,
                  site: str = "fleet.write") -> None:
    io = chaos._IO
    tmp = path.with_name(path.name + ".tmp")
    if io is None:
        tmp.write_text(text, encoding="utf-8")
        os.replace(tmp, path)
    else:
        io.write_text(tmp, text, site)
        io.replace(tmp, path, site + ".replace")


class FleetDir:
    """One fleet's coordination directory: queue/leases/done/failed + manifest.

    Every mutation is a single atomic filesystem operation (rename or
    tmp+replace), so any number of worker processes and one coordinator can
    share the directory with no locks.  All methods tolerate concurrent
    mutation: a file that vanishes mid-operation means another process got
    there first, never an error.
    """

    def __init__(self, root: os.PathLike):
        self.root = pathlib.Path(root)
        self.queue = self.root / QUEUE
        self.leases = self.root / LEASES
        self.done = self.root / DONE
        self.failed = self.root / FAILED
        # job-name -> (mtime_ns, telemetry count): a worker's claim loop
        # must not re-parse every queue file on every attempt.  Keyed by
        # mtime so a REPUBLISHED job (fleet start --retune, a later drift
        # epoch) with a new count invalidates its stale entry — a stat per
        # entry instead of a read+parse
        self._priority_cache: Dict[str, Tuple[int, int]] = {}

    # -- lifecycle -----------------------------------------------------------
    def init(self, store_path: os.PathLike, *, lease_timeout_s: float = 30.0,
             max_attempts: int = 3) -> Dict[str, object]:
        """Create the directory layout and the manifest (idempotent)."""
        for d in (self.root, self.queue, self.leases, self.done, self.failed):
            d.mkdir(parents=True, exist_ok=True)
        manifest = {
            "schema_version": FLEET_SCHEMA_VERSION,
            "store": str(pathlib.Path(store_path).resolve()),
            "lease_timeout_s": float(lease_timeout_s),
            "max_attempts": int(max_attempts),
            "created_at": time.time(),
        }
        path = self.root / MANIFEST
        if path.exists():               # resume: the existing bus wins
            return self.manifest()
        _atomic_write(path, json.dumps(manifest, sort_keys=True))
        return manifest

    def manifest(self) -> Dict[str, object]:
        path = self.root / MANIFEST
        if not path.exists():
            raise FileNotFoundError(
                f"{path}: not a fleet directory (run `fleet start` first)")
        return json.loads(path.read_text())

    def store_path(self) -> pathlib.Path:
        return pathlib.Path(str(self.manifest()["store"]))

    def shard_dir(self) -> pathlib.Path:
        """Per-worker shard stores live NEXT TO the parent store."""
        store = self.store_path()
        return store.with_name(store.name + ".shards")

    def shard_path(self, worker_id: str) -> pathlib.Path:
        return self.shard_dir() / f"{worker_id}.jsonl"

    def telemetry_dir(self) -> pathlib.Path:
        """Per-worker cumulative telemetry dumps ride the bus itself.

        ``<fleet>/telemetry/<worker_id>/<epoch>.json`` — written by
        :class:`~repro.tunedb.telemetry.TelemetryExporter`, aggregated by
        the coordinator's :meth:`Coordinator.global_telemetry`.
        """
        return self.root / "telemetry"

    # -- publish -------------------------------------------------------------
    def publish(self, job: FleetJob, *, force: bool = False) -> bool:
        """Queue one job unless it is already anywhere in the lifecycle.

        ``force`` re-queues a job whose previous run already completed or
        failed (the ``fleet start --retune`` semantics): the stale terminal
        marker is dropped first.  A job currently queued or leased is never
        duplicated, force or not.
        """
        jid = job.job_id
        for d in (self.queue, self.leases):
            if (d / f"{jid}.json").exists():
                return False
        for d in (self.done, self.failed):
            marker = d / f"{jid}.json"
            if marker.exists():
                if not force:
                    return False
                marker.unlink(missing_ok=True)
        if job.created_at <= 0:
            job = dataclasses.replace(job, created_at=time.time())
        retry_io(lambda: _atomic_write(self.queue / f"{jid}.json",
                                       job.to_json(), site="lease.publish"),
                 site="lease.publish")
        return True

    # -- claim / heartbeat (worker side) --------------------------------------
    def claim(self) -> Optional[Tuple[FleetJob, pathlib.Path]]:
        """Claim the hottest available queue entry by atomic rename.

        Candidates are ordered by the telemetry ``count`` the coordinator
        wrote into each job file, hottest first (job-id order breaks ties,
        so count-less plans keep the old deterministic behavior): the
        shapes serving traffic hits most get tuned — and merged back into
        the store — before the long tail.  The priority read is advisory
        only; the CLAIM is still the atomic rename, so racing workers
        contending for the same hot entry resolve exactly as before (one
        winner, losers move down their list).

        Returns (job, lease_path), or None when the queue is empty (or every
        entry was snatched by a faster racer — indistinguishable, by design).
        """
        entries: List[Tuple[int, str]] = []
        try:
            for p in self.queue.iterdir():
                if p.suffix != ".json":
                    continue
                try:
                    mtime = p.stat().st_mtime_ns
                except FileNotFoundError:
                    continue            # claimed under us: move on
                cached = self._priority_cache.get(p.name)
                if cached is None or cached[0] != mtime:
                    count = 0           # fresh or rewritten: one parse
                    try:
                        d = json.loads(p.read_text())
                        count = int(d.get("count", 0))
                    except (ValueError, TypeError, OSError):
                        pass            # vanished or garbage: lowest priority
                    if len(self._priority_cache) > 65536:
                        self._priority_cache.clear()
                    cached = self._priority_cache[p.name] = (mtime, count)
                entries.append((-cached[1], p.name))
        except FileNotFoundError:
            return None
        io = chaos._IO
        for _, name in sorted(entries):
            src, dst = self.queue / name, self.leases / name
            try:
                # freshen BEFORE the rename: rename preserves mtime, and a
                # job that sat queued longer than the lease timeout must
                # not be born expired (reclaimed out of the claimant's
                # hands before it can heartbeat)
                if io is None:
                    os.utime(src)
                    os.rename(src, dst)
                else:
                    retry_io(lambda: io.utime(src, "lease.claim.utime"),
                             site="lease.claim.utime")
                    retry_io(lambda: io.rename(src, dst, "lease.claim"),
                             site="lease.claim")
            except FileNotFoundError:
                continue                # lost the race for this entry
            except OSError:
                continue                # still failing after retries: next
            # a transient read error must NOT be treated as "job vanished":
            # only a parse failure proves garbage.  On a persistent read
            # error the lease is LEFT IN PLACE — never unlinked — so lease
            # expiry requeues the job instead of dropping it on the floor.
            try:
                reader = (dst.read_text if io is None
                          else lambda: io.read_text(dst, "lease.claim.read"))
                job = FleetJob.from_json(retry_io(reader,
                                                  site="lease.claim.read"))
            except ValueError:
                dst.unlink(missing_ok=True)      # foreign garbage: drop it
                continue
            except FileNotFoundError:
                continue                # reclaimed/completed under us
            except OSError:
                continue                # transient burst: expiry requeues it
            try:
                # the claim is the first heartbeat; a transient failure here
                # is survivable — the heartbeat loop retries momentarily
                if io is None:
                    os.utime(dst)
                else:
                    io.utime(dst, "lease.claim.heartbeat")
            except OSError:
                pass
            return job, dst
        return None

    def heartbeat(self, lease_path: pathlib.Path) -> bool:
        """Refresh the lease mtime; False means the lease was reclaimed.

        Only a VANISHED lease reports False (the job was reclaimed); a
        transient I/O error is retried and, if it persists, reported True —
        the lease file still exists, and claiming "reclaimed" would make
        the worker abandon work that lease expiry may never actually take
        away."""
        io = chaos._IO
        try:
            op = ((lambda: os.utime(lease_path)) if io is None
                  else (lambda: io.utime(lease_path, "lease.heartbeat")))
            retry_io(op, site="lease.heartbeat")
            return True
        except FileNotFoundError:
            return False
        except OSError:
            return lease_path.exists()

    # -- completion / failure (worker side) ------------------------------------
    def complete(self, job: FleetJob, lease_path: pathlib.Path,
                 meta: Mapping[str, object]) -> bool:
        """Mark a job done (marker first, then drop the lease).

        The done marker is written BEFORE the lease is released: a crash
        between the two leaves a lease that the sweeper removes on sight of
        the marker, never a completed job that gets re-run.  Returns False
        when the lease was already reclaimed — the work still counts (the
        shard has the records; merge is newest-wins) but the marker credit
        goes to whichever execution finished first.
        """
        marker = self.done / f"{job.job_id}.json"
        already = marker.exists()
        if not already:
            payload = dict(meta)
            payload.update(job_id=job.job_id, space=job.space,
                           inputs=job.inputs, finished_at=time.time())
            retry_io(lambda: _atomic_write(
                marker, json.dumps(payload, sort_keys=True),
                site="lease.complete"), site="lease.complete")
        try:
            io = chaos._IO
            if io is None:
                lease_path.unlink(missing_ok=True)
            else:
                io.unlink(lease_path, "lease.complete.release",
                          missing_ok=True)
        except OSError:
            pass        # marker already durable: the sweeper drops the lease
        return not already

    def fail(self, job: FleetJob, lease_path: pathlib.Path, error: str, *,
             max_attempts: int) -> str:
        """Requeue a failed job (attempts bumped) or bury it in ``failed/``.

        Returns ``"requeued"`` or ``"failed"``.
        """
        attempts = job.attempts + 1
        if attempts >= max_attempts:
            retry_io(lambda: _atomic_write(
                self.failed / f"{job.job_id}.json", json.dumps({
                    "job": json.loads(job.to_json()), "attempts": attempts,
                    "error": error, "failed_at": time.time()},
                    sort_keys=True), site="lease.fail"), site="lease.fail")
            outcome = "failed"
        else:
            requeued = dataclasses.replace(job, attempts=attempts)
            retry_io(lambda: _atomic_write(
                self.queue / f"{job.job_id}.json", requeued.to_json(),
                site="lease.requeue"), site="lease.requeue")
            outcome = "requeued"
        lease_path.unlink(missing_ok=True)
        return outcome

    # -- expiry / sweep (coordinator side) -------------------------------------
    def reclaim_expired(self, *, lease_timeout_s: float,
                        max_attempts: int) -> List[str]:
        """Return crashed workers' jobs to the queue; bury the hopeless.

        A lease whose job already has a done marker is simply swept (the
        worker died between marker and release).  Returns the job ids
        requeued or failed this pass.
        """
        now = time.time()
        touched: List[str] = []
        io = chaos._IO
        for lease in sorted(self.leases.glob("*.json")):
            jid = lease.stem
            if (self.done / lease.name).exists():
                lease.unlink(missing_ok=True)      # finished, stale lease
                continue
            try:
                age = now - lease.stat().st_mtime
            except FileNotFoundError:
                continue                           # released under us
            if age <= lease_timeout_s:
                continue
            # transient read errors are retried, and a persistent one LEAVES
            # the lease for the next pass — only a parse failure (genuine
            # garbage) unlinks, so an EIO burst cannot silently destroy a
            # queued job
            try:
                reader = (lease.read_text if io is None
                          else lambda: io.read_text(lease,
                                                    "lease.reclaim.read"))
                job = FleetJob.from_json(retry_io(reader,
                                                  site="lease.reclaim.read"))
            except ValueError:
                lease.unlink(missing_ok=True)      # unparseable: job lost
                continue
            except FileNotFoundError:
                continue                           # released under us
            except OSError:
                continue                           # retry on the next pass
            self.fail(job, lease, f"lease expired after {age:.1f}s",
                      max_attempts=max_attempts)
            touched.append(jid)
        return touched

    def sweep_done(self) -> int:
        """Drop queue entries whose job completed anyway (an expiry requeue
        racing a slow-but-successful worker).  Returns entries removed."""
        n = 0
        for entry in self.queue.glob("*.json"):
            if (self.done / entry.name).exists():
                entry.unlink(missing_ok=True)
                n += 1
        return n

    # -- drain ----------------------------------------------------------------
    def request_drain(self) -> None:
        (self.root / DRAIN_MARKER).touch()

    def clear_drain(self) -> None:
        """Publishing new work revives a drained fleet: without this, a
        directory that was ever drained would turn every later worker away
        at startup forever."""
        (self.root / DRAIN_MARKER).unlink(missing_ok=True)

    def draining(self) -> bool:
        return (self.root / DRAIN_MARKER).exists()

    # -- inspection ------------------------------------------------------------
    def _count(self, d: pathlib.Path) -> int:
        try:
            return sum(1 for p in d.iterdir() if p.suffix == ".json")
        except FileNotFoundError:
            return 0

    def counts(self) -> Dict[str, int]:
        return {state: self._count(d) for state, d in
                ((QUEUE, self.queue), (LEASES, self.leases),
                 (DONE, self.done), (FAILED, self.failed))}

    def outstanding(self) -> int:
        """Jobs not yet terminally done/failed."""
        c = self.counts()
        return c[QUEUE] + c[LEASES]

    def done_meta(self) -> List[Dict[str, object]]:
        out = []
        for p in sorted(self.done.glob("*.json")):
            try:
                out.append(json.loads(p.read_text()))
            except (ValueError, OSError):
                continue
            out[-1].setdefault("job_id", p.stem)
        return out

    def status(self) -> Dict[str, object]:
        now = time.time()
        lease_ages = {}
        for p in sorted(self.leases.glob("*.json")):
            try:
                lease_ages[p.stem] = round(now - p.stat().st_mtime, 3)
            except FileNotFoundError:
                continue
        shards = {}
        shard_dir = self.shard_dir()
        if shard_dir.is_dir():
            for p in sorted(shard_dir.glob("*.jsonl")):
                shards[p.stem] = sum(1 for line in
                                     p.read_text().splitlines() if line)
        return {
            "root": str(self.root),
            "store": str(self.store_path()),
            "counts": self.counts(),
            "draining": self.draining(),
            "lease_age_s": lease_ages,
            "shard_records": shards,
        }
