"""Fleet coordinator: publish a tuning plan, merge shards, report.

The coordinator owns the authoritative :class:`~repro.tunedb.store.
RecordStore` and the fleet directory; workers own their shards.  Its loop:

  1. **publish** — one lease-file job per planned shape (idempotent by
     job id, so re-publishing a plan after a restart queues only what is
     not already queued, leased, done, or failed).
  2. **poll** — sweep queue entries whose job completed anyway, requeue
     expired leases (crashed workers), and *incrementally* merge every
     shard's new records into the parent store.  Each shard has a cursor
     file (``merged/<worker_id>.json``) recording how many records were
     consumed, so a coordinator restart resumes the merge exactly where
     the last one stopped — shards are append-only, like the store.
  3. **finalize** — when nothing is outstanding (or the deadline hits),
     retrain the regressors of every (space, backend) the merge touched
     and write a :class:`FleetReport` next to the manifest.

Merging preserves provenance: a record keeps its original ``source`` tag
(``fleet``/``retune``/``sample`` — the model harvest and audits key on it)
and gains ``merged_from=<worker_id>`` as the lineage of the merge itself.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
from typing import Dict, Iterable, List, Mapping, Optional, Set, Tuple

from .. import chaos
from ..chaos import retry_io
from ..store import (DispatchPlan, RecordStore, SAMPLE_SOURCE, TuneRecord,
                     shape_key)
from ..telemetry import FleetTelemetryView, ShapeTelemetry
from ..obs.sentry import RegressionSentry
from .lease import REPORT, FleetDir, FleetJob, _atomic_write

MERGED = "merged"                       # per-shard merge-cursor directory


@dataclasses.dataclass
class FleetReport:
    """What one fleet run accomplished, written to ``<fleet>/report.json``."""

    published: int = 0
    done: int = 0
    failed: int = 0
    requeued: int = 0                   # expiry reclaims observed this run
    merged_records: int = 0             # serving records folded into the store
    merged_samples: int = 0             # training samples folded in
    sentry_blocked: int = 0             # shard records refused as regressions
    retrained: List[str] = dataclasses.field(default_factory=list)
    workers: List[str] = dataclasses.field(default_factory=list)
    wall_s: float = 0.0
    jobs_per_s: float = 0.0

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


class Coordinator:
    """Publish :class:`FleetJob` leases, merge worker shards, retrain.

    ``store`` must be disk-backed: the manifest records its path so worker
    processes — which share nothing with the coordinator but the filesystem
    — can place their shards next to it.  Opening a coordinator on an
    existing fleet directory (``store=None``) resumes from the manifest.
    """

    def __init__(self, fleet_dir: os.PathLike,
                 store: Optional[RecordStore] = None, *,
                 lease_timeout_s: float = 30.0, max_attempts: int = 3,
                 sentry_margin: Optional[float] = None):
        self.fleet = FleetDir(fleet_dir)
        if store is not None:
            if store.path is None:
                raise ValueError(
                    "fleet coordination needs a disk-backed parent store "
                    "(workers derive their shard paths from it)")
            self.store = store
            self.fleet.init(store.path, lease_timeout_s=lease_timeout_s,
                            max_attempts=max_attempts)
            # resuming an existing bus with a DIFFERENT store would merge
            # into a file no worker shards next to — refuse, don't diverge
            manifest_store = self.fleet.store_path()
            if manifest_store != pathlib.Path(store.path).resolve():
                raise ValueError(
                    f"fleet dir {self.fleet.root} was created for store "
                    f"{manifest_store}, not {store.path}; use a fresh "
                    "fleet directory (or omit `store` to resume)")
        else:
            self.store = RecordStore.open(self.fleet.store_path())
        m = self.fleet.manifest()
        self.lease_timeout_s = float(m["lease_timeout_s"])
        self.max_attempts = int(m["max_attempts"])
        self._merged_dir = self.fleet.root / MERGED
        self._merged_dir.mkdir(parents=True, exist_ok=True)
        self.published = 0
        self.requeued = 0
        self.merged_records = 0
        self.merged_samples = 0
        # merge-time regression gate: a shard record that would supersede a
        # FASTER serving record (beyond the margin) is refused before it
        # reaches the parent store — None disables the gate
        self.sentry = (None if sentry_margin is None
                       else RegressionSentry(noise_margin=sentry_margin))
        self.sentry_blocked = 0
        # (space, backend) pairs the merge touched — the retrain set
        self.affected: Set[Tuple[str, str]] = set()
        # shard sizes at the last merge: an unchanged file is not re-parsed
        # (the poll loop runs merge_completed every few hundred ms)
        self._shard_sizes: Dict[str, int] = {}

    # -- publish ---------------------------------------------------------------
    def publish(self, jobs: Iterable, *, source: str = "fleet",
                force: bool = False) -> int:
        """Queue jobs (session ``TuneJob``s, ``FleetJob``s, or
        ``(space, inputs, count)`` tuples).  Returns how many were new.
        ``force`` re-queues jobs a PREVIOUS fleet run already finished
        (their stale done/failed markers are dropped) — the re-tune path.
        """
        n = 0
        for job in jobs:
            if isinstance(job, FleetJob):
                fj = job
            elif isinstance(job, tuple):
                space, inputs, count = job
                fj = FleetJob(space=space, inputs=dict(inputs),
                              count=int(count), source=source)
            else:                       # session.TuneJob duck type
                fj = FleetJob(space=job.space, inputs=dict(job.inputs),
                              count=int(getattr(job, "count", 0)),
                              source=source)
            if self.fleet.publish(fj, force=force):
                n += 1
        if n:
            # new work revives a previously drained directory — workers
            # must not keep turning away at the stale DRAIN marker
            self.fleet.clear_drain()
        self.published += n
        return n

    def plan_from_telemetry(self, telemetry=None, *,
                            spaces: Optional[List[str]] = None, top_k: int = 8,
                            backend: Optional[str] = None,
                            skip_existing: bool = True,
                            source: str = "fleet") -> List[FleetJob]:
        """Mine the top-K hot shapes per space into publishable jobs,
        skipping shapes the parent store already serves (under ``backend``,
        when the fleet tunes for a pinned fingerprint).  With no
        ``telemetry`` argument the FLEET-GLOBAL view is mined: every
        replica's latest cumulative dump on the bus, aggregated by
        :meth:`global_telemetry` — so published plans track fleet-wide
        hot-shape mass, not one process's window."""
        if telemetry is None:
            telemetry = self.global_telemetry()
        jobs: List[FleetJob] = []
        for space in (spaces if spaces is not None else telemetry.spaces()):
            for inputs, count in telemetry.hot_shapes(space, top_k):
                if skip_existing and self.store.contains(space, inputs,
                                                         backend=backend):
                    continue
                jobs.append(FleetJob(space=space, inputs=dict(inputs),
                                     count=count, source=source))
        return jobs

    # -- fleet-global telemetry ------------------------------------------------
    def global_telemetry(self, *, local: Optional[ShapeTelemetry] = None,
                         refresh_s: float = 0.0) -> FleetTelemetryView:
        """The aggregated fleet-wide telemetry view.

        Folds every worker's latest cumulative dump under
        ``<fleet>/telemetry/`` (written by
        :class:`~repro.tunedb.telemetry.TelemetryExporter`) into one
        :class:`FleetTelemetryView` with per-replica provenance
        (``.replicas()``: worker -> {epoch, calls, age_s}).  ``local``
        defaults to an EMPTY telemetry: the coordinator is an aggregator,
        not a traffic source, so the view is pure bus state unless a
        serving process hands in its own counters.
        """
        return FleetTelemetryView(
            self.fleet.telemetry_dir(),
            local=local if local is not None else ShapeTelemetry(),
            refresh_s=refresh_s)

    def telemetry_provenance(self) -> Dict[str, Dict[str, object]]:
        """Per-replica dump provenance off the bus, for report/status."""
        return self.global_telemetry().replicas()

    @staticmethod
    def _shape_bucket(space: str, inputs: Mapping[str, object]) -> tuple:
        """Affinity-class signature: (space, log2-bucketed dims).

        Shapes whose dimensions share log2 buckets want the same kernel
        configs (the store's nearest index uses the same quantization), so
        they belong on the same replica — routing them together keeps each
        replica's plan small AND its hit rate high.
        """
        sig = []
        for k in sorted(inputs):
            v = inputs[k]
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                sig.append((k, str(v)))
            elif v > 0:
                sig.append((k, int(v).bit_length()))
            else:
                sig.append((k, int(v)))
        return (space, tuple(sig))

    def partition_hot_shapes(self, n_replicas: int, *, telemetry=None,
                             top_k: int = 32,
                             spaces: Optional[List[str]] = None
                             ) -> List[List[Tuple[str, Dict[str, int], int]]]:
        """Partition the global hot set into per-replica affinity classes.

        Hot shapes group into buckets by :meth:`_shape_bucket` signature;
        buckets are assigned hottest-first to the replica with the least
        accumulated call mass (greedy LPT) — so class mass stays balanced
        while same-bucket shapes land on the same replica.  Returns one
        ``[(space, inputs, count), ...]`` class per replica.
        """
        if n_replicas <= 0:
            raise ValueError(f"n_replicas must be positive, got {n_replicas}")
        if telemetry is None:
            telemetry = self.global_telemetry()
        buckets: Dict[tuple, List] = {}
        for space in (spaces if spaces is not None else telemetry.spaces()):
            for inputs, count in telemetry.hot_shapes(space, top_k):
                b = buckets.setdefault(self._shape_bucket(space, inputs),
                                       [0, []])
                b[0] += count
                b[1].append((space, dict(inputs), int(count)))
        classes: List[List[Tuple[str, Dict[str, int], int]]] = [
            [] for _ in range(n_replicas)]
        loads = [0] * n_replicas
        for _sig, (mass, shapes) in sorted(
                buckets.items(), key=lambda kv: (-kv[1][0], repr(kv[0]))):
            i = min(range(n_replicas), key=lambda j: (loads[j], j))
            loads[i] += mass
            classes[i].extend(shapes)
        return classes

    def publish_replica_plans(self, registry_root: os.PathLike,
                              n_replicas: int, *, telemetry=None,
                              fingerprint: Optional[str] = None,
                              models_dir: Optional[os.PathLike] = None,
                              top_k: int = 32) -> List[Dict[str, object]]:
        """Publish one SMALL specialized plan per replica affinity class.

        Each class's shapes resolve through the usual cascade (store exact
        -> model predict -> nearest) and freeze into a per-replica
        :class:`DispatchPlan` published under
        ``<registry_root>/replica-<i>/`` via the existing
        :class:`~repro.tunedb.plans.PlanRegistry` — replicas follow their
        own registry with the same :class:`PlanFollower` protocol.  Unlike
        ``publish_plan`` (one global plan covering every serving record),
        a replica plan holds ONLY its class: that is what keeps per-replica
        plans small and affinity-routing hit rates high.  Returns one
        summary dict per replica.
        """
        from ..plans import PlanRegistry
        classes = self.partition_hot_shapes(n_replicas, telemetry=telemetry,
                                            top_k=top_k)
        models = self.fresh_models()
        if models is None and models_dir \
                and pathlib.Path(models_dir).is_dir():
            from ..model import ModelSet
            loaded = ModelSet.load(models_dir)
            if len(loaded):
                models = loaded
        predict = getattr(models, "predict", None) if models is not None \
            else None
        out: List[Dict[str, object]] = []
        root = pathlib.Path(registry_root)
        for i, shapes in enumerate(classes):
            table: Dict[tuple, Tuple[Dict[str, int], str]] = {}
            for space, inputs, _count in shapes:
                cfg, tier = None, ""
                rec = self.store.get(space, inputs, backend=fingerprint)
                if rec is not None:
                    cfg, tier = rec.config, "exact"
                if cfg is None and callable(predict):
                    got = predict(space, inputs, backend=fingerprint)
                    if got is not None:
                        cfg, tier = got[0], "model"
                if cfg is None:
                    rec = self.store.nearest(space, inputs,
                                             backend=fingerprint, count=False)
                    if rec is not None:
                        cfg, tier = rec.config, "nearest"
                if cfg is not None:
                    table[(space, shape_key(inputs))] = (dict(cfg), tier)
            name = f"replica-{i}"
            manifest = None
            if table:
                plan = DispatchPlan(generation=0, fingerprint=fingerprint,
                                    store_version=self.store.version,
                                    table=table)
                manifest = PlanRegistry(root / name).publish(
                    plan, store=self.store)
            out.append({
                "replica": name, "registry": str(root / name),
                "shapes": len(shapes), "entries": len(table),
                "mass": sum(c for _, _, c in shapes),
                "generation": (manifest.generation if manifest is not None
                               else None)})
        return out

    # -- shard merge -----------------------------------------------------------
    def _cursor(self, worker_id: str) -> Tuple[int, int]:
        """(records merged, byte offset consumed) for one shard."""
        path = self._merged_dir / f"{worker_id}.json"
        if not path.exists():
            return 0, 0
        try:
            d = json.loads(path.read_text())
            return int(d["merged"]), int(d.get("offset", -1))
        except (ValueError, KeyError, TypeError):
            return 0, 0

    def _save_cursor(self, worker_id: str, merged: int, offset: int) -> None:
        retry_io(lambda: _atomic_write(
            self._merged_dir / f"{worker_id}.json",
            json.dumps({"merged": merged, "offset": offset,
                        "updated_at": time.time()}),
            site="coord.cursor"), site="coord.cursor")

    def merge_completed(self) -> Tuple[int, int]:
        """Fold every shard's NEW records into the parent store.

        Incremental and idempotent: each shard's cursor advances past the
        records consumed, so calling this in a poll loop (or after a
        coordinator restart) merges each record exactly once.  The serving
        index stays newest-wins regardless — a job that ran twice (expiry
        requeue racing a slow worker) lands twice in the log but serves
        once.  Returns (serving records, samples) merged this call.
        """
        shard_dir = self.fleet.shard_dir()
        if not shard_dir.is_dir():
            return 0, 0
        n_recs = n_samples = 0
        # one durability barrier per merge PASS, not per record: a poll loop
        # fsyncing the parent store per merged record stalls the workers'
        # own shard writes on the shared filesystem
        fsync_prev, self.store.fsync = self.store.fsync, False
        try:
            n_recs, n_samples = self._merge_pass(shard_dir)
        finally:
            self.store.fsync = fsync_prev
            if fsync_prev and n_recs + n_samples:
                self.store.sync()
        self.merged_records += n_recs
        self.merged_samples += n_samples
        return n_recs, n_samples

    def _sentry_refuses(self, rec: TuneRecord) -> bool:
        """Merge-time regression gate: True when ``rec`` would supersede a
        faster serving record beyond the sentry's noise margin.  Training
        samples pass (they never serve); refused records are counted and
        published to the metrics registry but never reach the store."""
        if self.sentry is None or rec.source == SAMPLE_SOURCE:
            return False
        cur = self.store._index.get((rec.backend, rec.key))
        # created_at<=0 would be stamped "now" by add() — it WOULD supersede
        if cur is None or (0 < rec.created_at < cur.created_at):
            return False                 # no record displaced: nothing to gate
        if not self.sentry.regresses(cur.tflops, rec.tflops):
            return False
        self.sentry_blocked += 1
        try:
            from ..obs.metrics import get_registry
            get_registry().counter(
                "tunedb_sentry_regressions_total",
                "records flagged as regressed by the sentry").inc(
                    where="merge")
        except Exception:
            pass
        return True

    def _merge_pass(self, shard_dir) -> Tuple[int, int]:
        n_recs = n_samples = 0
        io = chaos._IO
        for shard_path in sorted(shard_dir.glob("*.jsonl")):
            worker_id = shard_path.stem
            try:
                size = shard_path.stat().st_size
            except FileNotFoundError:
                continue
            if size == self._shard_sizes.get(worker_id):
                continue                 # nothing appended since last merge
            count, offset = self._cursor(worker_id)
            # shards are append-only: seek past what previous passes
            # consumed and parse only the NEW bytes (a poll loop re-decoding
            # a growing shard from byte 0 every pass is O(n^2) over the
            # run).  A pre-offset cursor (older format, offset<0) pays one
            # full parse and skips the already-merged record count.
            start, skip = (offset, 0) if offset >= 0 else (0, count)
            try:
                if io is not None:
                    io.probe("coord.merge.read")
                with shard_path.open("rb") as fh:
                    fh.seek(start)
                    chunk = fh.read()
            except FileNotFoundError:
                continue                 # compacted under us
            except OSError:
                continue                 # transient: size entry stays stale,
                                         # so the next poll re-reads the shard
            upto = chunk.rfind(b"\n")    # only COMPLETE lines are consumable
            if upto < 0:
                self._shard_sizes[worker_id] = size
                continue                 # torn tail only: next append re-reads
            fresh: List[TuneRecord] = []
            for raw in chunk[:upto].split(b"\n"):
                raw = raw.strip()
                if not raw:
                    continue
                try:
                    fresh.append(TuneRecord.from_json(raw.decode("utf-8")))
                except (ValueError, TypeError, KeyError,
                        UnicodeDecodeError):
                    continue             # foreign garbage line: skipped
            for rec in fresh[skip:]:
                if self._sentry_refuses(rec):
                    continue             # consumed (cursor advances), refused
                self.store.add(dataclasses.replace(rec,
                                                   merged_from=worker_id))
                if rec.source == SAMPLE_SOURCE:
                    n_samples += 1
                else:
                    n_recs += 1
                    self.affected.add((rec.space, rec.backend))
            new_count = (len(fresh) if offset < 0
                         else count + len(fresh))
            self._save_cursor(worker_id, new_count, start + upto + 1)
            # only after the cursor is durable: an exception above leaves
            # the size entry stale, so the next poll re-reads the shard
            # instead of stranding its records behind an "unchanged" skip
            self._shard_sizes[worker_id] = size
        return n_recs, n_samples

    # -- shard GC --------------------------------------------------------------
    def compact_shards(self) -> List[str]:
        """Archive cursor-complete merged shards out of ``<store>.shards/``.

        A shard whose merge cursor has consumed every byte contributes
        nothing further to the bus — it only makes every later merge pass
        stat it and every ``status()`` re-count it, forever.  ``fleet drain
        --compact`` moves such shards into ``<store>.shards/archive/`` and
        drops their cursor files (a returning worker with the same id
        starts a FRESH shard at offset 0, which the reset cursor then
        merges from the top — keeping a stale cursor would silently skip
        its first records).

        Only safe once no worker can still be appending — the drain path
        runs it after the queue and leases are empty.  Shards with
        unmerged bytes (including a torn tail) or legacy pre-offset
        cursors are left alone.  Returns the worker ids archived.
        """
        shard_dir = self.fleet.shard_dir()
        archived: List[str] = []
        if not shard_dir.is_dir():
            return archived
        archive = shard_dir / "archive"
        for shard_path in sorted(shard_dir.glob("*.jsonl")):
            worker_id = shard_path.stem
            try:
                size = shard_path.stat().st_size
            except FileNotFoundError:
                continue
            _count, offset = self._cursor(worker_id)
            if offset < 0 or offset < size:
                continue                 # legacy cursor / unmerged bytes
            archive.mkdir(parents=True, exist_ok=True)
            dest = archive / shard_path.name
            if dest.exists():            # same id archived before: version it
                n = 1
                while (archive / f"{worker_id}.{n}.jsonl").exists():
                    n += 1
                dest = archive / f"{worker_id}.{n}.jsonl"
            os.replace(shard_path, dest)
            (self._merged_dir / f"{worker_id}.json").unlink(missing_ok=True)
            self._shard_sizes.pop(worker_id, None)
            archived.append(worker_id)
        return archived

    # -- the poll loop ---------------------------------------------------------
    def poll(self) -> Dict[str, object]:
        """One maintenance pass: sweep, reclaim expired leases, merge.

        Deliberately cheap enough for a sub-second loop: directory entry
        counts only — the full ``FleetDir.status()`` (which reads every
        shard to count records) is for the CLI, not this path.
        """
        self.fleet.sweep_done()
        reclaimed = self.fleet.reclaim_expired(
            lease_timeout_s=self.lease_timeout_s,
            max_attempts=self.max_attempts)
        self.requeued += len(reclaimed)
        recs, samples = self.merge_completed()
        return {"counts": self.fleet.counts(),
                "draining": self.fleet.draining(),
                "reclaimed": reclaimed, "merged_now": recs + samples}

    def outstanding(self) -> int:
        return self.fleet.outstanding()

    def wait(self, *, timeout_s: Optional[float] = None,
             poll_s: float = 0.25, verbose: bool = False,
             cancel=None) -> bool:
        """Poll until every published job is done or failed (True), or the
        deadline passes (False).  Merging happens as shards fill, not at
        the end — a long fleet's records serve as soon as they land.

        ``cancel`` (a ``threading.Event``) aborts the wait early: the
        retune controller's watchdog sets it when an async epoch outlives
        its window, so a wedged fleet never pins the submitting process."""
        deadline = None if timeout_s is None else time.time() + timeout_s
        while True:
            if cancel is not None and cancel.is_set():
                return False
            status = self.poll()
            left = self.outstanding()
            if verbose:
                c = status["counts"]
                print(f"[fleet] queue {c['queue']}, leases {c['leases']}, "
                      f"done {c['done']}, failed {c['failed']}")
            if left == 0:
                return True
            if deadline is not None and time.time() >= deadline:
                return False
            time.sleep(poll_s)

    # -- retrain + report ------------------------------------------------------
    def retrain(self, *, models_dir: Optional[os.PathLike] = None,
                min_samples: int = 24, epochs: int = 20,
                seed: int = 0) -> List[str]:
        """Retrain the regressors of every (space, backend) the merge
        touched; persist artifacts when ``models_dir`` is given.  Returns
        the ``space/backend`` keys retrained."""
        if not self.affected:
            return []
        from ..model import train_models
        fresh = None
        for space, backend in sorted(self.affected):
            part = train_models(self.store, space=space, backend=backend,
                                min_samples=min_samples, epochs=epochs,
                                seed=seed)
            fresh = part if fresh is None else fresh.merged_with(part)
        if fresh is None or not len(fresh):
            return []
        if models_dir:
            fresh.save(models_dir)
        self._fresh_models = fresh
        return [f"{s}/{b}" for s, b in sorted(fresh.models)]

    def fresh_models(self):
        """The ModelSet the last ``retrain()`` produced (None before)."""
        return getattr(self, "_fresh_models", None)

    def publish_plan(self, registry_dir: os.PathLike, *,
                     fingerprint: Optional[str] = None,
                     models_dir: Optional[os.PathLike] = None,
                     telemetry=None, hot_k: Optional[int] = None):
        """Compile the merged store into a golden :class:`DispatchPlan` and
        publish it to a plan registry for serving replicas to follow.

        The distribution half of the fleet story: workers collected records
        onto the bus, the merge folded them into ``self.store`` — this
        ships the result.  Models come from the last ``retrain()`` when one
        ran, else from ``models_dir``; the staleness gate cannot trip here
        because the plan is compiled from the store's CURRENT version.
        With no ``telemetry`` argument, the fleet-global aggregated view
        (when any replica has dumped onto the bus) pre-resolves the GLOBAL
        hot set into the plan.  Returns the published
        :class:`~repro.tunedb.plans.PlanManifest`.
        """
        from ..plans import PlanRegistry
        from ..store import PLAN_HOT_K, compile_plan
        if telemetry is None:
            fleet_view = self.global_telemetry()
            if fleet_view.total() > 0:
                telemetry = fleet_view
        models = self.fresh_models()
        if models is None and models_dir and pathlib.Path(models_dir).is_dir():
            from ..model import ModelSet
            loaded = ModelSet.load(models_dir)
            if len(loaded):
                models = loaded
        plan = compile_plan(self.store, models, fingerprint,
                            telemetry=telemetry,
                            hot_k=PLAN_HOT_K if hot_k is None else hot_k)
        if plan is None or not len(plan):
            raise ValueError(
                "nothing to publish: the merged store has no serving "
                "records" + (f" under fingerprint {fingerprint!r}"
                             if fingerprint else ""))
        return PlanRegistry(registry_dir).publish(plan, store=self.store)

    def report(self, *, retrained: Optional[List[str]] = None,
               wall_s: float = 0.0, write: bool = True) -> FleetReport:
        counts = self.fleet.counts()
        workers = sorted({str(m.get("worker_id", "?"))
                          for m in self.fleet.done_meta()})
        rep = FleetReport(
            published=self.published, done=counts["done"],
            failed=counts["failed"], requeued=self.requeued,
            merged_records=self.merged_records,
            merged_samples=self.merged_samples,
            sentry_blocked=self.sentry_blocked,
            retrained=list(retrained or []), workers=workers,
            wall_s=wall_s,
            jobs_per_s=(counts["done"] / wall_s if wall_s > 0 else 0.0))
        if write:
            _atomic_write(self.fleet.root / REPORT,
                          json.dumps(rep.to_dict(), indent=1,
                                     sort_keys=True))
        self._publish_metrics(counts)
        return rep

    def _publish_metrics(self, counts: Dict[str, int]) -> None:
        """Shard-merge progress + queue state into the metrics registry."""
        try:
            from ..obs.metrics import get_registry
            reg = get_registry()
            jobs = reg.gauge("tunedb_fleet_jobs",
                             "fleet bus job counts by state")
            for state in ("queue", "leases", "done", "failed"):
                jobs.set(counts.get(state, 0), state=state)
            merged = reg.gauge("tunedb_fleet_merged_records",
                               "records folded into the parent store")
            merged.set(self.merged_records, kind="serving")
            merged.set(self.merged_samples, kind="sample")
            reg.gauge("tunedb_fleet_requeued",
                      "expiry reclaims observed this run").set(self.requeued)
            reg.gauge("tunedb_fleet_sentry_blocked",
                      "shard records refused by the merge sentry").set(
                          self.sentry_blocked)
        except Exception:
            pass    # observability never blocks the fleet loop


def run_fleet_inline(fleet_dir: os.PathLike, store: RecordStore,
                     jobs: Iterable, *, n_workers: int = 2,
                     tuners: Optional[Mapping[str, object]] = None,
                     tuner_factory=None, source: str = "fleet",
                     lease_timeout_s: float = 30.0,
                     timeout_s: Optional[float] = None,
                     remeasure: bool = True) -> FleetReport:
    """Convenience harness: coordinator + N thread workers in one process.

    The protocol is identical to the multi-process fleet (same directory,
    same leases, same shards) — this just saves tests and benchmarks the
    process management.  Workers share ``tuners`` (train-once).
    """
    import threading

    from .worker import Worker

    t0 = time.time()
    coord = Coordinator(fleet_dir, store, lease_timeout_s=lease_timeout_s)
    coord.publish(jobs, source=source)
    coord.fleet.request_drain()          # one plan, then everybody goes home
    workers = [Worker(fleet_dir, worker_id=f"w{i}", tuners=tuners,
                      tuner_factory=tuner_factory, poll_s=0.02,
                      remeasure=remeasure)
               for i in range(n_workers)]
    threads = [threading.Thread(target=w.run) for w in workers]
    for t in threads:
        t.start()
    coord.wait(timeout_s=timeout_s, poll_s=0.1)
    for t in threads:
        t.join()
    coord.poll()                         # final merge after the last worker
    return coord.report(wall_s=time.time() - t0)
