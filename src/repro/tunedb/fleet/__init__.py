"""repro.tunedb.fleet — distributed tuning: coordinator + sharded workers.

The single-process :class:`~repro.tunedb.session.TuningSession` scaled out
MITuna-style over a shared filesystem (no network, no daemon):

  lease.py        the coordination bus: job files claimed by atomic rename,
                  heartbeat mtime refresh, lease expiry, done markers, DRAIN
  worker.py       claim -> tune -> append to a private shard store
                  (``<store>.shards/<worker_id>.jsonl``)
  coordinator.py  publish plans, requeue crashed workers' jobs, merge shards
                  into the parent store (provenance preserved), retrain the
                  affected regressors, write a FleetReport

CLI: ``python -m repro.tunedb fleet {start,worker,status,drain}``.  The
serving loop reaches it through the RetuneController's async mode, which
submits drift-triggered plans to a fleet directory instead of tuning inline.
"""

from .coordinator import Coordinator, FleetReport, run_fleet_inline
from .lease import FleetDir, FleetJob, job_id_for
from .worker import Worker, WorkerReport, default_worker_id

__all__ = [
    "Coordinator", "FleetReport", "run_fleet_inline",
    "FleetDir", "FleetJob", "job_id_for",
    "Worker", "WorkerReport", "default_worker_id",
]
