"""Fleet worker: claim leased jobs, tune, append to a private shard store.

A :class:`Worker` is the unit the fleet scales by: each one claims jobs from
the shared :class:`~repro.tunedb.fleet.lease.FleetDir` (claim-by-atomic-
rename, so two workers can never run the same lease), tunes the shape with
its per-space tuner, and appends the resulting records to its OWN shard
store — ``<store>.shards/<worker_id>.jsonl`` — so the fleet's write paths
never contend on one file.  The coordinator merges shards into the parent
store; a worker never touches the parent.

While a job runs, a daemon heartbeat thread refreshes the lease mtime every
``heartbeat_s``; a worker that dies mid-job simply stops heartbeating, and
the coordinator's expiry pass returns the job to the queue.  Workers may be
threads in one process (tests, the controller's in-process fallback) or
independent OS processes (``python -m repro.tunedb fleet worker``) — the
protocol is the filesystem either way.
"""

from __future__ import annotations

import dataclasses
import os
import socket
import threading
import time
import uuid
from typing import Callable, Dict, List, Mapping, Optional

import contextlib

from .. import chaos
from ..session import record_from_search
from ..store import RecordStore, SAMPLE_SOURCE, TuneRecord
from ..telemetry import TelemetryExporter, get_telemetry
from .lease import FleetDir, FleetJob

# lazily bound trace module (False = unavailable): the per-job probe is
# one module-attribute read, so untraced workers pay zero instrument calls
_TRACE = None
_NULL_CTX = contextlib.nullcontext()


def default_worker_id() -> str:
    """Host-unique, restart-unique id: shard files never collide."""
    return (f"{socket.gethostname()}-{os.getpid()}-{uuid.uuid4().hex[:6]}"
            .replace("/", "-"))


def _default_tuner_factory(space_name: str):
    """Self-sufficient worker: train a modest sim-backed tuner on demand."""
    from repro.core.backend import SimulatedTPUBackend
    from repro.core.space import SPACES
    from repro.core.tuner import InputAwareTuner
    return InputAwareTuner.train(
        SPACES[space_name], n_samples=4000, hidden=(32, 64, 32), epochs=12,
        backend=SimulatedTPUBackend(noise=0.02), seed=0)


@dataclasses.dataclass
class WorkerReport:
    worker_id: str
    claimed: int = 0
    tuned: int = 0
    failed: int = 0
    lost: int = 0                       # leases reclaimed out from under us
    wall_s: float = 0.0
    errors: List[str] = dataclasses.field(default_factory=list)


class Worker:
    """One fleet worker: claim -> tune -> shard-append -> done marker."""

    def __init__(self, fleet_dir: os.PathLike, *,
                 worker_id: Optional[str] = None,
                 tuners: Optional[Mapping[str, object]] = None,
                 tuner_factory: Optional[Callable[[str], object]] = None,
                 heartbeat_s: float = 2.0, poll_s: float = 0.2,
                 remeasure: bool = True, collect_samples: bool = True,
                 telemetry_export_s: float = 0.0,
                 trace_export: bool = False,
                 verbose: bool = False):
        self.fleet = FleetDir(fleet_dir)
        self.worker_id = worker_id or default_worker_id()
        self.heartbeat_s = heartbeat_s
        self.poll_s = poll_s
        self.remeasure = remeasure
        self.collect_samples = collect_samples
        # > 0: periodically dump this process's telemetry onto the bus
        # (``<fleet>/telemetry/<worker_id>/``) for the coordinator's
        # fleet-global aggregation — see Coordinator.global_telemetry
        self.telemetry_export_s = float(telemetry_export_s)
        self.exporter: Optional[TelemetryExporter] = None
        # True (the `fleet worker` CLI, i.e. process workers): dump this
        # process's finished spans to ``<fleet>/traces/<worker_id>.jsonl``
        # at end of run, for collect_fleet_spans / `tunedb trace` to merge.
        # Thread workers must leave it False — they SHARE the process
        # tracer, and the dump clears retention out from under its owner.
        self.trace_export = trace_export
        self.verbose = verbose
        self._tuners: Dict[str, object] = dict(tuners or {})
        self._tuner_factory = tuner_factory or _default_tuner_factory
        # attachment is lazy: a worker may come up BEFORE any coordinator
        # has initialized the bus (the "start workers any time" story) —
        # it idles until the manifest appears instead of crashing
        self._manifest: Optional[Dict] = None
        self.shard: Optional[RecordStore] = None
        self.report = WorkerReport(worker_id=self.worker_id)

    def _ensure_attached(self) -> bool:
        """Bind to the bus once its manifest exists; False while it's not
        a fleet directory yet."""
        if self.shard is not None:
            return True
        try:
            self._manifest = self.fleet.manifest()
        except FileNotFoundError:
            return False
        # no per-record fsync: the append reaches the kernel before the done
        # marker is written, so a crashed WORKER loses nothing, and a
        # crashed HOST is the lease-expiry/requeue case the protocol
        # recovers (the lease bus itself is atomic, not power-loss-durable;
        # the authoritative parent store re-fsyncs at merge time).
        self.shard = RecordStore(self.fleet.shard_path(self.worker_id),
                                 fsync=False)
        return True

    def _tuner_for(self, space: str):
        tuner = self._tuners.get(space)
        if tuner is None:
            tuner = self._tuners[space] = self._tuner_factory(space)
        return tuner

    # -- one job ---------------------------------------------------------------
    def _tune_job(self, job: FleetJob, lease_path) -> TuneRecord:
        """Run the tuner under a live heartbeat; commit to the shard."""
        stop = threading.Event()

        def beat():
            while not stop.wait(self.heartbeat_s):
                if not self.fleet.heartbeat(lease_path):
                    return               # lease reclaimed: stop beating
        t = threading.Thread(target=beat, daemon=True)
        t.start()
        try:
            tuner = self._tuner_for(job.space)
            result = tuner.search(job.inputs, remeasure=self.remeasure)
        finally:
            stop.set()
            t.join()
        rec = record_from_search(job.space, job.inputs, result,
                                 tuner.backend, source=job.source)
        # kill-point: tuned but nothing durable yet — a crash here loses
        # only work the lease expiry requeues
        io = chaos._IO
        if io is not None:
            io.probe("worker.tuned")
        self.shard.add(rec)
        if io is not None:
            # kill-point: the record is in the shard but no done marker —
            # the job re-runs and the merge's newest-wins index absorbs it
            io.probe("worker.appended")
        if self.collect_samples and result.measured:
            for cfg, tflops in result.measured:
                if cfg == result.best:
                    continue
                self.shard.add(TuneRecord(
                    space=job.space, inputs=dict(job.inputs),
                    config=dict(cfg), tflops=float(tflops),
                    backend=rec.backend, source=SAMPLE_SOURCE))
        return rec

    def run_one(self) -> Optional[bool]:
        """Claim and run one job.  None: nothing to claim.  True: tuned and
        marked done.  False: the job errored (requeued/buried) or the lease
        was lost to an expiry reclaim (the shard records still count)."""
        if not self._ensure_attached():
            return None                  # no bus yet: idle like empty queue
        claimed = self.fleet.claim()
        if claimed is None:
            return None
        job, lease_path = claimed
        self.report.claimed += 1
        # kill-point: claimed but untouched — classic crashed-worker case,
        # recovered by lease expiry + requeue
        io = chaos._IO
        if io is not None:
            io.probe("worker.claimed")
        t0 = time.time()
        global _TRACE
        t = _TRACE
        if t is None:
            try:
                from ..obs import trace as t
            except Exception:
                t = False
            _TRACE = t
        tr = t._TRACER if t else None   # None: untraced, zero instruments
        # adopt the coordinator's trace id from the job file — the tuning
        # session then shows up linked under its submit→swap window in the
        # merged fleet trace; an id-less job falls back to local sampling
        ctx = (tr.root("fleet.job", trace_id=job.trace_id or None,
                       space=job.space, job=job.job_id,
                       worker=self.worker_id)
               if tr is not None else _NULL_CTX)
        with ctx as sp:
            try:
                rec = self._tune_job(job, lease_path)
            except Exception as e:  # noqa: BLE001 — job isolation is the point
                err = f"{type(e).__name__}: {e}"
                outcome = self.fleet.fail(
                    job, lease_path, err,
                    max_attempts=int(self._manifest.get("max_attempts", 3)))
                self.report.failed += 1
                self.report.errors.append(f"{job.job_id}: {err} ({outcome})")
                self._count_outcome("failed")
                if sp is not None:
                    sp.attrs["outcome"] = "failed"
                return False
            if sp is not None:
                sp.attrs["outcome"] = "tuned"
                sp.attrs["tflops"] = round(float(rec.tflops), 3)
        if io is not None:
            # kill-point: between shard append and done marker — the
            # re-run-not-lost window the E19 invariant pins down
            io.probe("worker.complete")
        ok = self.fleet.complete(job, lease_path, {
            "worker_id": self.worker_id, "tflops": rec.tflops,
            "backend": rec.backend, "wall_s": round(time.time() - t0, 4),
            "trace_id": job.trace_id})
        if ok:
            self.report.tuned += 1
            if self.verbose:
                print(f"[fleet:{self.worker_id}] {job.space} {job.inputs} "
                      f"-> {rec.tflops:.1f} TFLOPS")
        else:
            self.report.lost += 1
        self._count_outcome("tuned" if ok else "lost")
        return ok

    def _count_outcome(self, outcome: str) -> None:
        """Per-process worker throughput into the metrics registry (thread
        workers share the coordinator's registry; process workers export
        their own if they ever grow a scrape endpoint)."""
        try:
            from ..obs.metrics import get_registry
            get_registry().counter(
                "tunedb_worker_jobs_total",
                "fleet jobs finished by workers in this process").inc(
                    outcome=outcome)
        except Exception:
            pass

    # -- the loop --------------------------------------------------------------
    def run(self, *, max_jobs: Optional[int] = None,
            idle_timeout_s: Optional[float] = None) -> WorkerReport:
        """Work until drained (DRAIN marker + empty queue), ``max_jobs``
        jobs are done, or the queue stays empty for ``idle_timeout_s``."""
        t0 = time.time()
        if self.telemetry_export_s > 0 and self.exporter is None:
            self.exporter = TelemetryExporter(
                get_telemetry(), self.fleet.telemetry_dir(),
                worker_id=self.worker_id,
                interval_s=self.telemetry_export_s).start()
        idle_since: Optional[float] = None
        while True:
            if max_jobs is not None and self.report.claimed >= max_jobs:
                break
            out = self.run_one()
            if out is not None:
                idle_since = None
                continue
            # empty queue: drained fleets exit, others idle-poll
            if self.fleet.draining():
                break
            now = time.time()
            if idle_since is None:
                idle_since = now
            if (idle_timeout_s is not None
                    and now - idle_since >= idle_timeout_s):
                break
            time.sleep(self.poll_s)
        if self.exporter is not None:
            self.exporter.stop()         # final dump: the window's tail lands
            self.exporter = None
        if self.trace_export:
            self._export_spans()
        self.report.wall_s = time.time() - t0
        return self.report

    def _export_spans(self) -> int:
        """Dump this process's finished spans onto the bus (JSONL, append,
        torn-tolerant on the reading side)."""
        tr = _TRACE._TRACER if _TRACE else None
        if tr is None:
            try:
                from ..obs import trace as t
            except Exception:
                return 0
            tr = t._TRACER
        if tr is None:
            return 0
        from ..obs.trace import FLEET_TRACE_DIR
        return tr.export_jsonl(
            self.fleet.root / FLEET_TRACE_DIR / f"{self.worker_id}.jsonl")
