"""Deterministic fault injection for the tunedb filesystem-bus protocols.

The repo runs six protocols over plain files — store JSONL append, fleet
lease claim-by-rename, coordinator shard merge, plan registry
publish/follow, telemetry dumps, trace dumps — and every one of them has a
hand-reasoned story for crashes, torn writes, and transient I/O errors.
This module makes those stories *testable*: a seeded :class:`FaultPlan`
arms one process-global :class:`FaultyIO` shim, and every bus touch point
routes its filesystem operations through it, so a chaos harness can inject

* **torn writes** — partial bytes land, then a simulated crash;
* **failed / duplicated renames** — the atomic step refused, or performed
  and then reported failed (the caller retries and duplicates);
* **ENOSPC / EIO** on write or fsync;
* **stale / truncated reads** — a reader sees the previous content of a
  path, or a prefix of the current one;
* **latency stalls** on any operation;
* **kill-points** — :class:`KillPoint` aborting a multi-step protocol
  between steps, exactly where a SIGKILL would land.

Zero cost disarmed: the shim is a module-level nullable (``chaos._IO``),
the same pattern as ``obs.trace._TRACER`` — every call site reads one
module attribute and, when it is ``None``, runs its exact pre-chaos code
path.  E19 (``benchmarks/bench_chaos.py``) proves the disarmed hot
dispatch path makes zero shim calls.

Determinism: one ``random.Random(seed)`` drives every injection decision,
so a given (plan, operation order) replays the same faults — a failing
chaos run is reproducible from its seed.

:class:`KillPoint` derives from **BaseException** on purpose: the
repo-wide ``except Exception`` job-isolation and observability swallows
must not absorb a simulated crash; it unwinds to the chaos harness the way
a real kill takes the process.

The module also ships :func:`retry_io`, the shared transient-error retry
policy (bounded exponential backoff + jitter, per-call-site metric) that
replaces the ad-hoc ``except OSError: pass`` swallows in the lease,
registry, and telemetry paths.  See ``docs/ROBUSTNESS.md``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import errno as _errno_mod
import fnmatch
import os
import pathlib
import random
import time
from typing import Callable, Dict, List, Optional, Tuple, Union

__all__ = [
    "KillPoint", "FaultRule", "FaultPlan", "FaultyIO",
    "arm", "disarm", "active", "armed",
    "retry_io", "TRANSIENT_ERRNOS",
]


class KillPoint(BaseException):
    """A simulated hard crash injected inside or between protocol steps."""

    def __init__(self, site: str):
        super().__init__(f"simulated crash at {site}")
        self.site = site


# fault kinds each primitive consults (a rule whose kind does not apply to
# the operation simply never matches it)
_READ_KINDS = ("stale_read", "truncated_read", "errno", "stall", "kill")
_WRITE_KINDS = ("torn_write", "errno", "stall", "kill")
_RENAME_KINDS = ("rename_fail", "rename_dup", "errno", "stall", "kill")
_META_KINDS = ("errno", "stall", "kill")

KINDS = ("torn_write", "errno", "rename_fail", "rename_dup",
         "stale_read", "truncated_read", "stall", "kill")


@dataclasses.dataclass
class FaultRule:
    """One injectable fault: *which* sites, *what* fault, *how often*.

    ``site`` is an ``fnmatch`` pattern over call-site names (e.g.
    ``"lease.*"`` or ``"store.append"``); ``p`` is the per-matching-op
    injection probability; ``after`` skips the first N matching ops (let a
    protocol make progress before hurting it) and ``max_count`` bounds the
    total injections (0 = unlimited)."""

    site: str = "*"
    kind: str = "errno"
    p: float = 1.0
    errno: int = _errno_mod.EIO
    max_count: int = 0
    after: int = 0
    stall_s: float = 0.0
    # runtime counters — FaultyIO mutates these; reports read them
    seen: int = 0
    fired: int = 0


@dataclasses.dataclass
class FaultPlan:
    """A seeded schedule of :class:`FaultRule` entries."""

    seed: int = 0
    rules: List[FaultRule] = dataclasses.field(default_factory=list)


class FaultyIO:
    """The injectable I/O shim every filesystem-bus touch point routes
    through *when armed*.  Each primitive consults the plan's rules in
    order; the first applicable rule that fires decides the fault."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.rng = random.Random(plan.seed)
        self.calls = 0
        self.injected: Dict[Tuple[str, str], int] = {}
        self._read_cache: Dict[str, str] = {}

    # -- rule selection -----------------------------------------------------
    def _pick(self, site: str, kinds: Tuple[str, ...]) -> Optional[FaultRule]:
        for rule in self.plan.rules:
            if rule.kind not in kinds:
                continue
            if not fnmatch.fnmatch(site, rule.site):
                continue
            rule.seen += 1
            if rule.seen <= rule.after:
                continue
            if rule.max_count and rule.fired >= rule.max_count:
                continue
            if self.rng.random() >= rule.p:
                continue
            rule.fired += 1
            key = (site, rule.kind)
            self.injected[key] = self.injected.get(key, 0) + 1
            return rule
        return None

    def _meta(self, rule: FaultRule, site: str) -> None:
        """Apply an errno/stall/kill rule (stall returns; the rest raise)."""
        if rule.kind == "stall":
            time.sleep(rule.stall_s)
            return
        if rule.kind == "kill":
            raise KillPoint(site)
        raise OSError(rule.errno, os.strerror(rule.errno), site)

    # -- primitives ---------------------------------------------------------
    def probe(self, site: str) -> None:
        """A kill-point / errno / stall checkpoint between protocol steps."""
        self.calls += 1
        rule = self._pick(site, _META_KINDS)
        if rule is not None:
            self._meta(rule, site)

    def read_text(self, path, site: str, *, encoding: str = "utf-8") -> str:
        self.calls += 1
        spath = os.fspath(path)
        rule = self._pick(site, _READ_KINDS)
        if rule is not None:
            if rule.kind == "stale_read":
                cached = self._read_cache.get(spath)
                if cached is not None:
                    return cached
            elif rule.kind == "truncated_read":
                text = pathlib.Path(path).read_text(encoding=encoding)
                cut = self.rng.randrange(len(text)) if text else 0
                return text[:cut]
            else:
                self._meta(rule, site)
        text = pathlib.Path(path).read_text(encoding=encoding)
        self._read_cache[spath] = text
        return text

    def read_bytes(self, path, site: str) -> bytes:
        self.calls += 1
        rule = self._pick(site, _READ_KINDS)
        if rule is not None:
            if rule.kind == "truncated_read":
                blob = pathlib.Path(path).read_bytes()
                cut = self.rng.randrange(len(blob)) if blob else 0
                return blob[:cut]
            if rule.kind != "stale_read":    # no byte-level stale cache
                self._meta(rule, site)
        return pathlib.Path(path).read_bytes()

    def write_text(self, path, text: str, site: str, *,
                   encoding: str = "utf-8") -> None:
        self.calls += 1
        rule = self._pick(site, _WRITE_KINDS)
        if rule is not None:
            if rule.kind == "torn_write":
                cut = self.rng.randrange(len(text)) if text else 0
                pathlib.Path(path).write_text(text[:cut], encoding=encoding)
                raise KillPoint(site)
            self._meta(rule, site)
        pathlib.Path(path).write_text(text, encoding=encoding)

    def write_bytes(self, path, blob: bytes, site: str) -> None:
        self.calls += 1
        rule = self._pick(site, _WRITE_KINDS)
        if rule is not None:
            if rule.kind == "torn_write":
                cut = self.rng.randrange(len(blob)) if blob else 0
                pathlib.Path(path).write_bytes(blob[:cut])
                raise KillPoint(site)
            self._meta(rule, site)
        pathlib.Path(path).write_bytes(blob)

    def file_write(self, fh, data: str, site: str) -> None:
        """Write to an already-open handle (the store's append handle):
        a torn write lands a prefix, flushes it to the OS, then crashes."""
        self.calls += 1
        rule = self._pick(site, _WRITE_KINDS)
        if rule is not None:
            if rule.kind == "torn_write":
                cut = self.rng.randrange(len(data)) if data else 0
                fh.write(data[:cut])
                fh.flush()
                raise KillPoint(site)
            self._meta(rule, site)
        fh.write(data)

    def replace(self, src, dst, site: str) -> None:
        self._rename(os.replace, src, dst, site)

    def rename(self, src, dst, site: str) -> None:
        self._rename(os.rename, src, dst, site)

    def _rename(self, op: Callable, src, dst, site: str) -> None:
        self.calls += 1
        rule = self._pick(site, _RENAME_KINDS)
        if rule is not None:
            if rule.kind == "rename_fail":
                raise OSError(rule.errno, os.strerror(rule.errno),
                              os.fspath(src))
            if rule.kind == "rename_dup":
                # the rename HAPPENED but the caller sees failure — a retry
                # duplicates the effect, the race the protocols must absorb
                op(src, dst)
                raise OSError(rule.errno, os.strerror(rule.errno),
                              os.fspath(src))
            self._meta(rule, site)
        op(src, dst)

    def fsync(self, fd: Union[int, object], site: str) -> None:
        self.calls += 1
        rule = self._pick(site, _META_KINDS)
        if rule is not None:
            self._meta(rule, site)
        os.fsync(fd if isinstance(fd, int) else fd.fileno())

    def utime(self, path, site: str) -> None:
        self.calls += 1
        rule = self._pick(site, _META_KINDS)
        if rule is not None:
            self._meta(rule, site)
        os.utime(path)

    def unlink(self, path, site: str, *, missing_ok: bool = False) -> None:
        self.calls += 1
        rule = self._pick(site, _META_KINDS)
        if rule is not None:
            self._meta(rule, site)
        pathlib.Path(path).unlink(missing_ok=missing_ok)

    # -- reporting ----------------------------------------------------------
    def report(self) -> Dict[str, object]:
        by_kind: Dict[str, int] = {}
        for (_, kind), n in self.injected.items():
            by_kind[kind] = by_kind.get(kind, 0) + n
        return {
            "seed": self.plan.seed,
            "calls": self.calls,
            "injected_total": sum(self.injected.values()),
            "by_kind": by_kind,
            "by_site": {f"{site}|{kind}": n
                        for (site, kind), n in sorted(self.injected.items())},
        }


# ---------------------------------------------------------------------------
# the process-global shim (None = disarmed, the production state)
# ---------------------------------------------------------------------------

_IO: Optional[FaultyIO] = None


def arm(plan: FaultPlan) -> FaultyIO:
    """Install a :class:`FaultyIO` for ``plan`` as the process-global shim."""
    global _IO
    _IO = FaultyIO(plan)
    return _IO


def disarm() -> Optional[FaultyIO]:
    """Remove the shim; returns it so harnesses can read its report."""
    global _IO
    io, _IO = _IO, None
    return io


def active() -> Optional[FaultyIO]:
    return _IO


@contextlib.contextmanager
def armed(plan: FaultPlan):
    """``with chaos.armed(FaultPlan(...)) as io:`` — scoped arming."""
    io = arm(plan)
    try:
        yield io
    finally:
        disarm()


# ---------------------------------------------------------------------------
# shared transient-error retry policy
# ---------------------------------------------------------------------------

# errnos worth retrying: transient device/contention conditions.  ENOSPC is
# deliberately NOT here (retrying a full disk burns the budget for nothing)
# and FileNotFoundError never retries (a vanished path is a genuine race —
# somebody else won it).
TRANSIENT_ERRNOS = frozenset({
    _errno_mod.EIO, _errno_mod.EAGAIN, _errno_mod.EBUSY,
})


def _count_retry(site: str, err: Optional[int]) -> None:
    try:
        from .obs.metrics import get_registry
        get_registry().counter(
            "tunedb_io_retries_total",
            "transient I/O errors retried by retry_io, per call site",
        ).inc(site=site, errno=str(err))
    except Exception:
        pass            # observability never blocks the retry itself


def retry_io(fn: Callable, *, site: str, attempts: int = 3,
             base_delay_s: float = 0.005, max_delay_s: float = 0.25,
             transient: frozenset = TRANSIENT_ERRNOS):
    """Run ``fn()`` retrying *transient* OSErrors with bounded exponential
    backoff + jitter.  Non-transient errors (ENOSPC, ENOENT, ValueError,
    ...) propagate immediately; the final transient failure re-raises after
    the attempt budget.  Every retried error counts in
    ``tunedb_io_retries_total{site,errno}``."""
    last: Optional[OSError] = None
    for i in range(max(int(attempts), 1)):
        try:
            return fn()
        except FileNotFoundError:
            raise               # a lost race, not a flaky device
        except OSError as e:
            if e.errno not in transient:
                raise
            last = e
            _count_retry(site, e.errno)
            if i + 1 < max(int(attempts), 1):
                delay = min(base_delay_s * (2.0 ** i), max_delay_s)
                time.sleep(delay * (0.5 + 0.5 * random.random()))
    assert last is not None
    raise last
