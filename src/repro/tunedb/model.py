"""Learned performance models served from the tuning-record database.

This module closes the loop the paper builds in §5-§6: the tuning records a
fleet accumulates (``RecordStore``) become the *training set* of an MLP
performance regressor (§5: log2 features over input+tuning parameters, ReLU
hidden layers, log-throughput target), and at dispatch time a novel input
shape is resolved by the §6 runtime search — one batched forward pass of the
regressor over every legal tuning configuration for that shape — instead of
borrowing its nearest tuned neighbor's config.  Nearest-neighbor lookup only
generalizes *on* the tuned grid; the regressor generalizes *across* input
shapes (the paper's central claim, echoed by the model-driven adaptive
library line of work: arXiv:1806.07060, MLKAPS arXiv:2501.05811).

Paper §5 -> implementation map:
  * §5.1 dataset        ``harvest`` turns TuneRecords (tuner/session results
                        plus the ``source="sample"`` exploration measurements
                        a :class:`~repro.tunedb.session.TuningSession`
                        commits) into a :class:`repro.core.dataset.Dataset`.
  * §5.2 features       ``repro.core.features.Featurizer`` — log2 transform,
                        standardization; stats are *persisted with the model*
                        so a serving process featurizes identically.
  * §5.3 regressor      ``repro.core.mlp.MLP`` — ReLU MLP, Adam, MSE on
                        log2(TFLOPS).
  * §6   runtime        ``PerfModel.predict_config`` /
                        ``ModelSet.predict`` — exhaustive scan of the legal
                        config slice scored by ONE batched MLP forward pass,
                        memoized per shape so the serving hot path pays a
                        dict hit after the first resolution.

Models are keyed by ``(space, backend fingerprint)``: one store can hold
records measured on several backends (v5e sim, wall-clock CPU, ...) and
serves a separate regressor for each.  Artifacts are versioned
(``MODEL_SCHEMA_VERSION``); a loader that meets an artifact from the future
skips it with a warning instead of misreading it.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import re
import time
import warnings
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from .store import (SAMPLE_SOURCE, RecordStore, TuneRecord, normalize_config,
                    normalize_inputs)

MODEL_SCHEMA_VERSION = 1


class ModelArtifactError(ValueError):
    """Raised when a persisted model artifact cannot be loaded safely."""


def backend_slug(fingerprint: str) -> str:
    """Filesystem-safe, collision-resistant slug for a backend fingerprint."""
    clean = re.sub(r"[^A-Za-z0-9_.-]+", "-", fingerprint).strip("-") or "any"
    return f"{clean[:48]}-{hashlib.sha1(fingerprint.encode()).hexdigest()[:8]}"


def default_models_dir(store_path: os.PathLike) -> pathlib.Path:
    """Where a store's model artifacts live: ``<store>.models/`` sibling."""
    p = pathlib.Path(store_path)
    return p.with_name(p.name + ".models")


# ---------------------------------------------------------------------------
# §5.1 — harvest the record log into training datasets
# ---------------------------------------------------------------------------

def harvest(store: RecordStore, *, space: Optional[str] = None,
            backend: Optional[str] = None,
            min_tflops: float = 1e-6) -> Dict[Tuple[str, str], "object"]:
    """Group every usable record by (space, backend) into Datasets.

    Uses the store's full training log — including superseded re-tunes and
    ``source="sample"`` exploration measurements — not just the latest record
    per shape: every measurement is a labeled (inputs, config) -> TFLOPS
    point, and the regressor wants all of them.  Records with non-positive
    throughput (legacy imports) or configs that do not cover the space's
    tuning parameters are dropped.
    """
    from repro.core.dataset import Dataset
    from repro.core.space import SPACES

    grouped: Dict[Tuple[str, str], Dict[str, list]] = {}
    for rec in store.training_records(space=space, backend=backend):
        sp = SPACES.get(rec.space)
        if sp is None or rec.tflops <= min_tflops:
            continue
        if not all(k in rec.config for k in sp.param_names):
            continue
        if not all(k in rec.inputs for k in sp.input_params):
            continue
        g = grouped.setdefault((rec.space, rec.backend),
                               {"inputs": [], "configs": [], "tflops": []})
        g["inputs"].append(dict(rec.inputs))
        g["configs"].append(dict(rec.config))
        g["tflops"].append(rec.tflops)
    return {
        key: Dataset(space=SPACES[key[0]], inputs=g["inputs"],
                     configs=g["configs"],
                     tflops=np.asarray(g["tflops"], np.float64))
        for key, g in grouped.items()
    }


def collect_samples(store: RecordStore, backend, *, per_shape: int = 48,
                    space: Optional[str] = None, seed: int = 0,
                    max_shapes: Optional[int] = None) -> int:
    """Label random legal configs for every tuned shape (training data).

    The session's top-k measurements cluster around the model's current
    optimum; a regressor also needs to see *mediocre* configs to learn the
    performance landscape (§5.1's uniform phase, restricted to the shapes
    traffic actually produced — the input-aware twist).  Appends
    ``source="sample"`` records, which the store keeps out of the serving
    index.  Returns the number of samples committed.
    """
    from repro.core.search import enumerate_legal
    from repro.core.space import SPACES

    from .session import backend_fingerprint

    rng = np.random.default_rng(seed)
    fp = backend_fingerprint(backend)
    shapes: List[Tuple[str, Dict[str, int]]] = []
    seen = set()
    for rec in store.records():
        if space is not None and rec.space != space:
            continue
        if rec.space not in SPACES:
            continue
        key = rec.key
        if key in seen:
            continue
        seen.add(key)
        shapes.append((rec.space, dict(rec.inputs)))
    if max_shapes is not None:
        shapes = shapes[:max_shapes]

    n = 0
    for space_name, inputs in shapes:
        sp = SPACES[space_name]
        legal = enumerate_legal(sp, inputs)
        if not legal:
            continue
        idx = rng.permutation(len(legal))[:per_shape]
        for i in idx:
            cfg = legal[int(i)]
            tflops = float(backend.measure(space_name, cfg, inputs))
            store.add(TuneRecord(
                space=space_name, inputs=inputs, config=dict(cfg),
                tflops=tflops, backend=fp, source=SAMPLE_SOURCE))
            n += 1
    return n


# ---------------------------------------------------------------------------
# §5.3 + §6 — one trained regressor per (space, backend fingerprint)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PerfModel:
    """A trained performance regressor for one (space, backend) pair."""

    space: "object"                       # repro.core.space.ParamSpace
    backend: str                          # backend fingerprint it models
    model: "object"                       # repro.core.mlp.MLP
    featurizer: "object"                  # fitted repro.core.features.Featurizer
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def key(self) -> Tuple[str, str]:
        return (self.space.name, self.backend)

    def predict_config(self, inputs: Mapping[str, int], *, top_k: int = 1,
                       candidates: Optional[List[Dict[str, int]]] = None):
        """§6 runtime search: score every legal config in one forward pass."""
        from repro.core.search import exhaustive_search
        return exhaustive_search(self.space, normalize_inputs(inputs),
                                 model=self.model, featurizer=self.featurizer,
                                 top_k=top_k, candidates=candidates)

    # -- persistence ---------------------------------------------------------
    def _stem(self) -> str:
        return f"{self.space.name}--{backend_slug(self.backend)}"

    def save(self, directory: os.PathLike) -> pathlib.Path:
        d = pathlib.Path(directory)
        d.mkdir(parents=True, exist_ok=True)
        stem = self._stem()
        npz_path = d / f"{stem}.npz"
        npz_tmp = npz_path.with_name(npz_path.name + ".tmp")
        npz_tmp.write_bytes(self.model.to_bytes())
        os.replace(npz_tmp, npz_path)    # never a readable meta + torn npz
        meta_path = d / f"{stem}.json"
        tmp = meta_path.with_name(meta_path.name + ".tmp")
        tmp.write_text(json.dumps({
            "model_schema_version": MODEL_SCHEMA_VERSION,
            "space": self.space.name,
            "backend": self.backend,
            "featurizer": json.loads(self.featurizer.to_json()),
            "meta": self.meta,
        }, sort_keys=True))
        os.replace(tmp, meta_path)
        return meta_path

    @classmethod
    def load(cls, meta_path: os.PathLike) -> "PerfModel":
        from repro.core.features import Featurizer
        from repro.core.mlp import MLP
        from repro.core.space import SPACES

        meta_path = pathlib.Path(meta_path)
        try:
            d = json.loads(meta_path.read_text())
        except (ValueError, OSError) as e:
            raise ModelArtifactError(f"{meta_path.name}: unreadable ({e})")
        try:
            version = int(d.get("model_schema_version", -1))
        except (TypeError, ValueError):
            version = -1
        if version != MODEL_SCHEMA_VERSION:
            raise ModelArtifactError(
                f"{meta_path.name}: model schema v{version} != "
                f"v{MODEL_SCHEMA_VERSION} (refusing to misread)")
        space = SPACES.get(d.get("space"))
        if space is None:
            raise ModelArtifactError(
                f"{meta_path.name}: unknown space {d.get('space')!r}")
        npz = meta_path.with_suffix(".npz")
        if not npz.exists():
            raise ModelArtifactError(f"{meta_path.name}: missing {npz.name}")
        try:
            featurizer = Featurizer.from_json(space,
                                              json.dumps(d["featurizer"]))
            model = MLP.from_bytes(npz.read_bytes())
            return cls(space=space, backend=d["backend"], model=model,
                       featurizer=featurizer, meta=dict(d.get("meta", {})))
        except Exception as e:   # noqa: BLE001 — torn npz / malformed meta:
            # any parse failure here means a damaged artifact, and the
            # contract is "skip, never take serving down"
            raise ModelArtifactError(
                f"{meta_path.name}: damaged artifact "
                f"({type(e).__name__}: {e})")


def train_models(store: RecordStore, *, space: Optional[str] = None,
                 backend: Optional[str] = None, min_samples: int = 24,
                 hidden: Tuple[int, ...] = (64, 128, 64), epochs: int = 30,
                 val_frac: float = 0.1, seed: int = 0,
                 verbose: bool = False) -> "ModelSet":
    """Train one regressor per (space, backend) group with enough samples."""
    import jax

    from repro.core.mlp import MLP

    models = ModelSet()
    for (space_name, fp), ds in sorted(harvest(store, space=space,
                                               backend=backend).items()):
        if len(ds) < min_samples:
            if verbose:
                print(f"[model] {space_name}/{fp}: {len(ds)} samples "
                      f"< {min_samples}, skipping")
            continue
        train, val = ds.split(val_frac=val_frac, seed=seed)
        featurizer, X, y = train.featurize()
        _, Xv, yv = val.featurize(featurizer)
        model = MLP.create(jax.random.PRNGKey(seed), in_dim=featurizer.dim,
                           hidden=hidden)
        history = model.fit(X, y, epochs=epochs, X_val=Xv, y_val=yv,
                            verbose=verbose)
        pm = PerfModel(space=ds.space, backend=fp, model=model,
                       featurizer=featurizer, meta={
                           "created_at": time.time(),
                           "n_samples": len(ds),
                           "hidden": list(hidden),
                           "epochs": epochs,
                           "seed": seed,
                           "val_mse": history[-1] if history else None,
                       })
        models.add(pm)
        if verbose:
            mse = pm.meta["val_mse"]
            print(f"[model] {space_name}/{fp}: trained on {len(ds)} samples, "
                  f"val mse {'n/a' if mse is None else f'{mse:.4f}'}")
    return models


# ---------------------------------------------------------------------------
# The serving-side model registry
# ---------------------------------------------------------------------------

class ModelSet:
    """Per-(space, backend) PerfModels with memoized dispatch resolution.

    ``measurer`` is the optional §6 top-k re-measurement hook: a callable
    ``(space_name, config, inputs) -> TFLOPS`` (a measurement backend's
    ``measure``).  When set, the first resolution of a shape re-measures the
    model's top ``remeasure_top_k`` candidates and serves the measured
    winner — the paper's recipe for washing model noise out of the argmax.
    The cost is a handful of measurements ONCE per novel shape (memoized);
    without a measurer the pure model argmax is served.

    Confidence gating (both off unless enabled — serving policy, carried
    across retrain hot-swaps like the measurer): a resolution is *declined*
    — dispatch falls through to the nearest-record tier — when

      * ``margin_threshold`` > 0 and the predicted top-1 beats top-2 by
        less than that relative margin: an argmax the model cannot separate
        from its runner-up is noise, not a decision; or
      * ``max_feature_z`` > 0 and any *input* feature lies further than
        that many training standard deviations from the featurizer's
        stats: the shape is off the training manifold, where a regressor
        is confidently wrong — a good nearby record must win instead.
    """

    def __init__(self, *, measurer=None, remeasure_top_k: int = 12,
                 margin_threshold: float = 0.0,
                 max_feature_z: float = 0.0) -> None:
        self.models: Dict[Tuple[str, str], PerfModel] = {}
        self.measurer = measurer
        # deferred-measurement mode (serving): with a MeasureQueue attached,
        # predict() serves the model argmax immediately and enqueues the
        # top-k for idle-decode-gap re-measurement (tunedb.measure) instead
        # of paying the measurements inline on the dispatch path
        self.measure_queue = None
        self.remeasure_top_k = remeasure_top_k
        self.margin_threshold = margin_threshold
        self.max_feature_z = max_feature_z
        self.hits = 0                    # resolutions served (memo or fresh)
        self.misses = 0                  # no model / no legal config
        self.gated = 0                   # resolutions declined by confidence
        self.skipped: List[str] = []     # artifacts refused at load time
        self._memo: Dict[tuple, Optional[Tuple[Dict[str, int], float]]] = {}

    def add(self, pm: PerfModel) -> None:
        self.models[pm.key] = pm
        self._memo.clear()

    def invalidate_memos(self) -> None:
        """Drop per-shape resolutions (called on serving-state installs)."""
        self._memo.clear()

    def apply_measurement(self, space: str, backend: Optional[str],
                          inputs: Mapping[str, int], cfg: Mapping[str, int],
                          tflops: float) -> None:
        """Commit a deferred re-measurement's winner: later resolutions of
        this shape serve the measured config, not the model argmax."""
        inputs = normalize_inputs(inputs)
        memo_key = (space, backend, tuple(sorted(inputs.items())))
        self._memo[memo_key] = (normalize_config(cfg), float(tflops))

    def merged_with(self, newer: "ModelSet") -> "ModelSet":
        """A fresh ModelSet carrying this set's models overridden by
        ``newer``'s — the retrain hot-swap: untouched (space, backend)
        regressors keep serving, retrained ones replace their ancestors.
        The SERVING configuration (measurer, re-measure width, confidence
        gates) stays this set's — a freshly trained set carries defaults,
        not policy."""
        out = ModelSet(measurer=self.measurer or newer.measurer,
                       remeasure_top_k=self.remeasure_top_k,
                       margin_threshold=self.margin_threshold,
                       max_feature_z=self.max_feature_z)
        out.measure_queue = self.measure_queue or newer.measure_queue
        out.models.update(self.models)
        out.models.update(newer.models)
        return out

    def __len__(self) -> int:
        return len(self.models)

    def resolve_model(self, space: str, backend: Optional[str] = None
                      ) -> Optional[PerfModel]:
        """Exact (space, backend) model; else the newest model for the space."""
        if backend is not None:
            return self.models.get((space, backend))
        best = None
        for (sp, _), pm in self.models.items():
            if sp != space:
                continue
            if best is None or (pm.meta.get("created_at", 0)
                                > best.meta.get("created_at", 0)):
                best = pm
        return best

    def _off_manifold(self, pm: PerfModel, inputs: Mapping[str, int]) -> bool:
        """Is this shape outside the regressor's training input range?

        Z-scores the INPUT slice of the feature vector against the
        persisted featurizer stats (tuning-parameter dims do not apply: the
        §6 scan sweeps them, only the inputs are fixed by traffic).
        """
        f = pm.featurizer
        if self.max_feature_z <= 0 or f.mean is None:
            return False
        names = list(f.space.input_params)
        vals = np.asarray([float(inputs[k]) for k in names], np.float64)
        raw = np.log2(vals + 1.0) if f.log else vals
        n = len(names)                   # input dims lead the feature vector
        z = np.abs((raw - f.mean[:n]) / f.std[:n])
        return bool(z.max() > self.max_feature_z)

    def predict(self, space: str, inputs: Mapping[str, int], *,
                backend: Optional[str] = None
                ) -> Optional[Tuple[Dict[str, int], float]]:
        """Model-guided config for a shape: (config, predicted TFLOPS).

        The first resolution of a shape pays the §6 exhaustive scan (legal
        enumeration + one batched forward pass); every later call is a memo
        hit, which is what keeps the serving dispatch path flat.  Returns
        ``None`` — dispatch falls to the nearest-record tier — when no
        model covers the (space, backend), the shape has no legal config,
        or a confidence gate (margin / off-manifold) declines to answer.
        """
        inputs = normalize_inputs(inputs)
        memo_key = (space, backend, tuple(sorted(inputs.items())))
        if memo_key in self._memo:
            out = self._memo[memo_key]
            if out is None:
                self.misses += 1
            else:
                self.hits += 1
            return out
        pm = self.resolve_model(space, backend)
        out: Optional[Tuple[Dict[str, int], float]] = None
        gated = False
        if pm is not None:
            try:
                if self._off_manifold(pm, inputs):
                    gated = True
                else:
                    k = (self.remeasure_top_k if self.measurer is not None
                         else 1)
                    if self.margin_threshold > 0:
                        k = max(k, 2)    # the gate needs the runner-up
                    res = pm.predict_config(inputs, top_k=k)
                    if self.margin_threshold > 0 and len(res.top_k) > 1:
                        p1, p2 = res.top_k[0][1], res.top_k[1][1]
                        if p1 <= 0 or (p1 - p2) / p1 < self.margin_threshold:
                            gated = True
                    if gated:
                        pass
                    elif self.measurer is not None and len(res.top_k) > 1 \
                            and self.measure_queue is not None:
                        # serving: answer with the argmax NOW, schedule the
                        # §6 re-measurement for an idle decode gap — the
                        # measured winner later upgrades the memo and the
                        # plan-overlay entry (MeasureQueue.process)
                        self.measure_queue.push(
                            space, backend, inputs,
                            [dict(c) for c, _ in res.top_k])
                        out = (normalize_config(res.best),
                               float(res.predicted_tflops))
                    elif self.measurer is not None and len(res.top_k) > 1:
                        measured = [(cfg,
                                     float(self.measurer(space, cfg, inputs)))
                                    for cfg, _ in res.top_k]
                        cfg, tflops = max(measured, key=lambda t: t[1])
                        out = (normalize_config(cfg), tflops)
                    else:
                        out = (normalize_config(res.best),
                               float(res.predicted_tflops))
            except ValueError:           # no legal configuration for inputs
                out = None
            except Exception as e:   # noqa: BLE001 — a loaded artifact whose
                # featurizer/space drifted must degrade to the lower dispatch
                # tiers, never crash the kernel hot path (warn once, memoized)
                warnings.warn(
                    f"tunedb model for {space!r} failed at resolution "
                    f"({type(e).__name__}: {e}); falling back",
                    RuntimeWarning, stacklevel=2)
                out = None
        if len(self._memo) > 4096:
            self._memo.clear()
        self._memo[memo_key] = out
        if gated:
            self.gated += 1
        if out is None:
            self.misses += 1
        else:
            self.hits += 1
        return out

    # -- persistence ---------------------------------------------------------
    def save(self, directory: os.PathLike) -> pathlib.Path:
        d = pathlib.Path(directory)
        for pm in self.models.values():
            pm.save(d)
        return d

    @classmethod
    def load(cls, directory: os.PathLike, *, warn: bool = True) -> "ModelSet":
        """Load every readable artifact; skip (don't crash on) bad ones.

        A serving process must come up even when the artifact directory holds
        models written by a newer schema or torn files — those are skipped
        with one warning each and recorded in ``skipped``.
        """
        ms = cls()
        d = pathlib.Path(directory)
        if not d.is_dir():
            return ms
        for meta_path in sorted(d.glob("*.json")):
            try:
                ms.add(PerfModel.load(meta_path))
            except ModelArtifactError as e:
                ms.skipped.append(str(e))
                if warn:
                    warnings.warn(f"tunedb model artifact skipped: {e}",
                                  RuntimeWarning, stacklevel=2)
        return ms

    def stats(self) -> Dict[str, object]:
        return {
            "models": {
                f"{sp}/{fp}": {k: v for k, v in pm.meta.items()}
                for (sp, fp), pm in sorted(self.models.items())},
            "lookups": {"hits": self.hits, "misses": self.misses,
                        "gated": self.gated},
            "gating": {"margin_threshold": self.margin_threshold,
                       "max_feature_z": self.max_feature_z},
            "skipped_artifacts": list(self.skipped),
        }


# ---------------------------------------------------------------------------
# Process-global model set: the dispatcher's model-guided tier.  The actual
# reference lives in store.ServingState so a store+models hot-swap is ONE
# atomic generation flip — these are the models-only views of it.
# ---------------------------------------------------------------------------

def install_models(models: Optional[ModelSet]) -> None:
    """Make model-guided resolution visible to the kernel dispatcher."""
    from .store import install_serving
    install_serving(models=models)


def get_models() -> Optional[ModelSet]:
    from .store import serving_state
    return serving_state().models


def clear_models() -> None:
    install_models(None)
