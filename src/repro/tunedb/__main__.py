"""``python -m repro.tunedb`` — operate the tuning-record database.

Subcommands:
  tune    train (or load) a tuner and tune shapes into a store; shapes come
          from a telemetry dump (``--shapes-from-telemetry``) and/or explicit
          ``--shape M=4096,N=16,K=2560`` flags
  train   distill the store's measurement log into per-(space, backend)
          MLP performance models and persist versioned artifacts
  predict model-guided config for a shape (the §6 runtime search, offline)
  models  list persisted model artifacts and their training metadata
  retune  one controller pass over a telemetry dump: diff it against the
          saved epoch baseline (``<telemetry>.epoch``), and when hot-shape
          drift or untuned mass crosses threshold, tune the novel shapes,
          retrain the affected regressors, and advance the baseline
  watch   poll a telemetry dump on an interval, running ``retune`` passes
          until interrupted (or ``--max-polls``) — the out-of-process
          continuous-retuning daemon
  fleet   distributed tuning over a shared directory:
            fleet start   publish a plan as lease files (mined from
                          telemetry and/or explicit --shape jobs); --wait
                          merges shards, retrains, writes the FleetReport;
                          --workers N spawns N local worker subprocesses
                          (the one-command laptop fleet)
            fleet worker  claim jobs (hottest telemetry count first), tune,
                          append to a private shard store
            fleet status  queue/lease/done/failed counts + shard sizes
            fleet drain   tell workers to exit once the queue empties;
                          --wait finalizes like ``start --wait``; --compact
                          archives cursor-complete merged shards off the bus
            fleet route   dry-run shape-affinity routing: score a --shape
                          request against every per-replica plan registry
                          under --registry-root and print the chosen replica
  plan    golden dispatch-plan artifacts (docs/PLANS.md):
            plan export   compile a store (+models/telemetry) into a
                          versioned plan artifact under <store>.plan/
            plan inspect  verify (schema + digest) and print an artifact
            plan publish  compile + publish the next generation to a plan
                          registry directory for followers to pull
            plan follow   poll a registry and atomically hot-swap each new
                          generation into this process's serving state
  trace   request-trace spans (docs/OBSERVABILITY.md):
            trace export  merge span dumps (--fleet traces/ and/or --input
                          files) into one Perfetto-loadable Chrome trace
            trace summary per-span-name latency + dispatch-tier attribution
  stats   print store (and optional telemetry) statistics as JSON
  export  compact a store to latest-record-per-shape
  merge   fold several stores into one (newest record per shape wins)

Example round trip:
  $ python -m repro.tunedb tune --space gemm --shapes-from-telemetry \\
        --telemetry /tmp/shapes.json --store /tmp/tunedb.jsonl
  $ python -m repro.tunedb train --store /tmp/tunedb.jsonl
  $ python -m repro.tunedb predict --store /tmp/tunedb.jsonl \\
        --space gemm --shape M=4096,N=16,K=2560
  $ python -m repro.tunedb watch --telemetry /tmp/shapes.json \\
        --store /tmp/tunedb.jsonl --interval 60

Fleet round trip (one coordinator terminal, N worker terminals):
  $ python -m repro.tunedb fleet start --fleet /tmp/fleet \\
        --store /tmp/tunedb.jsonl --telemetry /tmp/shapes.json --drain
  $ python -m repro.tunedb fleet worker --fleet /tmp/fleet   # xN machines
  $ python -m repro.tunedb fleet drain --fleet /tmp/fleet --wait --train
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time
from typing import Dict, List, Optional

from .store import RecordStore
from .telemetry import ShapeTelemetry

DEFAULT_STORE = os.path.expanduser("~/.cache/repro-isaac/tunedb.jsonl")

# optional input params a CLI --shape may omit
_SHAPE_DEFAULTS = {"dtype_bits": 16, "trans_a": 0, "trans_b": 0, "causal": 1}


def _parse_shape(spec: str, space) -> Dict[str, int]:
    """'M=4096,N=16,K=2560' -> full input dict for `space`."""
    given: Dict[str, int] = {}
    for part in spec.split(","):
        if not part:
            continue
        k, _, v = part.partition("=")
        if not _:
            raise SystemExit(f"bad --shape entry {part!r} (want k=v)")
        given[k.strip()] = int(v)
    inputs = {}
    for name in space.input_params:
        if name in given:
            inputs[name] = given.pop(name)
        elif name in _SHAPE_DEFAULTS:
            inputs[name] = _SHAPE_DEFAULTS[name]
        else:
            raise SystemExit(
                f"--shape {spec!r} missing input param {name!r} "
                f"(space {space.name} needs {space.input_params})")
    if given:
        raise SystemExit(f"--shape {spec!r}: unknown params {sorted(given)}")
    return inputs


def _cmd_tune(args: argparse.Namespace) -> int:
    from repro.core.backend import SimulatedTPUBackend
    from repro.core.space import SPACES
    from repro.core.tuner import InputAwareTuner

    from .session import TuningSession

    space = SPACES[args.space]
    store = RecordStore.open(args.store)

    telemetry: Optional[ShapeTelemetry] = None
    if args.shapes_from_telemetry:
        if not args.telemetry:
            raise SystemExit("--shapes-from-telemetry needs --telemetry PATH")
        if not os.path.exists(args.telemetry):
            raise SystemExit(f"telemetry file not found: {args.telemetry}")
        telemetry = ShapeTelemetry.load(args.telemetry)
    shapes: Optional[List[Dict[str, int]]] = None
    if args.shape:
        shapes = [_parse_shape(s, space) for s in args.shape]
    if telemetry is None and shapes is None:
        raise SystemExit("need --shapes-from-telemetry and/or --shape")

    if args.load_tuner:
        tuner = InputAwareTuner.load(args.load_tuner, space,
                                     backend=SimulatedTPUBackend())
    else:
        print(f"[tunedb] training {args.space} tuner "
              f"({args.train_samples} samples, {args.epochs} epochs)...")
        tuner = InputAwareTuner.train(
            space, n_samples=args.train_samples, epochs=args.epochs,
            backend=SimulatedTPUBackend(), seed=args.seed)
        if args.save_tuner:
            tuner.save(args.save_tuner)

    session = TuningSession(
        tuner, store, telemetry, top_k_shapes=args.top_k,
        workers=args.workers, remeasure=not args.no_remeasure,
        skip_existing=not args.retune, progress_path=args.progress)
    reports = []
    if telemetry is not None:
        reports.append(session.run(verbose=True))        # mined hot shapes
    if shapes:
        reports.append(session.run(shapes=shapes, verbose=True))
    tuned = sum(r.tuned for r in reports)
    skipped = sum(r.skipped for r in reports)
    failed = sum(r.failed for r in reports)
    wall = sum(r.wall_s for r in reports)
    print(f"[tunedb] session done: {tuned} tuned, {skipped} skipped, "
          f"{failed} failed in {wall:.1f}s -> {args.store}")
    for r in reports:
        for err in r.errors:
            print(f"[tunedb]   failed: {err}", file=sys.stderr)
    return 1 if failed and not tuned else 0


def _cmd_train(args: argparse.Namespace) -> int:
    from .model import collect_samples, default_models_dir, train_models

    store = RecordStore.open(args.store)
    if not len(store):
        print(f"[tunedb] store {args.store} has no records; run `tune` first",
              file=sys.stderr)
        return 1
    if args.samples_per_shape > 0:
        from repro.core.backend import SimulatedTPUBackend
        n = collect_samples(store, SimulatedTPUBackend(),
                            per_shape=args.samples_per_shape,
                            space=args.space, seed=args.seed)
        print(f"[tunedb] collected {n} exploration samples "
              f"({args.samples_per_shape}/shape)")
    models = train_models(store, space=args.space, hidden=args.hidden,
                          epochs=args.epochs, seed=args.seed,
                          min_samples=args.min_samples, verbose=True)
    if not len(models):
        print("[tunedb] no (space, backend) group had enough samples; "
              "try --samples-per-shape", file=sys.stderr)
        return 1
    out = args.models_dir or default_models_dir(args.store)
    models.save(out)
    print(f"[tunedb] saved {len(models)} model(s) -> {out}")
    for key, meta in models.stats()["models"].items():
        mse = meta["val_mse"]
        print(f"[tunedb]   {key}: {meta['n_samples']} samples, "
              f"val mse {'n/a' if mse is None else f'{mse:.4f}'}")
    return 0


def _cmd_predict(args: argparse.Namespace) -> int:
    from repro.core.space import SPACES

    from .model import ModelSet, default_models_dir

    space = SPACES[args.space]
    models = ModelSet.load(args.models_dir or default_models_dir(args.store))
    pm = models.resolve_model(args.space, args.backend)
    if pm is None:
        have = sorted(f"{s}/{b}" for s, b in models.models)
        print(f"[tunedb] no model for space {args.space!r}"
              + (f" backend {args.backend!r}" if args.backend else "")
              + f"; available: {have or 'none'} (run `train` first)",
              file=sys.stderr)
        return 1
    for spec in args.shape:
        inputs = _parse_shape(spec, space)
        try:
            res = pm.predict_config(inputs, top_k=args.top_k)
        except ValueError as e:          # no legal configuration
            print(f"[tunedb] predict failed for {spec!r}: {e}",
                  file=sys.stderr)
            return 1
        print(json.dumps({
            "space": args.space, "backend": pm.backend, "inputs": inputs,
            "config": res.best,
            "predicted_tflops": round(res.predicted_tflops, 3),
            "n_candidates": res.n_candidates,
            "top_k": [{"config": c, "predicted_tflops": round(p, 3)}
                      for c, p in res.top_k],
        }, sort_keys=True))
    return 0


def _build_retune_controller(args: argparse.Namespace, telemetry, baseline,
                             tuners=None):
    from .controller import RetuneConfig, RetuneController
    from .model import default_models_dir

    def tuner_factory(space_name: str):
        from repro.core.backend import SimulatedTPUBackend
        from repro.core.space import SPACES
        from repro.core.tuner import InputAwareTuner
        if args.load_tuner:
            return InputAwareTuner.load(args.load_tuner, SPACES[space_name],
                                        backend=SimulatedTPUBackend())
        print(f"[tunedb] training {space_name} tuner "
              f"({args.train_samples} samples, {args.epochs} epochs)...")
        return InputAwareTuner.train(
            SPACES[space_name], n_samples=args.train_samples,
            epochs=args.epochs, backend=SimulatedTPUBackend(), seed=args.seed)

    store = RecordStore.open(args.store)
    return RetuneController(
        store, telemetry=telemetry, tuners=tuners,
        tuner_factory=tuner_factory,
        models_dir=(args.models_dir or default_models_dir(args.store)
                    if not args.no_train else None),
        cfg=RetuneConfig(
            drift_threshold=args.drift, untuned_mass_threshold=args.untuned,
            min_calls=args.min_calls, top_k_shapes=args.top_k,
            workers=args.workers, retrain=not args.no_train, seed=args.seed,
            publish=getattr(args, "publish", None)),
        baseline=baseline, verbose=True)


def _baseline_path(args: argparse.Namespace) -> str:
    return args.baseline or args.telemetry + ".epoch"


def _load_baseline(args: argparse.Namespace):
    path = _baseline_path(args)
    if os.path.exists(path):
        return ShapeTelemetry.load(path).snapshot()
    return ShapeTelemetry().snapshot()      # first epoch: everything is new


def _retune_pass(args: argparse.Namespace, tuner_cache=None) -> int:
    """One detect(+tune+train+baseline-advance) pass; returns tuned count.

    ``tuner_cache`` (a mutable dict) carries trained tuners across the watch
    loop's per-poll controllers, so a shifting workload does not re-train a
    tuner from scratch on every poll."""
    import shutil

    if not os.path.exists(args.telemetry):
        print(f"[tunedb] telemetry file not found: {args.telemetry}",
              file=sys.stderr)
        return -1
    telemetry = ShapeTelemetry.load(args.telemetry)
    controller = _build_retune_controller(args, telemetry,
                                          _load_baseline(args), tuner_cache)
    decisions = controller.check()
    for dec in decisions.values():
        mark = dec.reason or "steady"
        print(f"[retune:{dec.space}] {mark}: drift {dec.drift:.3f} "
              f"(>= {args.drift} triggers), untuned mass "
              f"{dec.untuned_mass:.3f} (>= {args.untuned} triggers), "
              f"{dec.window_calls} window calls, "
              f"{len(dec.novel_shapes)} novel hot shapes")
    report = (controller.force_retune(decisions) if args.force
              else controller.maybe_retune(decisions))
    if tuner_cache is not None:
        tuner_cache.update(controller.tuners())
    if report is None:
        print("[tunedb] no retune: traffic within thresholds")
        return 0
    # the consumed telemetry becomes the next epoch's baseline
    shutil.copyfile(args.telemetry, _baseline_path(args))
    print(f"[tunedb] retuned {report.tuned} shape(s) in {report.wall_s:.1f}s; "
          f"retrained {report.retrained or 'nothing'}; serving generation "
          f"{report.generation} -> {args.store}")
    return report.tuned


def _cmd_retune(args: argparse.Namespace) -> int:
    return 1 if _retune_pass(args) < 0 else 0


def _cmd_watch(args: argparse.Namespace) -> int:
    import time as _time

    polls = 0
    tuner_cache: Dict[str, object] = {}     # trained once, reused per poll
    while True:
        polls += 1
        print(f"[tunedb] watch poll {polls}"
              + (f"/{args.max_polls}" if args.max_polls else ""))
        # a missing dump is just "not yet"
        _retune_pass(args, tuner_cache)
        if args.max_polls and polls >= args.max_polls:
            return 0
        _time.sleep(args.interval)


# ---------------------------------------------------------------------------
# fleet: distributed tuning over a shared directory
# ---------------------------------------------------------------------------

def _fleet_finalize(coord, args: argparse.Namespace, t0: float) -> int:
    """Wait out the outstanding jobs, merge, optionally retrain, report.

    The report's done/failed counts are cumulative DIRECTORY state (a
    reused fleet dir keeps its history); the exit code judges only this
    invocation — failures that appeared while it waited.
    """
    import time as _time

    from .model import default_models_dir

    failed_before = coord.fleet.counts()["failed"]
    ok = coord.wait(timeout_s=args.timeout if args.timeout > 0 else None,
                    poll_s=0.2, verbose=True)
    coord.poll()                         # final merge after the last worker
    retrained: List[str] = []
    if args.train and coord.affected:
        models_dir = args.models_dir or default_models_dir(coord.store.path)
        retrained = coord.retrain(models_dir=models_dir,
                                  min_samples=args.min_samples,
                                  epochs=args.epochs, seed=args.seed)
        print(f"[fleet] retrained {retrained or 'nothing'} -> {models_dir}")
    if getattr(args, "publish", None):
        from .plans import PlanArtifactError
        try:
            man = coord.publish_plan(
                args.publish,
                models_dir=(args.models_dir
                            or default_models_dir(coord.store.path)))
            print(f"[fleet] published plan generation {man.generation} "
                  f"({man.n_entries} entries) -> {args.publish}")
        except PlanArtifactError as e:
            print(f"[fleet] plan publish refused: {e}", file=sys.stderr)
    rep = coord.report(retrained=retrained, wall_s=_time.time() - t0)
    print(json.dumps(rep.to_dict(), indent=1, sort_keys=True))
    if not ok:
        print(f"[fleet] timed out with {coord.outstanding()} job(s) "
              "outstanding", file=sys.stderr)
    if getattr(args, "compact", False):
        if ok and coord.outstanding() == 0:
            archived = coord.compact_shards()
            print(f"[fleet] compacted {len(archived)} merged shard(s) "
                  f"-> {coord.fleet.shard_dir() / 'archive'}")
        else:
            print("[fleet] skipping --compact: jobs still outstanding",
                  file=sys.stderr)
    return 0 if ok and rep.failed <= failed_before else 1


def _add_fleet_finalize_args(sp) -> None:
    sp.add_argument("--timeout", type=float, default=0.0,
                    help="give up waiting after this many seconds "
                         "(0 = wait forever)")
    sp.add_argument("--train", action="store_true",
                    help="retrain the affected regressors after the merge")
    sp.add_argument("--models-dir", default=None,
                    help="retrained artifacts dir (default: <store>.models/)")
    sp.add_argument("--min-samples", type=int, default=24)
    sp.add_argument("--epochs", type=int, default=20)
    sp.add_argument("--seed", type=int, default=0)
    sp.add_argument("--compact", action="store_true",
                    help="after every job lands and merges, archive the "
                         "cursor-complete shards out of <store>.shards/ "
                         "instead of leaving them on the bus forever")
    sp.add_argument("--publish", default=None,
                    help="after the merge (and --train retrain), compile the "
                         "merged store into a plan and publish it to this "
                         "registry dir for serving replicas to follow")


def _spawn_workers(args: argparse.Namespace) -> List:
    """Fork N local ``fleet worker`` subprocesses against the bus.

    The one-command laptop fleet: ``fleet start --workers 4`` replaces one
    coordinator terminal plus four worker terminals.  Each worker gets its
    own default (host-pid-random) id, so shard files never collide — and a
    restarted run never appends to a shard whose merge cursor already
    advanced.  PYTHONPATH is pinned to this process's ``repro`` checkout so
    the children resolve the same code regardless of the caller's env.
    """
    import pathlib
    import subprocess

    import repro

    env = dict(os.environ)
    # __path__, not __file__: repro is a namespace package (no __init__.py)
    src_root = str(pathlib.Path(list(repro.__path__)[0]).resolve().parent)
    env["PYTHONPATH"] = src_root + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "repro.tunedb", "fleet", "worker",
           "--fleet", str(args.fleet),
           "--train-samples", str(args.worker_train_samples),
           "--epochs", str(args.worker_epochs)]
    if args.load_tuner:
        cmd += ["--load-tuner", args.load_tuner]
    procs = [subprocess.Popen(cmd, env=env) for _ in range(args.workers)]
    print(f"[fleet] spawned {len(procs)} local worker process(es): "
          f"{' '.join(str(p.pid) for p in procs)}")
    return procs


def _reap_workers(procs: List) -> None:
    import subprocess

    for proc in procs:
        try:
            proc.wait(timeout=60)
        except subprocess.TimeoutExpired:
            print(f"[fleet] worker pid {proc.pid} did not exit; terminating",
                  file=sys.stderr)
            proc.terminate()
            try:
                proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                proc.kill()


def _cmd_fleet_start(args: argparse.Namespace) -> int:
    import time as _time

    from repro.core.space import SPACES

    from .fleet import Coordinator, FleetJob

    t0 = _time.time()
    store = RecordStore.open(args.store)
    coord = Coordinator(args.fleet, store,
                        lease_timeout_s=args.lease_timeout,
                        max_attempts=args.max_attempts)
    jobs: List[FleetJob] = []
    if args.telemetry:
        if not os.path.exists(args.telemetry):
            raise SystemExit(f"telemetry file not found: {args.telemetry}")
        telemetry = ShapeTelemetry.load(args.telemetry)
        jobs += coord.plan_from_telemetry(
            telemetry, spaces=[args.space] if args.space else None,
            top_k=args.top_k, backend=args.backend,
            skip_existing=not args.retune)
    if args.shape and not args.space:
        raise SystemExit("--shape needs --space")
    for spec in args.shape:
        space = SPACES[args.space]
        jobs.append(FleetJob(space=args.space,
                             inputs=_parse_shape(spec, space)))
    if not jobs and not args.wait:
        print("[fleet] nothing to publish (no --telemetry/--shape jobs, or "
              "the store already serves them)", file=sys.stderr)
    # --retune also force-requeues jobs a previous run of this fleet dir
    # already completed: a terminal marker must not pin a shape forever
    n = coord.publish(jobs, force=args.retune)
    print(f"[fleet] published {n} job(s) ({len(jobs) - n} already known) "
          f"-> {args.fleet}")
    if args.workers > 0 and not args.drain:
        # spawned workers have nobody to hand the bus to: the plan is
        # final by construction, so they must exit when it empties
        args.drain = True
    if args.drain:
        coord.fleet.request_drain()
    else:
        # restarting a plan revives a previously drained directory even
        # when every job was already queued (publish had nothing to add)
        coord.fleet.clear_drain()
    procs = _spawn_workers(args) if args.workers > 0 else []
    if args.wait or procs:
        # --workers implies --wait: the one-command fleet merges, reports,
        # and reaps its children before returning — even when finalize
        # blows up (a corrupt shard, Ctrl-C), no orphans are left behind
        try:
            return _fleet_finalize(coord, args, t0)
        finally:
            _reap_workers(procs)
    return 0


def _cmd_fleet_worker(args: argparse.Namespace) -> int:
    from .fleet import Worker

    def tuner_factory(space_name: str):
        from repro.core.backend import SimulatedTPUBackend
        from repro.core.space import SPACES
        from repro.core.tuner import InputAwareTuner
        if args.load_tuner:
            return InputAwareTuner.load(args.load_tuner, SPACES[space_name],
                                        backend=SimulatedTPUBackend())
        print(f"[fleet] training {space_name} tuner "
              f"({args.train_samples} samples, {args.epochs} epochs)...")
        return InputAwareTuner.train(
            SPACES[space_name], n_samples=args.train_samples,
            epochs=args.epochs, backend=SimulatedTPUBackend(),
            seed=args.seed)

    if args.trace_sample > 0:
        from .obs.trace import enable_tracing
        enable_tracing(args.trace_sample)
    worker = Worker(args.fleet, worker_id=args.worker_id,
                    tuner_factory=tuner_factory,
                    remeasure=not args.no_remeasure, verbose=True,
                    telemetry_export_s=args.telemetry_export,
                    trace_export=args.trace_sample > 0)
    print(f"[fleet] worker {worker.worker_id} claiming from {args.fleet}")
    report = worker.run(
        max_jobs=args.max_jobs if args.max_jobs > 0 else None,
        idle_timeout_s=(args.idle_timeout if args.idle_timeout > 0
                        else None))
    print(f"[fleet] worker {report.worker_id}: {report.tuned} tuned, "
          f"{report.failed} failed, {report.lost} lost in "
          f"{report.wall_s:.1f}s")
    for err in report.errors:
        print(f"[fleet]   failed: {err}", file=sys.stderr)
    return 1 if report.failed and not report.tuned else 0


def _cmd_fleet_status(args: argparse.Namespace) -> int:
    from .fleet import FleetDir

    if getattr(args, "json", False) or getattr(args, "watch", False):
        # the /status schema off the bus: same serializer as the endpoint
        from .obs import status_snapshot
        polls = 0
        while True:
            snap = status_snapshot(fleet=args.fleet)
            if args.watch:
                _print_fleet_line(snap)
            else:
                print(json.dumps(snap, indent=1, sort_keys=True,
                                 default=str))
            polls += 1
            if not args.watch or (args.max_polls and polls >= args.max_polls):
                return 0
            try:
                time.sleep(args.interval)
            except KeyboardInterrupt:
                return 0

    fleet = FleetDir(args.fleet)
    out = fleet.status()
    report = fleet.root / "report.json"
    if report.exists():
        out["report"] = json.loads(report.read_text())
    print(json.dumps(out, indent=1, sort_keys=True))
    return 0


def _print_fleet_line(snap: Dict) -> None:
    """One compact --watch line from the shared snapshot schema."""
    fleet = snap.get("fleet") or {}
    counts = fleet.get("counts") or {}
    report = fleet.get("report") or {}
    shards = fleet.get("shard_records") or {}
    print(f"[fleet] queue={counts.get('queue', 0)} "
          f"leases={counts.get('leases', 0)} done={counts.get('done', 0)} "
          f"failed={counts.get('failed', 0)} "
          f"shard_records={sum(shards.values())} "
          f"merged={report.get('merged_records', 0)} "
          f"sentry_blocked={report.get('sentry_blocked', 0)} "
          f"draining={bool(fleet.get('draining'))}", flush=True)


def _cmd_fleet_drain(args: argparse.Namespace) -> int:
    import time as _time

    from .fleet import Coordinator, FleetDir

    t0 = _time.time()
    FleetDir(args.fleet).request_drain()
    print(f"[fleet] drain requested: workers exit once {args.fleet} "
          "has an empty queue")
    if args.wait:
        return _fleet_finalize(Coordinator(args.fleet), args, t0)
    if args.compact:
        # no --wait: compact what is already merged, right now — the flag
        # must never be a silent no-op
        coord = Coordinator(args.fleet)
        coord.poll()                     # sweep + merge whatever landed
        if coord.outstanding() == 0:
            archived = coord.compact_shards()
            print(f"[fleet] compacted {len(archived)} merged shard(s) "
                  f"-> {coord.fleet.shard_dir() / 'archive'}")
        else:
            print(f"[fleet] skipping --compact: {coord.outstanding()} "
                  "job(s) still outstanding (use --wait)", file=sys.stderr)
    return 0


def _cmd_fleet_route(args: argparse.Namespace) -> int:
    """Dry-run one routing decision against published per-replica plans.

    Loads the current plan from every per-replica registry under
    ``--registry-root`` (what ``Coordinator.publish_replica_plans`` writes),
    scores the ``--shape`` request against each with the same
    ``plan_coverage`` probe the in-engine router uses, and prints the
    chosen replica — the operator's answer to "where would this request
    land, and why".
    """
    from repro.core.space import SPACES
    from repro.serve.router import make_router, plan_coverage

    from .plans import PlanArtifactError, PlanRegistry

    if args.shape and not args.space:
        raise SystemExit("--shape needs --space")
    shapes = [(args.space, _parse_shape(spec, SPACES[args.space]))
              for spec in args.shape]

    root = pathlib.Path(args.registry_root)
    replica_dirs = sorted(d for d in root.glob(args.glob) if d.is_dir())
    if not replica_dirs:
        raise SystemExit(f"[fleet] no replica registries matching "
                         f"{args.glob!r} under {root}")
    router = make_router(args.policy)
    plans: Dict[str, object] = {}
    for d in replica_dirs:
        reg = PlanRegistry(d)
        pointer = reg.current()
        plan = None
        if pointer is not None:
            try:
                plan = reg.pull(pointer)
            except PlanArtifactError as e:
                print(f"[fleet] {d.name}: plan rejected ({e})",
                      file=sys.stderr)
        plans[d.name] = plan
        router.add_replica(d.name, plan=plan)

    picked = router.route(shapes)
    outcomes = router.stats()["outcomes"]
    out = {
        "policy": args.policy,
        "replica": picked.name,
        "outcome": next(iter(outcomes)),
        "shapes": [{"space": s, "inputs": i} for s, i in shapes],
        "coverage": {name: plan_coverage(p, shapes)
                     for name, p in plans.items()},
        "plan_entries": {name: (len(p) if p is not None else 0)
                         for name, p in plans.items()},
    }
    print(json.dumps(out, indent=1, sort_keys=True))
    return 0


# ---------------------------------------------------------------------------
# plan: golden dispatch-plan artifacts (export / inspect / publish / follow)
# ---------------------------------------------------------------------------

def _compile_plan_from_args(args: argparse.Namespace):
    """(store, DispatchPlan) compiled from --store/--models-dir/--telemetry."""
    from .model import ModelSet, default_models_dir
    from .store import compile_plan

    store = RecordStore.open(args.store)
    models = None
    if not args.no_models:
        mdir = pathlib.Path(args.models_dir or default_models_dir(args.store))
        if mdir.is_dir():
            loaded = ModelSet.load(mdir)
            if len(loaded):
                models = loaded
    telemetry = None
    if args.telemetry and os.path.exists(args.telemetry):
        telemetry = ShapeTelemetry.load(args.telemetry)
    plan = compile_plan(store, models, args.backend,
                        telemetry=telemetry, hot_k=args.hot_k)
    if plan is None or not len(plan):
        raise SystemExit(f"[tunedb] nothing to plan: store {args.store} has "
                         "no serving records under this fingerprint")
    return store, plan


def _cmd_plan_export(args: argparse.Namespace) -> int:
    from .plans import PlanArtifactError, default_plan_dir, export_plan

    store, plan = _compile_plan_from_args(args)
    out = args.out or default_plan_dir(store.path)
    try:
        dest = export_plan(plan, out, store=store,
                           generation=args.generation)
    except PlanArtifactError as e:       # includes the stale-store refusal
        print(f"[tunedb] plan export refused: {e}", file=sys.stderr)
        return 1
    print(f"[tunedb] exported plan ({len(plan)} entries) -> {dest}")
    return 0


def _cmd_plan_inspect(args: argparse.Namespace) -> int:
    from .plans import PlanArtifactError, load_plan, read_manifest

    try:
        manifest = read_manifest(args.plan_dir)
        plan = load_plan(args.plan_dir)      # digest + schema verification
    except PlanArtifactError as e:
        print(f"[tunedb] plan artifact rejected: {e}", file=sys.stderr)
        return 1
    out = dict(manifest.to_dict())
    out["verified"] = True
    out["tiers"] = plan.stats()["tiers"]
    print(json.dumps(out, indent=1, sort_keys=True))
    return 0


def _cmd_plan_publish(args: argparse.Namespace) -> int:
    from .plans import PlanArtifactError, PlanRegistry

    store, plan = _compile_plan_from_args(args)
    try:
        manifest = PlanRegistry(args.registry).publish(plan, store=store)
    except PlanArtifactError as e:
        print(f"[tunedb] plan publish refused: {e}", file=sys.stderr)
        return 1
    print(f"[tunedb] published generation {manifest.generation} "
          f"({manifest.n_entries} entries, {manifest.digest}) "
          f"-> {args.registry}")
    return 0


def _cmd_plan_follow(args: argparse.Namespace) -> int:
    from .obs import RegressionSentry
    from .plans import PlanFollower

    store = None
    if args.store and os.path.exists(args.store):
        store = RecordStore.open(args.store)
    sentry = None if args.no_sentry else RegressionSentry(
        noise_margin=args.margin)
    follower = PlanFollower(args.registry, store=store,
                            fingerprint=args.backend,
                            poll_s=args.interval, sentry=sentry)
    print(f"[tunedb] following {args.registry} every {args.interval:g}s "
          "— Ctrl-C to stop")
    polls = 0
    try:
        while True:
            installed = follower.poll_once()
            polls += 1
            if installed is not None:
                print(f"[tunedb] installed generation "
                      f"{installed['generation']} "
                      f"({installed.get('n_entries', '?')} entries, "
                      f"lag {follower.lag_s:.2f}s)")
            if args.max_polls and polls >= args.max_polls:
                break
            time.sleep(args.interval)
    except KeyboardInterrupt:
        pass
    finally:
        follower.stop()
    stats = follower.stats()
    print(json.dumps(stats, indent=1, sort_keys=True))
    return 0 if stats["installs"] or not args.max_polls else 1


def _cmd_models(args: argparse.Namespace) -> int:
    from .model import ModelSet, default_models_dir

    models = ModelSet.load(args.models_dir or default_models_dir(args.store))
    print(json.dumps(models.stats(), indent=1, sort_keys=True))
    return 0 if len(models) or not models.skipped else 1


def _cmd_stats(args: argparse.Namespace) -> int:
    store = RecordStore.open(args.store)
    telemetry = None
    if args.telemetry and os.path.exists(args.telemetry):
        telemetry = ShapeTelemetry.load(args.telemetry)
    if getattr(args, "json", False):
        # the /status schema, exactly: one serializer for CLI and HTTP
        from .obs import status_snapshot
        out = status_snapshot(store=store, telemetry=telemetry)
    else:
        out = {"store": store.stats()}
        if telemetry is not None:
            out["telemetry"] = telemetry.stats()
    print(json.dumps(out, indent=1, sort_keys=True, default=str))
    return 0


def _collect_trace_spans(args: argparse.Namespace):
    """Spans from --fleet traces/ and/or explicit span files (JSONL dumps
    or Chrome trace JSON) — torn files skip, never raise."""
    from .obs.trace import collect_fleet_spans, load_span_file
    spans = []
    if getattr(args, "fleet", None):
        spans.extend(collect_fleet_spans(args.fleet))
    for path in getattr(args, "inputs", None) or []:
        spans.extend(load_span_file(path))
    return spans


def _cmd_trace_export(args: argparse.Namespace) -> int:
    from .obs.trace import chrome_trace
    spans = _collect_trace_spans(args)
    doc = chrome_trace(spans, pid=0)    # merged view: no one live process
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(doc))
    print(f"[trace] wrote {len(spans)} span(s) -> {out} "
          "(open in https://ui.perfetto.dev)")
    return 0


def _cmd_trace_summary(args: argparse.Namespace) -> int:
    from .obs.trace import summarize_spans
    spans = _collect_trace_spans(args)
    summary = summarize_spans(spans)
    if getattr(args, "json", False):
        print(json.dumps(summary, indent=1, sort_keys=True, default=str))
        return 0
    print(f"spans: {summary['spans']}  traces: {summary['traces']}")
    for name, ent in sorted(summary["names"].items()):
        print(f"  {name:<20} x{int(ent['count']):<6} "
              f"mean {ent['mean_us']:.1f}us  max {ent['max_us']:.1f}us")
    if summary["tiers"]:
        print("dispatch tiers:")
        for tier, ent in sorted(summary["tiers"].items()):
            print(f"  {tier:<20} x{int(ent['count']):<6} "
                  f"mean {ent['mean_us']:.1f}us")
    return 0


def _cmd_serve_status(args: argparse.Namespace) -> int:
    from .obs import StatusServer
    from .store import install_serving

    store = telemetry = None
    if args.store and os.path.exists(args.store):
        store = RecordStore.open(args.store)
        # make the store the process's serving state so the /metrics
        # collectors and /plan see it exactly like an engine would
        install_serving(store=store, fingerprint=args.backend)
    if args.telemetry and os.path.exists(args.telemetry):
        telemetry = ShapeTelemetry.load(args.telemetry)
    server = StatusServer(host=args.host, port=args.port, store=store,
                          telemetry=telemetry, fleet=args.fleet).start()
    print(f"[tunedb] status endpoint on {server.url} "
          f"(/metrics /status /plan) — Ctrl-C to stop")
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        server.stop()
    return 0


def _load_generation(path: str):
    """A diffable generation: a store JSONL, or a /plan JSON snapshot.

    Returns ("store", RecordStore) or ("plan", dict)."""
    with open(path, "r", encoding="utf-8") as fh:
        head = fh.read(4096).lstrip()
    if head.startswith("{"):
        try:
            doc = json.loads(pathlib.Path(path).read_text())
        except ValueError:
            doc = None
        if isinstance(doc, dict) and "entries" in doc:
            return "plan", doc
    return "store", RecordStore.open(path)


def _cmd_diff(args: argparse.Namespace) -> int:
    from .obs import RegressionSentry

    sentry = RegressionSentry(noise_margin=args.margin)
    old_kind, old = _load_generation(args.old)
    new_kind, new = _load_generation(args.new)
    if old_kind != new_kind:
        print(f"[tunedb] cannot diff a {old_kind} against a {new_kind}",
              file=sys.stderr)
        return 2
    if old_kind == "plan":
        report = sentry.diff_plans(old, new)
    else:
        report = sentry.diff_stores(old, new)
    if args.json:
        print(json.dumps(report.to_dict(), indent=1, sort_keys=True))
    else:
        print(f"[tunedb] diff {args.old} -> {args.new}: "
              f"{report.checked} shared key(s) checked, "
              f"{report.improved} improved, {report.unchanged} unchanged, "
              f"{report.added} added, {report.removed} removed "
              f"(noise margin {report.noise_margin:.0%})")
        for reg in report.regressions:
            if reg.old_tflops > 0:
                print(f"[tunedb]   REGRESSED {reg.space} "
                      f"{_fmt_inputs(reg.inputs)} [{reg.backend}]: "
                      f"{reg.old_tflops:.2f} -> {reg.new_tflops:.2f} "
                      f"TFLOPS (-{reg.drop:.0%})")
            else:
                print(f"[tunedb]   DROPPED {reg.space} "
                      f"{_fmt_inputs(reg.inputs)}: planned entry missing "
                      f"from the new generation")
        verdict = "OK" if report.ok else \
            f"{len(report.regressions)} regression(s)"
        print(f"[tunedb] verdict: {verdict}")
    return 0 if report.ok else 1


def _fmt_inputs(inputs) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(inputs.items()))


def _cmd_export(args: argparse.Namespace) -> int:
    n = RecordStore.open(args.store).export(args.out)
    print(f"[tunedb] exported {n} records -> {args.out}")
    return 0


def _cmd_merge(args: argparse.Namespace) -> int:
    merged = RecordStore.open(args.out)
    total = 0
    for path in args.stores:
        total += merged.merge(RecordStore.open(path))
    print(f"[tunedb] merged {total} records from {len(args.stores)} "
          f"stores -> {args.out} ({len(merged)} shapes)")
    return 0


def _cmd_fsck(args: argparse.Namespace) -> int:
    """Integrity-check a store (+ optional plan registry / fleet bus).

    Exit 0: everything verified (or every piece of damage was quarantined
    by ``--repair``).  Exit 1: damage present and unrepaired, or
    unrecoverable loss (a registry CURRENT pointing at an artifact that
    cannot be digest-verified — recompile and republish is the only fix).
    """
    from .store import TuneRecord

    report: dict = {"store": None, "plans": None, "fleet": None}
    damaged = 0          # findings --repair can (and did, if set) quarantine
    unrecoverable = 0    # findings no repair can undo

    # -- store: line + CRC scan (raw read: no load side effects) ------------
    store_path = pathlib.Path(args.store)
    bad_lines: List[int] = []
    n_lines = 0
    if store_path.exists():
        raw = store_path.read_text(encoding="utf-8")
        lines = raw.splitlines()
        torn_tail = bool(raw) and not raw.endswith("\n")
        for i, line in enumerate(lines, 1):
            if not line.strip():
                continue
            n_lines += 1
            try:
                TuneRecord.from_json(line)
            except ValueError:
                bad_lines.append(i)
        damaged += len(bad_lines)
        repaired = None
        if bad_lines and args.repair:
            store = RecordStore.open(store_path)   # load quarantines copies
            repaired = store.repair()              # rewrite drops bad lines
            qdir = store.quarantine_dir()
            print(f"[fsck] store {store_path}: quarantined "
                  f"{repaired['quarantined']} line(s) -> {qdir}, "
                  f"kept {repaired['kept']}")
        report["store"] = {
            "path": str(store_path), "lines": n_lines,
            "bad_lines": bad_lines, "torn_tail": torn_tail,
            "repaired": repaired}
        status = "clean" if not bad_lines else (
            "repaired" if args.repair else "DAMAGED")
        print(f"[fsck] store {store_path}: {n_lines} line(s), "
              f"{len(bad_lines)} bad ({status})")
    else:
        print(f"[fsck] store {store_path}: missing (nothing to check)")

    # -- plan artifacts: digest-verify every generation ---------------------
    from .plans import (CURRENT_NAME, GENERATIONS, MANIFEST_NAME,
                        PlanArtifactError, default_plan_dir, load_plan)
    plans_dir = pathlib.Path(args.plans) if args.plans else None
    if plans_dir is None and default_plan_dir(store_path).is_dir():
        plans_dir = default_plan_dir(store_path)
    if plans_dir is not None:
        gen_root = plans_dir / GENERATIONS
        targets = (sorted(d for d in gen_root.iterdir() if d.is_dir())
                   if gen_root.is_dir() else
                   [plans_dir] if (plans_dir / MANIFEST_NAME).exists()
                   else [])
        current_gen = None
        if (plans_dir / CURRENT_NAME).exists():
            try:
                current_gen = int(json.loads(
                    (plans_dir / CURRENT_NAME).read_text())["generation"])
            except (ValueError, KeyError, TypeError, OSError):
                print(f"[fsck] plans {plans_dir}: CURRENT pointer "
                      "unreadable (UNRECOVERABLE: republish)")
                unrecoverable += 1
        bad_gens: List[str] = []
        for gdir in targets:
            try:
                load_plan(gdir)
            except PlanArtifactError as e:
                bad_gens.append(gdir.name)
                is_current = (current_gen is not None
                              and gdir.name == f"{current_gen:08d}")
                if is_current:
                    # the pointer's own artifact is torn: followers cannot
                    # pull it and quarantining would orphan the pointer
                    print(f"[fsck] plans {plans_dir}: CURRENT generation "
                          f"{gdir.name} failed verification "
                          f"(UNRECOVERABLE: {e})")
                    unrecoverable += 1
                else:
                    damaged += 1
                    if args.repair:
                        qdir = plans_dir / "quarantine"
                        qdir.mkdir(parents=True, exist_ok=True)
                        os.replace(gdir, qdir / gdir.name)
                        print(f"[fsck] plans {plans_dir}: quarantined torn "
                              f"generation {gdir.name} -> {qdir}")
        report["plans"] = {"path": str(plans_dir),
                           "generations": len(targets),
                           "bad": bad_gens, "current": current_gen}
        status = "clean" if not bad_gens and not unrecoverable else (
            "repaired" if args.repair and not unrecoverable else "DAMAGED")
        print(f"[fsck] plans {plans_dir}: {len(targets)} artifact(s), "
              f"{len(bad_gens)} bad ({status})")

    # -- fleet bus invariants ----------------------------------------------
    if args.fleet:
        from .fleet import FleetDir
        from .fleet.lease import FleetJob
        fd = FleetDir(args.fleet)
        orphans: List[str] = []      # lease or queue entry behind a marker
        garbage: List[str] = []      # unparseable protocol files
        for kind, d in (("queue", fd.queue), ("lease", fd.leases)):
            if not d.is_dir():
                continue
            for p in sorted(d.glob("*.json")):
                try:
                    FleetJob.from_json(p.read_text(encoding="utf-8"))
                except (ValueError, KeyError, TypeError):
                    garbage.append(f"{kind}:{p.name}")
                    damaged += 1
                    if args.repair:
                        qdir = fd.root / "quarantine"
                        qdir.mkdir(parents=True, exist_ok=True)
                        os.replace(p, qdir / f"{kind}-{p.name}")
                    continue
                done = (fd.done / p.name).exists()
                if done:
                    # done-marker is the durable truth: a leftover lease or
                    # re-queued duplicate of a finished job is an orphan
                    orphans.append(f"{kind}:{p.name}")
                    damaged += 1
                    if args.repair:
                        p.unlink(missing_ok=True)
        if args.repair and (orphans or garbage):
            print(f"[fsck] fleet {fd.root}: removed {len(orphans)} "
                  f"orphan(s), quarantined {len(garbage)} garbage file(s)")
        report["fleet"] = {"path": str(fd.root), "orphans": orphans,
                           "garbage": garbage, "counts": fd.counts()}
        status = "clean" if not orphans and not garbage else (
            "repaired" if args.repair else "DAMAGED")
        print(f"[fsck] fleet {fd.root}: {len(orphans)} orphan(s), "
              f"{len(garbage)} garbage file(s) ({status})")

    if args.json:
        print(json.dumps(report, indent=1, sort_keys=True))
    if unrecoverable:
        print(f"[fsck] verdict: UNRECOVERABLE ({unrecoverable} finding(s))")
        return 1
    if damaged and not args.repair:
        print(f"[fsck] verdict: {damaged} finding(s) "
              "(re-run with --repair to quarantine)")
        return 1
    print("[fsck] verdict: OK" if not damaged
          else f"[fsck] verdict: OK ({damaged} finding(s) repaired)")
    return 0


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(prog="python -m repro.tunedb",
                                description=__doc__.splitlines()[0])
    sub = p.add_subparsers(dest="cmd", required=True)

    t = sub.add_parser("tune", help="tune shapes into a store")
    t.add_argument("--space", default="gemm",
                   choices=["gemm", "conv", "attention", "ssd"])
    t.add_argument("--store", default=DEFAULT_STORE)
    t.add_argument("--telemetry", default=None,
                   help="telemetry JSON dump (ShapeTelemetry.save)")
    t.add_argument("--shapes-from-telemetry", action="store_true",
                   help="mine jobs from the --telemetry file")
    t.add_argument("--shape", action="append", default=[],
                   help="explicit shape, e.g. M=4096,N=16,K=2560 (repeatable)")
    t.add_argument("--top-k", type=int, default=8,
                   help="how many hot shapes to tune")
    t.add_argument("--workers", type=int, default=4)
    t.add_argument("--train-samples", type=int, default=8000)
    t.add_argument("--epochs", type=int, default=25)
    t.add_argument("--seed", type=int, default=0)
    t.add_argument("--no-remeasure", action="store_true",
                   help="trust the model; skip top-k re-measurement")
    t.add_argument("--retune", action="store_true",
                   help="re-tune shapes already present in the store")
    t.add_argument("--progress", default=None,
                   help="resumable progress file for long sessions")
    t.add_argument("--load-tuner", default=None,
                   help="load a trained tuner dir instead of training")
    t.add_argument("--save-tuner", default=None)
    t.set_defaults(fn=_cmd_tune)

    def hidden_arg(spec: str):
        try:
            return tuple(int(x) for x in spec.split(",") if x)
        except ValueError:
            raise SystemExit(f"bad --hidden {spec!r} (want e.g. 64,128,64)")

    tr = sub.add_parser("train", help="train performance models from a store")
    tr.add_argument("--store", default=DEFAULT_STORE)
    tr.add_argument("--models-dir", default=None,
                    help="artifact dir (default: <store>.models/)")
    tr.add_argument("--space", default=None,
                    choices=["gemm", "conv", "attention", "ssd"],
                    help="restrict to one space (default: all in the store)")
    tr.add_argument("--samples-per-shape", type=int, default=48,
                    help="label this many random legal configs per tuned "
                         "shape before training (0 = harvest only)")
    tr.add_argument("--min-samples", type=int, default=24,
                    help="skip (space, backend) groups smaller than this")
    tr.add_argument("--epochs", type=int, default=30)
    tr.add_argument("--hidden", type=hidden_arg, default=(64, 128, 64),
                    help="MLP hidden sizes, e.g. 64,128,64")
    tr.add_argument("--seed", type=int, default=0)
    tr.set_defaults(fn=_cmd_train)

    pr = sub.add_parser("predict", help="model-guided config for a shape")
    pr.add_argument("--store", default=DEFAULT_STORE)
    pr.add_argument("--models-dir", default=None)
    pr.add_argument("--space", default="gemm",
                    choices=["gemm", "conv", "attention", "ssd"])
    pr.add_argument("--backend", default=None,
                    help="backend fingerprint (default: newest model)")
    pr.add_argument("--shape", action="append", required=True,
                    help="shape to predict for, e.g. M=4096,N=16,K=2560")
    pr.add_argument("--top-k", type=int, default=5)
    pr.set_defaults(fn=_cmd_predict)

    mo = sub.add_parser("models", help="list persisted model artifacts")
    mo.add_argument("--store", default=DEFAULT_STORE)
    mo.add_argument("--models-dir", default=None)
    mo.set_defaults(fn=_cmd_models)

    def add_retune_args(rp):
        rp.add_argument("--store", default=DEFAULT_STORE)
        rp.add_argument("--telemetry", required=True,
                        help="telemetry JSON dump (ShapeTelemetry.save)")
        rp.add_argument("--baseline", default=None,
                        help="epoch-baseline telemetry dump "
                             "(default: <telemetry>.epoch)")
        rp.add_argument("--models-dir", default=None,
                        help="retrained artifacts dir "
                             "(default: <store>.models/)")
        rp.add_argument("--drift", type=float, default=0.25,
                        help="hot-shape mass TV-distance trigger")
        rp.add_argument("--untuned", type=float, default=0.5,
                        help="untuned window-mass trigger")
        rp.add_argument("--min-calls", type=int, default=32,
                        help="window calls before a space is judged")
        rp.add_argument("--top-k", type=int, default=4,
                        help="novel hot shapes tuned per retune")
        rp.add_argument("--workers", type=int, default=2)
        rp.add_argument("--no-train", action="store_true",
                        help="skip the regressor retrain step")
        rp.add_argument("--force", action="store_true",
                        help="retune every space with novel hot shapes, "
                             "ignoring the thresholds")
        rp.add_argument("--load-tuner", default=None,
                        help="load a trained tuner dir instead of training")
        rp.add_argument("--train-samples", type=int, default=4000)
        rp.add_argument("--epochs", type=int, default=12)
        rp.add_argument("--seed", type=int, default=0)
        rp.add_argument("--publish", default=None,
                        help="after a successful swap, publish the new "
                             "generation's plan to this registry dir")

    rt = sub.add_parser(
        "retune", help="one drift-triggered retune pass over a telemetry dump")
    add_retune_args(rt)
    rt.set_defaults(fn=_cmd_retune)

    w = sub.add_parser(
        "watch", help="poll telemetry and retune continuously")
    add_retune_args(w)
    w.add_argument("--interval", type=float, default=60.0,
                   help="seconds between polls")
    w.add_argument("--max-polls", type=int, default=0,
                   help="stop after this many polls (0 = forever)")
    w.set_defaults(fn=_cmd_watch)

    fl = sub.add_parser("fleet", help="distributed tuning over a shared dir")
    fsub = fl.add_subparsers(dest="fleet_cmd", required=True)

    fs = fsub.add_parser("start", help="init a fleet dir and publish a plan")
    fs.add_argument("--fleet", required=True, help="fleet directory (the bus)")
    fs.add_argument("--store", default=DEFAULT_STORE,
                    help="parent record store (shards land next to it)")
    fs.add_argument("--telemetry", default=None,
                    help="mine hot shapes from this telemetry dump")
    fs.add_argument("--space", default=None,
                    choices=["gemm", "conv", "attention", "ssd"],
                    help="restrict mining to one space (required by --shape)")
    fs.add_argument("--shape", action="append", default=[],
                    help="explicit job, e.g. M=4096,N=16,K=2560 (repeatable)")
    fs.add_argument("--top-k", type=int, default=8,
                    help="hot shapes per space to publish")
    fs.add_argument("--backend", default=None,
                    help="skip shapes already tuned under this fingerprint "
                         "(default: any backend)")
    fs.add_argument("--retune", action="store_true",
                    help="publish shapes the store already serves too")
    fs.add_argument("--lease-timeout", type=float, default=30.0,
                    help="seconds without a heartbeat before a lease is "
                         "returned to the queue")
    fs.add_argument("--max-attempts", type=int, default=3)
    fs.add_argument("--drain", action="store_true",
                    help="mark the plan final: workers exit when it empties")
    fs.add_argument("--wait", action="store_true",
                    help="poll until every job lands, merging shards as "
                         "they fill; then report")
    fs.add_argument("--workers", type=int, default=0,
                    help="spawn N local fleet-worker subprocesses so one "
                         "command runs the whole laptop fleet (implies "
                         "--wait, and --drain so the workers exit when the "
                         "plan empties)")
    fs.add_argument("--load-tuner", default=None,
                    help="trained tuner dir forwarded to spawned workers")
    fs.add_argument("--worker-train-samples", type=int, default=4000,
                    help="tuner training size for spawned workers")
    fs.add_argument("--worker-epochs", type=int, default=12)
    _add_fleet_finalize_args(fs)
    fs.set_defaults(fn=_cmd_fleet_start)

    fw = fsub.add_parser("worker", help="run one fleet worker process")
    fw.add_argument("--fleet", required=True)
    fw.add_argument("--worker-id", default=None,
                    help="stable shard id (default: host-pid-random)")
    fw.add_argument("--max-jobs", type=int, default=0,
                    help="exit after this many claims (0 = until drained)")
    fw.add_argument("--idle-timeout", type=float, default=0.0,
                    help="exit after this long with an empty queue "
                         "(0 = wait for DRAIN)")
    fw.add_argument("--no-remeasure", action="store_true")
    fw.add_argument("--load-tuner", default=None,
                    help="load a trained tuner dir instead of training")
    fw.add_argument("--train-samples", type=int, default=4000)
    fw.add_argument("--epochs", type=int, default=12)
    fw.add_argument("--seed", type=int, default=0)
    fw.add_argument("--telemetry-export", type=float, default=0.0,
                    help="export this worker's shape telemetry to the "
                         "fleet bus every N seconds (0 = off); the "
                         "coordinator aggregates dumps into the "
                         "fleet-global view")
    fw.add_argument("--trace-sample", type=float, default=0.0,
                    help="enable tracing at this root sample rate (jobs "
                         "carrying a coordinator trace_id are always "
                         "kept); finished spans dump to "
                         "<fleet>/traces/<worker_id>.jsonl at exit")
    fw.set_defaults(fn=_cmd_fleet_worker)

    fst = fsub.add_parser("status", help="print fleet state as JSON")
    fst.add_argument("--fleet", required=True)
    fst.add_argument("--json", action="store_true",
                     help="emit the full /status snapshot schema (the "
                          "same serializer the HTTP endpoint uses)")
    fst.add_argument("--watch", action="store_true",
                     help="poll the bus and print one progress line per "
                          "--interval seconds (Ctrl-C to stop)")
    fst.add_argument("--interval", type=float, default=2.0)
    fst.add_argument("--max-polls", type=int, default=0,
                     help="stop --watch after N polls (0 = forever)")
    fst.set_defaults(fn=_cmd_fleet_status)

    fd = fsub.add_parser("drain", help="stop the fleet once the queue empties")
    fd.add_argument("--fleet", required=True)
    fd.add_argument("--wait", action="store_true",
                    help="wait for outstanding jobs, merge, and report")
    _add_fleet_finalize_args(fd)
    fd.set_defaults(fn=_cmd_fleet_drain)

    fr = fsub.add_parser(
        "route", help="dry-run shape-affinity routing against per-replica "
                      "plan registries")
    fr.add_argument("--registry-root", required=True,
                    help="directory holding the per-replica plan registries "
                         "(what the coordinator's replica-plan publish "
                         "writes)")
    fr.add_argument("--glob", default="replica-*",
                    help="registry subdirectory pattern under the root")
    fr.add_argument("--space", default=None,
                    choices=["gemm", "conv", "attention", "ssd"],
                    help="space the --shape flags belong to")
    fr.add_argument("--shape", action="append", default=[],
                    help="request shape, e.g. M=4096,N=16,K=2560 "
                         "(repeatable: a request may carry several shapes)")
    fr.add_argument("--policy", default="affinity",
                    choices=["affinity", "round_robin", "random"])
    fr.set_defaults(fn=_cmd_fleet_route)

    pl = sub.add_parser(
        "plan", help="golden dispatch-plan artifacts (see docs/PLANS.md)")
    psub = pl.add_subparsers(dest="plan_cmd", required=True)

    def add_plan_compile_args(sp):
        sp.add_argument("--store", default=DEFAULT_STORE)
        sp.add_argument("--models-dir", default=None,
                        help="model artifacts consulted for the hot-set "
                             "pre-resolution (default: <store>.models/)")
        sp.add_argument("--no-models", action="store_true",
                        help="compile from records + nearest only")
        sp.add_argument("--telemetry", default=None,
                        help="telemetry dump whose hot set gets pre-resolved")
        sp.add_argument("--backend", default=None,
                        help="fingerprint the plan is keyed to (None = any)")
        sp.add_argument("--hot-k", type=int, default=32,
                        help="hot shapes per space to pre-resolve")

    pe = psub.add_parser(
        "export", help="compile a store into a versioned plan artifact")
    add_plan_compile_args(pe)
    pe.add_argument("--out", default=None,
                    help="artifact root (default: <store>.plan/)")
    pe.add_argument("--generation", type=int, default=None,
                    help="explicit generation number (default: next free)")
    pe.set_defaults(fn=_cmd_plan_export)

    pi = psub.add_parser(
        "inspect", help="verify (schema+digest) and print a plan artifact")
    pi.add_argument("plan_dir", help="one generation's artifact directory")
    pi.set_defaults(fn=_cmd_plan_inspect)

    pp = psub.add_parser(
        "publish", help="compile + publish the next generation to a registry")
    add_plan_compile_args(pp)
    pp.add_argument("--registry", required=True,
                    help="plan registry directory followers poll")
    pp.set_defaults(fn=_cmd_plan_publish)

    pf = psub.add_parser(
        "follow", help="poll a registry, hot-swap each new generation")
    pf.add_argument("--registry", required=True)
    pf.add_argument("--store", default=None,
                    help="record store to serve alongside the plan")
    pf.add_argument("--backend", default=None,
                    help="fingerprint pin for the serving state")
    pf.add_argument("--interval", type=float, default=2.0,
                    help="seconds between registry polls")
    pf.add_argument("--max-polls", type=int, default=0,
                    help="stop after N polls (0 = forever)")
    pf.add_argument("--margin", type=float, default=0.10,
                    help="sentry noise margin for the coverage diff")
    pf.add_argument("--no-sentry", action="store_true",
                    help="skip the RegressionSentry plan diff before a swap")
    pf.set_defaults(fn=_cmd_plan_follow)

    tc = sub.add_parser(
        "trace", help="request-trace spans (see docs/OBSERVABILITY.md)")
    tsub = tc.add_subparsers(dest="trace_cmd", required=True)

    def add_trace_input_args(sp):
        sp.add_argument("--fleet", default=None,
                        help="merge every worker span dump under "
                             "<fleet>/traces/")
        sp.add_argument("--input", dest="inputs", action="append",
                        default=None, metavar="FILE",
                        help="span JSONL dump or Chrome trace JSON "
                             "(repeatable); torn files are skipped")

    te = tsub.add_parser(
        "export", help="merge span dumps into one Chrome trace JSON")
    add_trace_input_args(te)
    te.add_argument("--out", required=True,
                    help="Chrome trace-event JSON path (Perfetto-loadable)")
    te.set_defaults(fn=_cmd_trace_export)

    tu = tsub.add_parser(
        "summary", help="per-span-name latency + dispatch-tier attribution")
    add_trace_input_args(tu)
    tu.add_argument("--json", action="store_true")
    tu.set_defaults(fn=_cmd_trace_summary)

    s = sub.add_parser("stats", help="print store/telemetry statistics")
    s.add_argument("--store", default=DEFAULT_STORE)
    s.add_argument("--telemetry", default=None)
    s.add_argument("--json", action="store_true",
                   help="emit the full /status snapshot schema (the same "
                        "serializer the HTTP endpoint uses)")
    s.set_defaults(fn=_cmd_stats)

    ss = sub.add_parser(
        "serve-status",
        help="HTTP observability endpoint: /metrics, /status, /plan, "
             "/trace")
    ss.add_argument("--store", default=DEFAULT_STORE)
    ss.add_argument("--telemetry", default=None)
    ss.add_argument("--fleet", default=None,
                    help="include this fleet bus in /status")
    ss.add_argument("--backend", default=None,
                    help="pin the installed serving view to one fingerprint")
    ss.add_argument("--host", default="127.0.0.1")
    ss.add_argument("--port", type=int, default=9177)
    ss.set_defaults(fn=_cmd_serve_status)

    d = sub.add_parser(
        "diff",
        help="regression sentry: compare two store (or /plan snapshot) "
             "generations; exit 1 when the new one regresses")
    d.add_argument("old", help="baseline store JSONL or /plan JSON")
    d.add_argument("new", help="candidate store JSONL or /plan JSON")
    d.add_argument("--margin", type=float, default=0.10,
                   help="noise margin: flag only records slower than "
                        "old*(1-margin) (default 0.10)")
    d.add_argument("--json", action="store_true")
    d.set_defaults(fn=_cmd_diff)

    e = sub.add_parser("export", help="compact a store (latest per shape)")
    e.add_argument("--store", default=DEFAULT_STORE)
    e.add_argument("--out", required=True)
    e.set_defaults(fn=_cmd_export)

    m = sub.add_parser("merge", help="fold stores into one")
    m.add_argument("stores", nargs="+")
    m.add_argument("--out", required=True)
    m.set_defaults(fn=_cmd_merge)

    f = sub.add_parser(
        "fsck", help="verify store/plan/fleet integrity; --repair "
                     "quarantines damage")
    f.add_argument("store", nargs="?", default=DEFAULT_STORE,
                   help="record store to scan (line + CRC integrity)")
    f.add_argument("--plans", default=None,
                   help="plan registry or artifact dir to digest-verify "
                        "(default: <store>.plan when present)")
    f.add_argument("--fleet", default=None,
                   help="fleet bus dir to check for orphan leases, "
                        "done-marker duplicates, and garbage files")
    f.add_argument("--repair", action="store_true",
                   help="quarantine damaged lines/artifacts and remove "
                        "orphaned bus entries")
    f.add_argument("--json", action="store_true",
                   help="print the full finding report as JSON")
    f.set_defaults(fn=_cmd_fsck)
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
