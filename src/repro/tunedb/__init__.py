"""repro.tunedb — tuning-record database + shape-telemetry subsystem.

The persistence backbone of the input-aware runtime:

  store.py      versioned append-only JSONL record store (fingerprint-keyed),
                nearest-shape lookup
  telemetry.py  (space, input-shape) frequency counters fed by kernel dispatch
  model.py      performance regressors trained FROM the store, served per
                (space, backend fingerprint) at dispatch (paper §5-§6)
  session.py    tune the top-K hot shapes on a worker pool, commit to a store
  __main__.py   ``python -m repro.tunedb`` tune / train / predict / models /
                stats / export / merge CLI

The loop: dispatch records every kernel call's shape -> a TuningSession mines
the hottest shapes and tunes them -> ``train`` distills the accumulated
measurements into per-(space, backend) MLP regressors -> serving processes
warm-start from the store + model artifacts and resolve configs three-tier:
exact record hit, model-guided search, nearest-shape fallback — no tuner in
the process at all.
"""

from .store import (SCHEMA_VERSION, RecordStore, TuneRecord,
                    active_fingerprint, clear_store, get_store, input_key,
                    install_store, normalize_config)
from .telemetry import (ShapeTelemetry, clear_telemetry, get_telemetry,
                        record_shape)

__all__ = [
    "SCHEMA_VERSION", "RecordStore", "TuneRecord", "active_fingerprint",
    "clear_store", "get_store", "input_key", "install_store",
    "normalize_config",
    "ShapeTelemetry", "clear_telemetry", "get_telemetry", "record_shape",
    "TuningSession", "TuneJob", "SessionReport", "backend_fingerprint",
    "MODEL_SCHEMA_VERSION", "ModelSet", "PerfModel", "clear_models",
    "collect_samples", "default_models_dir", "get_models", "harvest",
    "install_models", "train_models",
]

_SESSION_NAMES = ("TuningSession", "TuneJob", "SessionReport",
                  "backend_fingerprint")
_MODEL_NAMES = ("MODEL_SCHEMA_VERSION", "ModelSet", "PerfModel",
                "clear_models", "collect_samples", "default_models_dir",
                "get_models", "harvest", "install_models", "train_models")


def __getattr__(name):
    # lazy: keeps `import repro.tunedb` cheap on the dispatch hot path and
    # guarantees core -> tunedb imports can never loop back through session.
    if name in _SESSION_NAMES:
        from . import session

        return getattr(session, name)
    if name in _MODEL_NAMES:
        from . import model

        return getattr(model, name)
    raise AttributeError(name)
