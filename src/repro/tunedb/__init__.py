"""repro.tunedb — tuning-record database + shape-telemetry subsystem.

The persistence backbone of the input-aware runtime:

  store.py      versioned append-only JSONL record store, nearest-shape lookup
  telemetry.py  (space, input-shape) frequency counters fed by kernel dispatch
  session.py    tune the top-K hot shapes on a worker pool, commit to a store
  __main__.py   ``python -m repro.tunedb`` tune / stats / export / merge CLI

The loop: dispatch records every kernel call's shape -> a TuningSession mines
the hottest shapes and tunes them -> serving processes warm-start from the
resulting store and get config hits (exact or nearest-shape) with no tuner
in the process at all.
"""

from .store import (SCHEMA_VERSION, RecordStore, TuneRecord, clear_store,
                    get_store, input_key, install_store, normalize_config)
from .telemetry import (ShapeTelemetry, clear_telemetry, get_telemetry,
                        record_shape)

__all__ = [
    "SCHEMA_VERSION", "RecordStore", "TuneRecord", "clear_store", "get_store",
    "input_key", "install_store", "normalize_config",
    "ShapeTelemetry", "clear_telemetry", "get_telemetry", "record_shape",
    "TuningSession", "TuneJob", "SessionReport", "backend_fingerprint",
]


def __getattr__(name):
    # lazy: keeps `import repro.tunedb` cheap on the dispatch hot path and
    # guarantees core -> tunedb imports can never loop back through session.
    if name in ("TuningSession", "TuneJob", "SessionReport",
                "backend_fingerprint"):
        from . import session

        return getattr(session, name)
    raise AttributeError(name)
