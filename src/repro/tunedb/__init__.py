"""repro.tunedb — tuning-record database + shape-telemetry subsystem.

The persistence backbone of the input-aware runtime:

  store.py      versioned append-only JSONL record store (fingerprint-keyed),
                log2-bucketed nearest-shape lookup, the ATOMIC process-global
                serving state (store + ModelSet + fingerprint pin swap as one
                generation: ``install_serving`` / ``serving_state``), and the
                frozen ``DispatchPlan`` each install compiles so steady-state
                dispatch is one lock-free probe
  telemetry.py  (space, input-shape) frequency counters fed by kernel
                dispatch through per-thread lock-free rings (drained once
                per decode tick), engine tick counters for true frequencies
                under jit, and epoch snapshots (``snapshot``/``diff``)
  model.py      performance regressors trained FROM the store, served per
                (space, backend fingerprint) at dispatch (paper §5-§6)
  session.py    tune the top-K hot shapes on a worker pool, commit to a store
  controller.py RetuneController — drift-triggered sessions (inline, async
                background thread, or published to a fleet), retrain, and
                atomic store/ModelSet hot-swap: the loop closed in-process
  fleet/        distributed tuning: filesystem lease protocol, coordinator,
                sharded workers (``<store>.shards/<worker_id>.jsonl``)
  plans.py      golden plan artifacts: export/load a generation's frozen
                ``DispatchPlan`` (``<store>.plan/<generation>/``, schema +
                digest gated), ``PlanRegistry`` publish and ``PlanFollower``
                replica pull/verify/hot-swap — the fleet bus reused for
                DISTRIBUTION (see ``docs/PLANS.md``)
  obs/          serving observability: process-wide metrics registry
                (lock-free per-thread shards), the /metrics + /status +
                /plan StatusServer, the shared status_snapshot serializer,
                and the RegressionSentry gating promotions at
                install_serving / fleet merge / ``tunedb diff``
  __main__.py   ``python -m repro.tunedb`` tune / train / predict / models /
                retune / watch / fleet / stats / serve-status / diff /
                export / merge CLI

The loop, continuous since PR 3: dispatch records every kernel call's shape
(and the serving engine replays jit-compiled shapes per decode tick) -> the
RetuneController diffs telemetry epochs and, when hot-shape mass drifts or
untuned mass grows, runs a TuningSession over the novel shapes -> ``train``
distills the grown measurement log into per-(space, backend) MLP regressors
-> ``install_serving`` hot-swaps the process-global store/ModelSet in one
generation, and dispatch keeps resolving three-tier (exact hit ->
model-guided search -> nearest-shape) without a restart.
"""

from .store import (PLAN_HOT_K, SCHEMA_VERSION, DispatchPlan, RecordStore,
                    ServingState, TuneRecord, active_fingerprint,
                    clear_store, compile_plan, get_store, input_key,
                    install_generation, install_serving, install_store,
                    normalize_config, serving_state, shape_key)
from .telemetry import (FleetTelemetryView, ShapeTelemetry, SpaceDrift,
                        TelemetryExporter, TelemetrySnapshot, clear_telemetry,
                        get_telemetry, record_shape)

__all__ = [
    "PLAN_HOT_K", "SCHEMA_VERSION", "DispatchPlan", "RecordStore",
    "ServingState", "TuneRecord",
    "active_fingerprint", "clear_store", "compile_plan", "get_store",
    "input_key", "install_generation", "install_serving", "install_store",
    "normalize_config", "serving_state", "shape_key",
    "FleetTelemetryView", "ShapeTelemetry", "SpaceDrift", "TelemetryExporter",
    "TelemetrySnapshot", "clear_telemetry", "get_telemetry", "record_shape",
    "TuningSession", "TuneJob", "SessionReport", "backend_fingerprint",
    "MODEL_SCHEMA_VERSION", "ModelSet", "PerfModel", "clear_models",
    "collect_samples", "default_models_dir", "get_models", "harvest",
    "install_models", "train_models",
    "RetuneConfig", "RetuneController", "RetuneReport", "SpaceDecision",
    "Coordinator", "FleetDir", "FleetJob", "FleetReport", "Worker",
    "WorkerReport", "run_fleet_inline",
    "MetricsRegistry", "RegressionSentry", "SentryReport", "StatusServer",
    "get_registry", "reset_metrics", "status_snapshot", "plan_snapshot",
    "PLAN_SCHEMA_VERSION", "PlanArtifactError", "StalePlanError",
    "PlanManifest", "PlanRegistry", "PlanFollower", "default_plan_dir",
    "export_plan", "load_plan", "read_manifest",
]

_SESSION_NAMES = ("TuningSession", "TuneJob", "SessionReport",
                  "backend_fingerprint")
_MODEL_NAMES = ("MODEL_SCHEMA_VERSION", "ModelSet", "PerfModel",
                "clear_models", "collect_samples", "default_models_dir",
                "get_models", "harvest", "install_models", "train_models")
_CONTROLLER_NAMES = ("RetuneConfig", "RetuneController", "RetuneReport",
                     "SpaceDecision")
_FLEET_NAMES = ("Coordinator", "FleetDir", "FleetJob", "FleetReport",
                "Worker", "WorkerReport", "run_fleet_inline")
_OBS_NAMES = ("MetricsRegistry", "RegressionSentry", "SentryReport",
              "StatusServer", "get_registry", "reset_metrics",
              "status_snapshot", "plan_snapshot")
_PLANS_NAMES = ("PLAN_SCHEMA_VERSION", "PlanArtifactError", "StalePlanError",
                "PlanManifest", "PlanRegistry", "PlanFollower",
                "default_plan_dir", "export_plan", "load_plan",
                "read_manifest")


def __getattr__(name):
    # lazy: keeps `import repro.tunedb` cheap on the dispatch hot path and
    # guarantees core -> tunedb imports can never loop back through session.
    if name in _SESSION_NAMES:
        from . import session

        return getattr(session, name)
    if name in _MODEL_NAMES:
        from . import model

        return getattr(model, name)
    if name in _CONTROLLER_NAMES:
        from . import controller

        return getattr(controller, name)
    if name in _FLEET_NAMES:
        from . import fleet

        return getattr(fleet, name)
    if name in _OBS_NAMES:
        from . import obs

        return getattr(obs, name)
    if name in _PLANS_NAMES:
        from . import plans

        return getattr(plans, name)
    raise AttributeError(name)
