"""Wall-clock kernel measurement on the serving path (paper §6, closed).

The paper's loop is measure → model → *re-measure*: the model proposes a
top-k, real measurements pick the winner.  Offline tools pass a backend's
``measure`` straight into ``ModelSet(measurer=...)`` and pay the
measurements inline at resolution time.  A serving engine cannot — a
dispatch resolution sits on the decode path — so this module splits the
recipe in two:

* :class:`ServingMeasurer` — the ``(space, cfg, inputs) -> TFLOPS``
  callable wired as ``ModelSet.measurer`` behind
  ``ServeConfig(measure="wallclock")``.  On TPU it times the real kernels
  via :class:`~repro.core.backend.WallClockBackend`; off-hardware (or for
  a space wall-clock timing does not cover) it falls back to the analytic
  :class:`~repro.core.backend.SimulatedTPUBackend` with ONE RuntimeWarning
  — a dev box must run the same code path it ships.  Every measurement
  increments ``tunedb_measurements_total{backend}`` and, when tracing is
  on, records a ``measure.wallclock`` / ``measure.sim`` span — so the
  Perfetto view shows the tuner's measurements on the same clock as the
  decode ticks they stole time from.

* :class:`MeasureQueue` — the idle-decode-gap scheduler.  With a queue
  attached (``ModelSet.measure_queue``), ``ModelSet.predict`` serves the
  model argmax *immediately* and enqueues the top-k candidates here; the
  engine's controller poll drains a few items per decode tick
  (:meth:`process`), re-measures the candidates, and commits the measured
  winner back into the ModelSet memo **and** the live plan overlay — the
  next resolution of that shape serves the measured config with a plan
  probe, and no decode tick ever blocked on a measurement.
"""

from __future__ import annotations

import threading
import warnings
from collections import deque
from typing import Deque, Dict, List, Mapping, Optional, Tuple

from .store import normalize_inputs

__all__ = ["MeasureQueue", "ServingMeasurer"]

MEASURE_MODES = ("wallclock", "sim")


def _count_measurement(backend: str) -> None:
    try:
        from .obs.metrics import get_registry
        get_registry().counter(
            "tunedb_measurements_total",
            "serving-path kernel measurements by backend").inc(
                backend=backend)
    except Exception:
        pass                    # observability never blocks a measurement


class ServingMeasurer:
    """``ModelSet.measurer`` for a serving process: wall clock on
    hardware, simulator off it, spans + counters either way."""

    def __init__(self, mode: str = "wallclock", *, warmup: int = 1,
                 iters: int = 3) -> None:
        if mode not in MEASURE_MODES:
            raise ValueError(f"measure mode {mode!r}; pick one of "
                             f"{MEASURE_MODES}")
        from repro.core.backend import SimulatedTPUBackend, WallClockBackend
        self.mode = mode
        self._wall = (WallClockBackend(warmup=warmup, iters=iters)
                      if mode == "wallclock" else None)
        self._sim = SimulatedTPUBackend(noise=0.0)
        self.counts: Dict[str, int] = {"wallclock": 0, "sim": 0}
        self._warned_fallback = False

    def _on_hardware(self) -> bool:
        import jax
        return jax.default_backend() == "tpu"

    def _pick_backend(self, space: str):
        """(backend object, label) for one measurement."""
        if self._wall is None:
            return self._sim, "sim"
        if not self._on_hardware():
            if not self._warned_fallback:
                self._warned_fallback = True
                warnings.warn(
                    "measure=wallclock without TPU hardware; re-measuring "
                    "on the simulated backend instead",
                    RuntimeWarning, stacklevel=3)
            return self._sim, "sim"
        return self._wall, "wallclock"

    def __call__(self, space: str, cfg: Mapping[str, int],
                 inputs: Mapping[str, int]) -> float:
        backend, label = self._pick_backend(space)
        from .obs import trace as _trace
        tr = _trace._TRACER
        ctx = None
        if tr is not None:
            shape = ",".join(f"{k}={v}" for k, v in sorted(inputs.items()))
            name = f"measure.{label}"
            ctx = tr.span(name, space=space, shape=shape)
            if ctx is _trace._NULL_SPAN:
                # no open trace on this thread (engine-init calibration,
                # offline tools): measurements are rare and are exactly
                # what the profiling harness exists to show — always keep
                ctx = tr.root(name, trace_id=_trace.new_trace_id(),
                              space=space, shape=shape)
        if ctx is not None:
            with ctx as sp:
                tflops, label = self._measure(backend, label, space, cfg,
                                              inputs)
                if sp is not None:
                    sp.attrs["backend"] = label
                    sp.attrs["tflops"] = round(float(tflops), 3)
        else:
            tflops, _ = self._measure(backend, label, space, cfg, inputs)
        return tflops

    def _measure(self, backend, label: str, space: str,
                 cfg: Mapping[str, int],
                 inputs: Mapping[str, int]) -> Tuple[float, str]:
        try:
            tflops = float(backend.measure(space, cfg, inputs))
        except NotImplementedError:
            # wall-clock timing does not cover this space (GEMM-only
            # today): the simulator keeps the §6 loop closed for it
            label = "sim"
            tflops = float(self._sim.measure(space, cfg, inputs))
        self.counts[label] = self.counts.get(label, 0) + 1
        _count_measurement(label)
        return tflops, label

    def stats(self) -> Dict[str, object]:
        return {"mode": self.mode, "counts": dict(self.counts),
                "fallback_warned": self._warned_fallback}


class MeasureQueue:
    """Thread-safe backlog of deferred §6 top-k re-measurements.

    ``push`` comes from ``ModelSet.predict`` (dispatch path — must be
    cheap: one lock, one dedupe probe, one append).  ``process`` runs in
    idle decode gaps, driven by the engine's controller poll."""

    def __init__(self, maxlen: int = 256) -> None:
        self._lock = threading.Lock()
        self._items: Deque[tuple] = deque()
        self._queued: set = set()
        self.maxlen = maxlen
        self.pushed = 0
        self.processed = 0
        self.dropped = 0                # queue-full discards
        self.upgrades = 0               # measured winner beat the argmax

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    def push(self, space: str, backend: Optional[str],
             inputs: Mapping[str, int],
             candidates: List[Dict[str, int]]) -> bool:
        key = (space, backend, tuple(sorted(inputs.items())))
        with self._lock:
            if key in self._queued:
                return False
            if len(self._items) >= self.maxlen:
                self.dropped += 1
                return False
            self._queued.add(key)
            self._items.append((key, space, backend, dict(inputs),
                                [dict(c) for c in candidates]))
            self.pushed += 1
        return True

    def _pop(self) -> Optional[tuple]:
        with self._lock:
            if not self._items:
                return None
            item = self._items.popleft()
            self._queued.discard(item[0])
            return item

    def process(self, measurer, *, models=None, max_items: int = 2) -> int:
        """Re-measure up to ``max_items`` pending shapes; commit each
        measured winner into the ModelSet memo and the live plan overlay.
        Returns shapes processed.  A failing candidate measurement skips
        that candidate, never the decode tick driving this."""
        done = 0
        while done < max_items:
            item = self._pop()
            if item is None:
                break
            _key, space, backend, inputs, candidates = item
            measured: List[Tuple[Dict[str, int], float]] = []
            for cfg in candidates:
                try:
                    measured.append((cfg,
                                     float(measurer(space, cfg, inputs))))
                except Exception:
                    continue
            done += 1
            self.processed += 1
            if not measured:
                continue
            cfg, tflops = max(measured, key=lambda t: t[1])
            if candidates and cfg != candidates[0]:
                self.upgrades += 1
            if models is not None:
                try:
                    models.apply_measurement(space, backend, inputs, cfg,
                                             tflops)
                except Exception:
                    pass
            self._promote_plan(space, inputs, cfg)
        return done

    @staticmethod
    def _promote_plan(space: str, inputs: Mapping[str, int],
                      cfg: Mapping[str, int]) -> None:
        """Overwrite the shape's plan-overlay entry with the measured
        winner, so the frozen fast path serves it from the next call on.
        Only when the plan still belongs to the live store generation —
        a stood-aside plan will be recompiled anyway."""
        try:
            from .store import serving_state
            state = serving_state()
            plan, store = state.plan, state.store
            if plan is None:
                return
            if store is not None and store.version != plan.store_version:
                return
            key = tuple(sorted(normalize_inputs(inputs).items()))
            plan.promote(space, key, cfg, "model")
        except Exception:
            pass

    def stats(self) -> Dict[str, object]:
        with self._lock:
            backlog = len(self._items)
        return {"backlog": backlog, "pushed": self.pushed,
                "processed": self.processed, "dropped": self.dropped,
                "upgrades": self.upgrades, "maxlen": self.maxlen}
