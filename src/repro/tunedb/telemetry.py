"""Shape telemetry: which input shapes does traffic actually hit?

The paper tunes offline over a *synthetic* input distribution; what makes the
runtime pay off in production is tuning the shapes real traffic sends
(MLKAPS's observation).  :class:`ShapeTelemetry` is the counter the kernel
dispatcher feeds on every ``matmul`` / ``conv2d`` / ``flash_attention`` /
``ssd_scan`` call — a thread-safe frequency map from ``(space, inputs)`` to
hit count.  ``hot_shapes`` mines the top-K per space for the tuning session;
``save``/``load``/``merge`` move telemetry between serving processes and the
offline tuner fleet.

The record path is deliberately cheap — one lock-free append to the calling
thread's :class:`_Ring` (no lock, no hashing beyond the key tuple) — because
it also runs on the eager non-kernel dispatch path where the op itself costs
microseconds.  Pending entries fold into the counters in batches: the
serving engine drains once per decode tick, and every mining/snapshot entry
point drains on entry, so no reader ever sees a stale count and no count is
ever lost (a full ring falls back to the locked direct path rather than
dropping).  bench_tunedb.py holds the full resolution stack to <5% of
interpret-mode dispatch cost; bench_dispatch.py (E14) gates the frozen-plan
resolution path this feeds.

Counting semantics under jit — census vs ticks: dispatch runs inside traced
functions (the serving engine jits decode/prefill), where ``record`` executes
once per COMPILATION, not per device execution.  Left alone that makes
telemetry a census of distinct compiled shapes for jitted callers, while
eager callers contribute true call frequencies.  Two engine-fed hooks close
the gap without host callbacks on the device hot path:

  * ``capture()`` — a context manager that collects every (space, inputs)
    recorded inside its block.  The engine wraps the *tracing* call of a
    jitted decode/prefill in it, learning exactly which kernel shapes that
    compiled program executes.
  * ``record_ticks(shapes, n=1)`` — bump each captured shape by ``n`` per
    later execution of the compiled program.  Decode ticks therefore
    contribute true execution frequencies; the one-off trace-time census
    count is the +1 of the compiling call itself.

Epoch semantics: ``snapshot()`` freezes the current counters into an
immutable :class:`TelemetrySnapshot`; ``diff(prev)`` compares the *window*
of traffic since ``prev`` against the distribution ``prev`` had accumulated,
returning per-space :class:`SpaceDrift` — the total-variation distance
between the two hot-shape mass distributions plus the window's shape counts.
That is the drift signal the :class:`~repro.tunedb.controller.RetuneController`
thresholds to auto-launch tuning sessions when traffic shifts.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import pathlib
import threading
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

from . import chaos
from .chaos import retry_io
from .store import normalize_inputs

TELEMETRY_VERSION = 1


def _shape_key(inputs: Mapping[str, int]) -> Tuple[Tuple[str, int], ...]:
    return tuple(sorted(inputs.items()))


@dataclasses.dataclass(frozen=True)
class TelemetrySnapshot:
    """An immutable epoch snapshot of one telemetry's counters."""

    seq: int                            # monotonic per-telemetry epoch number
    # space -> shape-key -> (inputs, count); counts are cumulative at snap time
    counts: Dict[str, Dict[tuple, Tuple[Dict[str, int], int]]]

    def total(self, space: Optional[str] = None) -> int:
        spaces = [space] if space is not None else list(self.counts)
        return sum(c for s in spaces
                   for _, c in self.counts.get(s, {}).values())


@dataclasses.dataclass(frozen=True)
class SpaceDrift:
    """How one space's traffic moved between two telemetry epochs."""

    space: str
    drift: float                  # TV distance: prev mass vs window mass
    window_calls: int             # calls recorded since the prev snapshot
    prev_calls: int               # calls the prev snapshot had accumulated
    # (inputs, window count) for every shape hit in the window, hottest first
    window_shapes: List[Tuple[Dict[str, int], int]]


class _Capture:
    """Accumulates the (space, inputs) pairs recorded during a capture()."""

    def __init__(self) -> None:
        self.shapes: List[Tuple[str, Dict[str, int]]] = []


RING_SIZE = 4096        # pending shapes per writer thread before fallback


class _Ring:
    """One thread's lock-free pending-shape buffer (SPSC ring).

    The OWNING thread is the only writer of ``head`` and the slots; the
    drainer (serialized by the telemetry's drain lock) is the only writer
    of ``tail``.  CPython attribute reads/writes of ints and list slots are
    atomic under the GIL, so neither side ever sees a torn value: the
    drainer snapshots ``head`` and consumes exactly the slots published
    before the snapshot; later appends wait for the next drain.  A full
    ring (a drain-starved process) falls back to the locked direct path —
    counts are NEVER dropped, the lock-free property is what degrades.
    """

    __slots__ = ("buf", "head", "tail")

    def __init__(self, size: int = RING_SIZE) -> None:
        self.buf: List = [None] * size
        self.head = 0           # owner-thread writes only
        self.tail = 0           # drainer writes only (under drain lock)


class ShapeTelemetry:
    """Thread-safe (space, input-shape) frequency counter with epochs.

    Two recording paths feed the counters:

      * :meth:`record` — the locked direct upsert (miners, tick replay,
        capture attribution).
      * :meth:`record_buffered` — the serving hot path: one append to the
        calling thread's :class:`_Ring`, no lock, no hashing beyond the
        key tuple.  Pending entries fold into the counters at the next
        :meth:`drain_pending` — the engine drains once per decode tick,
        and every mining/snapshot entry point drains first, so readers
        never see a stale view.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        # serializes drainers (drain folds batches under self._lock, so the
        # two locks nest drain -> lock, never the reverse)
        self._drain_lock = threading.Lock()
        # space -> shape-key tuple -> (inputs, count)
        self._counts: Dict[str, Dict[tuple, Tuple[Dict[str, int], int]]] = {}
        self._ticks: Dict[str, int] = {}     # space -> engine tick bumps
        self._seq = 0                        # snapshot epoch counter
        self._captures: List[_Capture] = []
        self._tls = threading.local()
        # every writer thread's ring, tagged with a weakref to its owner so
        # drains can prune rings whose thread died (a long-lived server's
        # session worker threads must not leak 4096-slot buffers forever)
        self._rings: List[Tuple[object, _Ring]] = []

    # -- hot path -------------------------------------------------------------
    def _record_locked(self, space: str, inputs: Mapping[str, int],
                       n: int, feed_captures: bool = True) -> None:
        # raw-key fast path: numeric values hash like their int forms, so an
        # existing bucket is a plain dict hit with NO normalization copy —
        # the per-tick replay cost bench_retune gates.  Only a first-seen
        # (or string-valued) shape pays normalize_inputs.
        key = _shape_key(inputs)
        per_space = self._counts.setdefault(space, {})
        cur = per_space.get(key)
        if cur is None:                 # first sight (or string values)
            ninputs = normalize_inputs(inputs)
            key = _shape_key(ninputs)
            cur = per_space.get(key, (ninputs, 0))
        per_space[key] = (cur[0], cur[1] + n)
        if feed_captures:
            for cap in self._captures:
                cap.shapes.append((space, dict(cur[0])))

    def record(self, space: str, inputs: Mapping[str, int], n: int = 1) -> None:
        with self._lock:
            self._record_locked(space, inputs, n)

    def record_buffered(self, space: str, inputs: Mapping[str, int]) -> None:
        """Lock-free single-call record: append to this thread's ring.

        An active capture() forces the locked direct path — trace-time
        attribution must happen inside the capture block, on its thread.
        """
        if self._captures:
            self.record(space, inputs)
            return
        ring = getattr(self._tls, "ring", None)
        if ring is None:
            import weakref
            ring = self._tls.ring = _Ring()
            with self._lock:
                self._rings.append(
                    (weakref.ref(threading.current_thread()), ring))
        if ring.head - ring.tail >= len(ring.buf):
            self.record(space, inputs)      # drain-starved: locked fallback
            return
        ring.buf[ring.head % len(ring.buf)] = (space, inputs)
        ring.head += 1

    def drain_pending(self) -> int:
        """Fold every thread's pending ring entries into the counters.

        The engine calls this once per decode tick (one lock acquire for
        the whole batch instead of one per kernel call); mining and
        snapshot entry points call it on entry.  Ring entries were
        recorded outside any capture on their own thread, so the fold
        deliberately does NOT feed captures.  Returns entries folded.
        """
        drained = 0
        with self._drain_lock:
            with self._lock:
                rings = list(self._rings)
            dead = []
            for entry in rings:
                owner_ref, ring = entry
                head = ring.head            # snapshot: consume up to here
                if head != ring.tail:
                    size = len(ring.buf)
                    items = [ring.buf[i % size]
                             for i in range(ring.tail, head)]
                    ring.tail = head
                    with self._lock:
                        for space, inputs in items:
                            self._record_locked(space, inputs, 1,
                                                feed_captures=False)
                    drained += len(items)
                # a drained ring whose owner thread died contributes
                # nothing further: prune it from the registry
                if owner_ref() is None and ring.head == ring.tail:
                    dead.append(entry)
            if dead:
                with self._lock:
                    self._rings = [e for e in self._rings if e not in dead]
        return drained

    # -- jit tick hooks -------------------------------------------------------
    @contextlib.contextmanager
    def capture(self):
        """Collect every shape recorded inside the block (trace-time census).

        The engine wraps the compiling call of a jitted decode/prefill in
        this, then replays the captured shapes with :meth:`record_ticks` on
        every later execution — recovering true frequencies under jit.
        """
        cap = _Capture()
        self.drain_pending()            # pre-capture backlog is not ours
        with self._lock:
            self._captures.append(cap)
        try:
            yield cap
        finally:
            with self._lock:
                self._captures.remove(cap)

    def record_ticks(self, shapes: Iterable[Tuple[str, Mapping[str, int]]],
                     n: int = 1) -> None:
        """Bump each captured (space, inputs) by ``n`` executed ticks.

        One lock acquire for the whole replay batch — the engine calls
        this every decode tick, so the per-shape lock round-trips the
        original implementation paid were pure hot-path overhead.
        """
        per_space: Dict[str, int] = {}
        with self._lock:
            for space, inputs in shapes:
                self._record_locked(space, inputs, n)
                per_space[space] = per_space.get(space, 0) + n
            for space, k in per_space.items():
                self._ticks[space] = self._ticks.get(space, 0) + k

    # -- mining ---------------------------------------------------------------
    def count(self, space: str, inputs: Mapping[str, int]) -> int:
        self.drain_pending()
        key = _shape_key(normalize_inputs(inputs))
        with self._lock:
            cur = self._counts.get(space, {}).get(key)
            return 0 if cur is None else cur[1]

    def total(self, space: Optional[str] = None) -> int:
        self.drain_pending()
        with self._lock:
            spaces = [space] if space is not None else list(self._counts)
            return sum(c for s in spaces
                       for _, c in self._counts.get(s, {}).values())

    def hot_shapes(self, space: str, top_k: int = 8
                   ) -> List[Tuple[Dict[str, int], int]]:
        """Top-K (inputs, count) for one space, most frequent first."""
        self.drain_pending()
        with self._lock:
            items = list(self._counts.get(space, {}).values())
        items.sort(key=lambda t: (-t[1], sorted(t[0].items())))
        return [(dict(i), c) for i, c in items[:top_k]]

    def spaces(self) -> List[str]:
        self.drain_pending()
        with self._lock:
            return sorted(self._counts)

    def clear(self) -> None:
        with self._drain_lock:          # pending entries are discarded too
            with self._lock:
                rings = list(self._rings)
                self._counts.clear()
                self._ticks.clear()
                self._seq = 0
            for _owner, ring in rings:
                ring.tail = ring.head

    # -- epochs ---------------------------------------------------------------
    def snapshot(self) -> TelemetrySnapshot:
        """Freeze the current counters into an immutable epoch snapshot."""
        self.drain_pending()
        with self._lock:
            self._seq += 1
            return TelemetrySnapshot(
                seq=self._seq,
                counts={s: dict(per_space)
                        for s, per_space in self._counts.items()})

    def diff(self, prev: TelemetrySnapshot) -> Dict[str, SpaceDrift]:
        """Per-space hot-shape mass drift of the window since ``prev``.

        Drift is the total-variation distance between two distributions over
        shapes: the mass ``prev`` had accumulated vs the mass of the *window*
        (counts gained since ``prev``).  Steady traffic diffs near 0; a
        window dominated by shapes ``prev`` never saw diffs near 1.  A space
        with an empty window reports drift 0 (nothing new to act on).
        """
        cur = self.snapshot()
        out: Dict[str, SpaceDrift] = {}
        for space in sorted(set(cur.counts) | set(prev.counts)):
            now = cur.counts.get(space, {})
            old = prev.counts.get(space, {})
            window: Dict[tuple, Tuple[Dict[str, int], int]] = {}
            for key, (inputs, c) in now.items():
                gained = c - old.get(key, (None, 0))[1]
                if gained > 0:
                    window[key] = (inputs, gained)
            wtot = sum(c for _, c in window.values())
            otot = sum(c for _, c in old.values())
            if wtot == 0:
                drift = 0.0
            elif otot == 0:
                drift = 1.0                  # everything in the window is new
            else:
                keys = set(window) | set(old)
                drift = 0.5 * sum(
                    abs(window.get(k, (None, 0))[1] / wtot
                        - old.get(k, (None, 0))[1] / otot) for k in keys)
            shapes = sorted(window.values(),
                            key=lambda t: (-t[1], sorted(t[0].items())))
            out[space] = SpaceDrift(
                space=space, drift=drift, window_calls=wtot, prev_calls=otot,
                window_shapes=[(dict(i), c) for i, c in shapes])
        return out

    # -- persistence -----------------------------------------------------------
    def save(self, path: os.PathLike) -> None:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        self.drain_pending()
        with self._lock:
            payload = {
                "version": TELEMETRY_VERSION,
                "counts": {
                    s: [{"inputs": i, "count": c}
                        for i, c in per_space.values()]
                    for s, per_space in self._counts.items()},
                "ticks": dict(self._ticks),
            }
        tmp = path.with_name(path.name + ".tmp")
        io = chaos._IO
        if io is None:
            with tmp.open("w", encoding="utf-8") as fh:
                fh.write(json.dumps(payload, sort_keys=True))
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, path)
        else:
            with tmp.open("w", encoding="utf-8") as fh:
                io.file_write(fh, json.dumps(payload, sort_keys=True),
                              "telemetry.save")
                fh.flush()
                io.fsync(fh, "telemetry.save.fsync")
            io.replace(tmp, path, "telemetry.save.replace")

    @classmethod
    def load(cls, path: os.PathLike) -> "ShapeTelemetry":
        t = cls()
        path = pathlib.Path(path)
        io = chaos._IO
        # stale_read / truncated_read faults here exercise the view's
        # "torn mid-read: try an older epoch" fallback in _merge_worker
        text = (path.read_text() if io is None
                else io.read_text(path, "telemetry.load"))
        payload = json.loads(text)
        for space, entries in payload.get("counts", {}).items():
            for e in entries:
                t.record(space, e["inputs"], n=int(e["count"]))
        with t._lock:
            t._ticks.update({s: int(n) for s, n
                             in payload.get("ticks", {}).items()})
        return t

    def merge(self, other: "ShapeTelemetry") -> None:
        other.drain_pending()
        # snapshot under OTHER's lock: a concurrent record()/clear() on it
        # must not mutate the dicts mid-iteration
        with other._lock:
            items = [(space, [v for v in per_space.values()])
                     for space, per_space in other._counts.items()]
            ticks = dict(other._ticks)
        for space, values in items:
            for inputs, count in values:
                self.record(space, inputs, n=count)
        with self._lock:
            for space, n in ticks.items():
                self._ticks[space] = self._ticks.get(space, 0) + n

    def stats(self) -> Dict[str, object]:
        self.drain_pending()
        with self._lock:
            return {
                "spaces": {s: {"shapes": len(m),
                               "calls": sum(c for _, c in m.values())}
                           for s, m in self._counts.items()},
                "ticks": dict(self._ticks),
                "epoch": self._seq,
            }


# ---------------------------------------------------------------------------
# Fleet scope: periodic dump export + the aggregated global read view.
# ---------------------------------------------------------------------------

def _count_dump(worker_id: str) -> None:
    try:                                    # obs imports telemetry: lazy
        from .obs.metrics import get_registry
        get_registry().counter(
            "tunedb_telemetry_dumps_total",
            "cumulative telemetry dumps exported to the fleet bus",
        ).inc(worker=worker_id)
    except Exception:                       # metrics must never break export
        pass


class TelemetryExporter:
    """Periodic export of one process's telemetry to the fleet bus.

    Every ``interval_s`` the exporter writes a CUMULATIVE dump of
    ``telemetry`` to ``<out_dir>/<worker_id>/<epoch>.json`` via
    :meth:`ShapeTelemetry.save` (atomic tmp+rename), bumping the epoch in
    the filename each time.  Cumulative dumps make aggregation idempotent:
    a reader folds only the LATEST epoch per worker, so a torn read, a
    missed interval, or a reader racing the pruner can never double-count
    a call.  Old epochs are pruned (last ``keep`` retained) so the bus
    directory stays O(workers), not O(uptime).
    """

    def __init__(self, telemetry: ShapeTelemetry, out_dir: os.PathLike, *,
                 worker_id: Optional[str] = None, interval_s: float = 5.0,
                 keep: int = 2) -> None:
        import socket
        self.telemetry = telemetry
        self.out_dir = pathlib.Path(out_dir)
        self.worker_id = worker_id or (
            f"{socket.gethostname()}-{os.getpid()}")
        self.interval_s = float(interval_s)
        self.keep = max(1, int(keep))
        self.exports = 0
        self._epoch = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def export_once(self) -> pathlib.Path:
        """Write one cumulative dump; returns the dump path."""
        self._epoch += 1
        dest = self.out_dir / self.worker_id / f"{self._epoch:08d}.json"
        self.telemetry.save(dest)
        self.exports += 1
        _count_dump(self.worker_id)
        stale = sorted(dest.parent.glob("*.json"))[:-self.keep]
        for p in stale:
            try:
                p.unlink()
            except OSError:                  # a concurrent reader won the race
                pass
        return dest

    def start(self) -> "TelemetryExporter":
        if self._thread is not None:
            return self
        self._stop.clear()

        def loop() -> None:
            while not self._stop.wait(self.interval_s):
                try:
                    # transient EIO/EAGAIN retried in-tick (counted in
                    # tunedb_io_retries_total); a persistent outage waits
                    # for the next interval instead of killing the thread
                    retry_io(self.export_once, site="telemetry.export")
                except OSError:
                    pass

        self._thread = threading.Thread(
            target=loop, name=f"telemetry-export-{self.worker_id}",
            daemon=True)
        self._thread.start()
        return self

    def stop(self, *, final_export: bool = True) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        if final_export:
            try:                             # flush the tail of the window
                retry_io(self.export_once, site="telemetry.export")
            except OSError:
                pass

    def stats(self) -> Dict[str, object]:
        return {"worker_id": self.worker_id, "epoch": self._epoch,
                "exports": self.exports, "interval_s": self.interval_s,
                "out_dir": str(self.out_dir)}


class FleetTelemetryView:
    """Fleet-global telemetry: local counters merged with every worker's dump.

    Duck-types the :class:`ShapeTelemetry` read surface (``snapshot`` /
    ``diff`` / ``count`` / ``hot_shapes`` / ``spaces`` / ``total`` /
    ``stats`` / ``drain_pending``) so the :class:`RetuneController` and
    ``plan_from_telemetry`` consume the GLOBAL view unchanged.  Each
    :meth:`refresh` rebuilds a merged :class:`ShapeTelemetry` from the
    local instance plus the latest cumulative dump of every worker under
    ``dump_root`` — counts are monotone (dumps are cumulative), so epoch
    diffs over rebuilt views behave exactly like diffs over one process's
    counters.  Reads are throttled to ``refresh_s``; epoch entry points
    (``snapshot``/``diff``/``stats``) always force a rebuild.
    """

    scope = "fleet"

    def __init__(self, dump_root: os.PathLike, *,
                 local: Optional[ShapeTelemetry] = None,
                 refresh_s: float = 2.0,
                 exclude: Iterable[str] = ()) -> None:
        self.dump_root = pathlib.Path(dump_root)
        self.local = local if local is not None else get_telemetry()
        self.refresh_s = float(refresh_s)
        # worker dirs to skip — a process that both exports AND aggregates
        # passes its own worker_id so its live local counts never fold in
        # twice (once live, once via its own stale dump)
        self.exclude = frozenset(exclude)
        self.refreshes = 0
        self._lock = threading.Lock()
        self._merged = ShapeTelemetry()
        self._replicas: Dict[str, Dict[str, object]] = {}
        self._last_refresh: Optional[float] = None

    def refresh(self, force: bool = False) -> ShapeTelemetry:
        """Rebuild (or reuse, inside the throttle window) the merged view."""
        import time
        now = time.monotonic()
        with self._lock:
            if (not force and self._last_refresh is not None
                    and now - self._last_refresh < self.refresh_s):
                return self._merged
            merged = ShapeTelemetry()
            merged.merge(self.local)
            replicas: Dict[str, Dict[str, object]] = {}
            if self.dump_root.is_dir():
                for wdir in sorted(self.dump_root.iterdir()):
                    if not wdir.is_dir() or wdir.name in self.exclude:
                        continue
                    prov = self._merge_worker(merged, wdir)
                    if prov is not None:
                        replicas[wdir.name] = prov
            self._merged = merged
            self._replicas = replicas
            self._last_refresh = now
            self.refreshes += 1
            return merged

    @staticmethod
    def _merge_worker(merged: ShapeTelemetry,
                      wdir: pathlib.Path) -> Optional[Dict[str, object]]:
        """Fold one worker's latest dump; provenance dict or None."""
        import time
        for latest in sorted(wdir.glob("*.json"), reverse=True):
            try:
                dump = ShapeTelemetry.load(latest)
                age_s = max(0.0, time.time() - latest.stat().st_mtime)
            except (OSError, ValueError):    # pruned/torn mid-read: try older
                continue
            merged.merge(dump)
            try:
                epoch = int(latest.stem)
            except ValueError:
                epoch = -1
            try:
                from .obs.metrics import get_registry
                get_registry().gauge(
                    "tunedb_fleet_telemetry_lag_seconds",
                    "age of the newest readable telemetry dump per worker",
                ).set(age_s, worker=wdir.name)
            except Exception:
                pass
            return {"epoch": epoch, "calls": dump.total(), "age_s": age_s}
        return None

    def replicas(self) -> Dict[str, Dict[str, object]]:
        """Per-replica provenance: worker -> {epoch, calls, age_s}."""
        self.refresh()
        with self._lock:
            return {w: dict(p) for w, p in self._replicas.items()}

    # -- ShapeTelemetry read surface ------------------------------------------
    def snapshot(self) -> TelemetrySnapshot:
        return self.refresh(force=True).snapshot()

    def diff(self, prev: TelemetrySnapshot) -> Dict[str, SpaceDrift]:
        return self.refresh(force=True).diff(prev)

    def count(self, space: str, inputs: Mapping[str, int]) -> int:
        return self.refresh().count(space, inputs)

    def hot_shapes(self, space: str, top_k: int = 8
                   ) -> List[Tuple[Dict[str, int], int]]:
        return self.refresh().hot_shapes(space, top_k)

    def spaces(self) -> List[str]:
        return self.refresh().spaces()

    def total(self, space: Optional[str] = None) -> int:
        return self.refresh().total(space)

    def drain_pending(self) -> int:
        return self.local.drain_pending()

    def stats(self) -> Dict[str, object]:
        out = self.refresh(force=True).stats()
        with self._lock:
            out["scope"] = self.scope
            out["replicas"] = {w: dict(p) for w, p in self._replicas.items()}
        return out


# ---------------------------------------------------------------------------
# Process-global collector: dispatch feeds this unconditionally; it is always
# present (a counter, not a policy), unlike the optional global store/tuner.
# ---------------------------------------------------------------------------

_TELEMETRY = ShapeTelemetry()


def get_telemetry() -> ShapeTelemetry:
    return _TELEMETRY


def record_shape(space: str, inputs: Mapping[str, int]) -> None:
    """Dispatcher entry point — one lock-free ring append per kernel call.

    The entry folds into the counters at the next ``drain_pending`` (the
    engine drains every decode tick; mining/snapshot calls drain first),
    so readers still see every call — only the per-call lock is gone.
    """
    _TELEMETRY.record_buffered(space, inputs)


def clear_telemetry() -> None:
    _TELEMETRY.clear()
