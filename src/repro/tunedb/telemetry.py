"""Shape telemetry: which input shapes does traffic actually hit?

The paper tunes offline over a *synthetic* input distribution; what makes the
runtime pay off in production is tuning the shapes real traffic sends
(MLKAPS's observation).  :class:`ShapeTelemetry` is the counter the kernel
dispatcher feeds on every ``matmul`` / ``conv2d`` / ``flash_attention`` /
``ssd_scan`` call — a thread-safe frequency map from ``(space, inputs)`` to
hit count.  ``hot_shapes`` mines the top-K per space for the tuning session;
``save``/``load``/``merge`` move telemetry between serving processes and the
offline tuner fleet.

The record path is deliberately cheap — a tuple-key dict upsert under a lock
(no hashing or serialization) — because it also runs on the eager non-kernel
dispatch path where the op itself costs microseconds.  bench_tunedb.py holds
the full resolution stack to <5% of interpret-mode dispatch cost.

Counting semantics under jit: dispatch runs inside traced functions (the
serving engine jits decode/prefill), where ``record`` executes once per
COMPILATION, not per device execution — so for jitted callers telemetry is a
census of distinct compiled shapes, while eager callers contribute true call
frequencies.  Per-execution counts under jit would need host callbacks on
the hot path (see ROADMAP tunedb next-steps).
"""

from __future__ import annotations

import json
import os
import pathlib
import threading
from typing import Dict, List, Mapping, Optional, Tuple

from .store import normalize_inputs

TELEMETRY_VERSION = 1


def _shape_key(inputs: Mapping[str, int]) -> Tuple[Tuple[str, int], ...]:
    return tuple(sorted(inputs.items()))


class ShapeTelemetry:
    """Thread-safe (space, input-shape) frequency counter."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        # space -> shape-key tuple -> (inputs, count)
        self._counts: Dict[str, Dict[tuple, Tuple[Dict[str, int], int]]] = {}

    # -- hot path -------------------------------------------------------------
    def record(self, space: str, inputs: Mapping[str, int], n: int = 1) -> None:
        key = _shape_key(inputs)
        with self._lock:
            per_space = self._counts.setdefault(space, {})
            cur = per_space.get(key)
            if cur is None:
                per_space[key] = (normalize_inputs(inputs), n)
            else:
                per_space[key] = (cur[0], cur[1] + n)

    # -- mining ---------------------------------------------------------------
    def count(self, space: str, inputs: Mapping[str, int]) -> int:
        cur = self._counts.get(space, {}).get(_shape_key(inputs))
        return 0 if cur is None else cur[1]

    def total(self, space: Optional[str] = None) -> int:
        with self._lock:
            spaces = [space] if space is not None else list(self._counts)
            return sum(c for s in spaces
                       for _, c in self._counts.get(s, {}).values())

    def hot_shapes(self, space: str, top_k: int = 8
                   ) -> List[Tuple[Dict[str, int], int]]:
        """Top-K (inputs, count) for one space, most frequent first."""
        with self._lock:
            items = list(self._counts.get(space, {}).values())
        items.sort(key=lambda t: (-t[1], sorted(t[0].items())))
        return [(dict(i), c) for i, c in items[:top_k]]

    def spaces(self) -> List[str]:
        with self._lock:
            return sorted(self._counts)

    def clear(self) -> None:
        with self._lock:
            self._counts.clear()

    # -- persistence -----------------------------------------------------------
    def save(self, path: os.PathLike) -> None:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with self._lock:
            payload = {
                "version": TELEMETRY_VERSION,
                "counts": {
                    s: [{"inputs": i, "count": c}
                        for i, c in per_space.values()]
                    for s, per_space in self._counts.items()},
            }
        tmp = path.with_name(path.name + ".tmp")
        with tmp.open("w", encoding="utf-8") as fh:
            fh.write(json.dumps(payload, sort_keys=True))
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: os.PathLike) -> "ShapeTelemetry":
        t = cls()
        payload = json.loads(pathlib.Path(path).read_text())
        for space, entries in payload.get("counts", {}).items():
            for e in entries:
                t.record(space, e["inputs"], n=int(e["count"]))
        return t

    def merge(self, other: "ShapeTelemetry") -> None:
        for space, per_space in other._counts.items():
            for inputs, count in list(per_space.values()):
                self.record(space, inputs, n=count)

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {
                "spaces": {s: {"shapes": len(m),
                               "calls": sum(c for _, c in m.values())}
                           for s, m in self._counts.items()},
            }


# ---------------------------------------------------------------------------
# Process-global collector: dispatch feeds this unconditionally; it is always
# present (a counter, not a policy), unlike the optional global store/tuner.
# ---------------------------------------------------------------------------

_TELEMETRY = ShapeTelemetry()


def get_telemetry() -> ShapeTelemetry:
    return _TELEMETRY


def record_shape(space: str, inputs: Mapping[str, int]) -> None:
    """Dispatcher entry point — one counter bump per kernel call."""
    _TELEMETRY.record(space, inputs)


def clear_telemetry() -> None:
    _TELEMETRY.clear()
