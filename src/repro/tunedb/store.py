"""Versioned, append-only tuning-record store.

A :class:`RecordStore` is the persistence backbone of the runtime (§6): every
tuned configuration the system ever measures becomes a :class:`TuneRecord`
line in a JSON-lines file.  The file is strictly append-only — re-tuning a
shape appends a new record rather than rewriting history, so a store doubles
as a tuning log; the in-memory index resolves each ``(space, inputs)`` key to
its most recent record.  Lines are written with flush+fsync so a crashed
writer loses at most its final, torn line, and the loader skips any line that
fails to parse — the atomicity contract the tests pin down.

Beyond exact lookup the store answers *nearest-shape* queries: when serving
traffic hits a shape nobody tuned, the closest tuned shape (log2 distance
over the numeric input dims, exact match on dtype/layout flags) supplies a
config that the ops-layer clamping then makes runnable.  ``merge`` /
``export`` combine stores from parallel tuning fleets into one artifact.

The backend fingerprint is a first-class lookup dimension: the serving index
is keyed by ``(backend, space, inputs)``, so one store holds records for
several backends (v5e sim, wall-clock CPU, a future v6e, ...) side by side.
``get``/``nearest`` take an optional ``backend=``; ``None`` means "newest
record regardless of backend" — the single-backend behavior.  Records with
``source="sample"`` (exploration measurements for model training, see
model.py) are kept in the training log but never enter the serving index.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
import math
import os
import pathlib
import threading
import time
import warnings
import zlib
from typing import Deque, Dict, List, Mapping, Optional, Tuple

import numpy as np

from . import chaos

SCHEMA_VERSION = 1

# store paths already warned about quarantined lines (warn once per path
# per process; the metric keeps the full count)
_QUARANTINE_WARNED: set = set()

# records whose source is this string are training data for the performance
# model (model.py), not serving candidates — they stay out of the index.
SAMPLE_SOURCE = "sample"

# input parameters that must match EXACTLY for a nearest-shape fallback —
# a config tuned for bf16 or a transposed layout is not a neighbor of fp32.
EXACT_MATCH_PARAMS = frozenset(
    {"dtype_bits", "trans_a", "trans_b", "causal", "R", "S"})


def normalize_config(cfg: Mapping[str, object]) -> Dict[str, int]:
    """Coerce a config mapping to the canonical ``Dict[str, int]`` form.

    JSON round-trips and hand-written caches can surface floats or string
    keys; every config leaving the store passes through here so callers
    always see one type (the `best_config` normalization contract).
    """
    return {str(k): int(v) for k, v in cfg.items()}


def normalize_inputs(inputs: Mapping[str, object]) -> Dict[str, int]:
    return {str(k): int(v) for k, v in inputs.items()}


def input_key(space: str, inputs: Mapping[str, object]) -> str:
    """Stable 16-hex key for a (space, inputs) pair."""
    blob = json.dumps(
        {"s": space, "i": dict(sorted(normalize_inputs(inputs).items()))},
        sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def shape_key(inputs: Mapping[str, int]) -> Tuple[Tuple[str, int], ...]:
    """Cheap hashable key for an input dict: no JSON, no digest.

    This is the key the serving hot path uses (DispatchPlan lookups and
    telemetry buckets): a sorted item tuple costs ~10x less than the
    ``input_key`` sha1 digest, which stays the *persistent* key format
    (progress files, job ids)."""
    return tuple(sorted(inputs.items()))


@dataclasses.dataclass(frozen=True)
class TuneRecord:
    """One measured tuning outcome for one input shape."""

    space: str
    inputs: Dict[str, int]
    config: Dict[str, int]
    tflops: float                       # measured (or model-predicted) perf
    latency_us: Optional[float] = None
    backend: str = "unknown"            # backend fingerprint, e.g. sim-tpu-v5e
    source: str = "tuner"               # tuner | session | retune | fleet | import
    created_at: float = 0.0             # unix seconds; 0 -> stamped on add
    # merge lineage: where a merged-in record came from (the source store's
    # path, or a fleet worker's shard id).  Orthogonal to ``source``, which
    # keeps saying WHY the record was measured — harvest/audits key on it.
    merged_from: Optional[str] = None
    schema_version: int = SCHEMA_VERSION

    @property
    def key(self) -> str:
        return input_key(self.space, self.inputs)

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        if d["merged_from"] is None:        # keep un-merged lines lean
            del d["merged_from"]
        # per-line integrity: crc32 over the canonical record JSON.  The
        # field is ADDITIVE — older readers drop unknown fields, so v1
        # stores without it (and v1 readers seeing it) both keep working;
        # readers that know the field verify it (see from_json / fsck).
        d["crc"] = zlib.crc32(
            json.dumps(d, sort_keys=True).encode("utf-8"))
        return json.dumps(d, sort_keys=True)

    @classmethod
    def from_json(cls, line: str) -> "TuneRecord":
        d = json.loads(line)
        if not isinstance(d, dict) or "space" not in d or "config" not in d:
            raise ValueError(f"not a TuneRecord: {line[:80]!r}")
        if int(d.get("schema_version", 1)) > SCHEMA_VERSION:
            # a newer writer's semantics are unknown; skip, don't misread
            raise ValueError(
                f"record schema v{d['schema_version']} > v{SCHEMA_VERSION}")
        crc = d.pop("crc", None)
        if crc is not None:
            want = zlib.crc32(json.dumps(d, sort_keys=True).encode("utf-8"))
            if int(crc) != want:
                raise ValueError(
                    f"record CRC mismatch (line says {crc}, content "
                    f"recomputes {want}) — corrupt in place, not torn")
        known = {f.name for f in dataclasses.fields(cls)}
        d = {k: v for k, v in d.items() if k in known}
        d["inputs"] = normalize_inputs(d.get("inputs", {}))
        d["config"] = normalize_config(d["config"])
        return cls(**d)


SUPERSESSION_CAP = 4096     # bounded like the plan overlay / nearest memos


@dataclasses.dataclass(frozen=True)
class Supersession:
    """One serving-index replacement: at store ``version``, record ``new``
    took over the ``(backend, key)`` slot from ``old``.  The regression
    sentry replays these to audit an in-place generation before it is
    frozen into a plan."""

    version: int
    old: TuneRecord
    new: TuneRecord


_MEMO_MISS = object()       # sentinel: None is a valid memoized outcome


def _shape_distance(a: Mapping[str, int], b: Mapping[str, int]
                    ) -> Optional[float]:
    """log2 distance between two input dicts; None if incomparable."""
    if set(a) != set(b):
        return None
    d = 0.0
    for k, va in a.items():
        vb = b[k]
        if k in EXACT_MATCH_PARAMS:
            if va != vb:
                return None
            continue
        d += (math.log2(1 + abs(va)) - math.log2(1 + abs(vb))) ** 2
    return math.sqrt(d)


class RecordStore:
    """Append-only JSONL store of :class:`TuneRecord`, indexed in memory.

    ``path=None`` gives a purely in-memory store (tests, ephemeral tuning).
    ``fsync=False`` trades the per-append durability barrier for throughput;
    callers owning a recovery story (fleet shards, whose jobs are re-queued
    on lease expiry) batch with an explicit :meth:`sync` at their commit
    point instead.
    """

    def __init__(self, path: Optional[os.PathLike] = None, *,
                 fsync: bool = True):
        self.path = pathlib.Path(path) if path is not None else None
        self.fsync = fsync
        self._lock = threading.Lock()
        # bumped on every append: an installed DispatchPlan compares this
        # against the version it was compiled from and stands aside (full
        # slow-path resolution) the moment the store has newer records —
        # a frozen plan must never shadow a fresher tuning outcome.
        self.version = 0
        # (backend, key) -> latest record: the fingerprint-keyed serving index
        self._index: Dict[Tuple[str, str], TuneRecord] = {}
        self._latest: Dict[str, TuneRecord] = {}     # key -> latest, any backend
        self._all: List[TuneRecord] = []             # full log incl. samples
        self._history: Dict[str, int] = {}           # key -> n records seen
        self.n_lines = 0                             # parsed lines on disk
        self.n_skipped = 0                           # torn/garbage lines
        self.n_samples = 0                           # training-only records
        self.hits = 0
        self.nearest_hits = 0
        self.misses = 0
        self._needs_newline = False     # true when the file ends in a torn line
        # (space, backend, shape)->(record|None) memo for nearest(): the
        # O(index) scan sits on the dispatch hot path for untuned shapes.
        # Invalidated on every add so new session results become visible
        # immediately.
        self._nearest_memo: Dict[tuple, Optional[TuneRecord]] = {}
        # lazily-built log2-bucketed neighbor index (see _nearest_index_for);
        # dropped on every add, rebuilt on the next un-memoized nearest()
        self._nearest_index: Optional[Dict[tuple, dict]] = None
        # bounded log of serving-index replacements: each time add() swaps
        # the record behind a (backend, key), the (version, old, new) pair
        # lands here so the regression sentry can audit everything a future
        # install_serving would freeze in (load-time replays are history,
        # not promotions, and are not logged).
        self.supersessions: Deque[Supersession] = collections.deque(
            maxlen=SUPERSESSION_CAP)
        if self.path is not None and self.path.exists():
            self._load()

    # -- persistence ---------------------------------------------------------
    @classmethod
    def open(cls, path: os.PathLike) -> "RecordStore":
        return cls(path)

    def _load(self) -> None:
        io = chaos._IO
        if io is not None:
            io.probe("store.load")
        bad: List[str] = []
        with self.path.open("r", encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = TuneRecord.from_json(line)
                except (ValueError, TypeError, KeyError):
                    self.n_skipped += 1        # torn tail / foreign garbage
                    bad.append(line)
                    continue
                self.n_lines += 1
                self._admit(rec)
        with self.path.open("rb") as fh:
            fh.seek(0, os.SEEK_END)
            if fh.tell():
                fh.seek(-1, os.SEEK_END)
                self._needs_newline = fh.read(1) != b"\n"
        if bad:
            self._quarantine(bad, reason="load")

    def quarantine_dir(self) -> Optional[pathlib.Path]:
        if self.path is None:
            return None
        return self.path.with_name(self.path.name + ".quarantine")

    def _quarantine(self, lines: List[str], *, reason: str
                    ) -> Optional[pathlib.Path]:
        """Preserve unparseable lines in ``<store>.quarantine/`` so a torn
        tail or corrupt record is never silently discarded — an operator
        (or ``tunedb fsck``) can inspect and recover them later.  Best
        effort by design: a quarantine failure must never block a load."""
        if self.path is None or not lines:
            return None
        try:
            qdir = self.quarantine_dir()
            qdir.mkdir(parents=True, exist_ok=True)
            dest = qdir / f"{int(time.time() * 1000):013d}-{reason}.jsonl"
            with dest.open("a", encoding="utf-8") as fh:
                fh.write("".join(line + "\n" for line in lines))
        except OSError:
            return None
        try:
            from .obs.metrics import get_registry
            get_registry().counter(
                "tunedb_store_quarantined_lines_total",
                "torn/corrupt store lines moved to quarantine").inc(
                    len(lines))
        except Exception:
            pass        # observability never blocks recovery
        key = str(self.path)
        if key not in _QUARANTINE_WARNED:
            _QUARANTINE_WARNED.add(key)
            warnings.warn(
                f"tunedb store {self.path}: quarantined {len(lines)} "
                f"unparseable line(s) to {dest}; parsed records keep "
                "serving (run `tunedb fsck --repair` to rewrite the file)",
                RuntimeWarning, stacklevel=2)
        return dest

    def repair(self) -> Dict[str, int]:
        """Rewrite the store file keeping only verifiably-parseable lines;
        everything else moves to the quarantine dir.  The fsck ``--repair``
        path.  Returns ``{"kept": n, "quarantined": m}``."""
        if self.path is None or not self.path.exists():
            return {"kept": 0, "quarantined": 0}
        good: List[str] = []
        bad: List[str] = []
        with self._lock:
            with self.path.open("r", encoding="utf-8") as fh:
                for line in fh:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        TuneRecord.from_json(line)
                    except (ValueError, TypeError, KeyError):
                        bad.append(line)
                    else:
                        good.append(line)
            if bad:
                self._quarantine(bad, reason="repair")
                tmp = self.path.with_name(self.path.name + ".repair-tmp")
                with tmp.open("w", encoding="utf-8") as fh:
                    fh.write("".join(line + "\n" for line in good))
                    fh.flush()
                    os.fsync(fh.fileno())
                os.replace(tmp, self.path)
                self._needs_newline = False
        return {"kept": len(good), "quarantined": len(bad)}

    def _admit(self, rec: TuneRecord) -> Optional[TuneRecord]:
        """Index one record; returns the serving record it replaced, if any."""
        if self.path is None:
            # in-memory store: the JSONL *is* this list.  Disk-backed stores
            # re-read the file in training_records() instead of pinning the
            # (samples-dominated) measurement log in every serving process.
            self._all.append(rec)
        if rec.source == SAMPLE_SOURCE:      # training data, never served
            self.n_samples += 1
            return None
        k = rec.key
        self._history[k] = self._history.get(k, 0) + 1
        bk = (rec.backend, k)
        replaced: Optional[TuneRecord] = None
        cur = self._index.get(bk)
        if cur is None or rec.created_at >= cur.created_at:
            self._index[bk] = rec
            replaced = cur
        any_cur = self._latest.get(k)
        if any_cur is None or rec.created_at >= any_cur.created_at:
            self._latest[k] = rec
        return replaced

    def add(self, rec: TuneRecord) -> TuneRecord:
        """Append one record (stamping created_at if unset) atomically."""
        if rec.created_at <= 0:
            rec = dataclasses.replace(rec, created_at=time.time())
        rec = dataclasses.replace(
            rec, inputs=normalize_inputs(rec.inputs),
            config=normalize_config(rec.config))
        with self._lock:
            self._nearest_memo.clear()
            self._nearest_index = None
            self.version += 1
            if self.path is not None:
                self.path.parent.mkdir(parents=True, exist_ok=True)
                io = chaos._IO
                with self.path.open("a", encoding="utf-8") as fh:
                    if self._needs_newline:     # seal a torn tail line first
                        fh.write("\n")
                        self._needs_newline = False
                    line = rec.to_json() + "\n"
                    if io is None:
                        fh.write(line)
                    else:
                        io.file_write(fh, line, "store.append")
                    fh.flush()
                    if self.fsync:
                        if io is None:
                            os.fsync(fh.fileno())
                        else:
                            io.fsync(fh, "store.append.fsync")
                self.n_lines += 1
            replaced = self._admit(rec)
            if replaced is not None:
                self.supersessions.append(
                    Supersession(version=self.version, old=replaced, new=rec))
        return rec

    def sync(self) -> None:
        """Durability barrier for ``fsync=False`` stores: flush appended
        records to disk now.  The fleet coordinator calls this once per
        merge pass (one barrier per batch of merged records, not one fsync
        per record); fleet worker shards skip it entirely — their recovery
        story is lease expiry + requeue, not power-loss durability."""
        if self.path is None or not self.path.exists():
            return
        io = chaos._IO
        with self.path.open("rb") as fh:
            if io is None:
                os.fsync(fh.fileno())
            else:
                io.fsync(fh, "store.sync")

    # -- lookup --------------------------------------------------------------
    def _exact(self, space: str, inputs: Mapping[str, int],
               backend: Optional[str]) -> Optional[TuneRecord]:
        key = input_key(space, inputs)
        if backend is not None:
            return self._index.get((backend, key))
        return self._latest.get(key)

    def get(self, space: str, inputs: Mapping[str, int], *,
            backend: Optional[str] = None) -> Optional[TuneRecord]:
        """Exact lookup of the latest record for (space, inputs[, backend]).

        Counts BOTH outcomes: a miss here is a real serving event even when
        a higher tier (model, heuristics) picks up the shape afterwards —
        `stats()["lookups"]` must not flatter the store's coverage.
        """
        rec = self._exact(space, inputs, backend)
        if rec is not None:
            self.hits += 1
        else:
            self.misses += 1
        return rec

    def contains(self, space: str, inputs: Mapping[str, int], *,
                 backend: Optional[str] = None) -> bool:
        """Exact membership without touching the hit/miss statistics —
        planning-time checks (session skip_existing) use this."""
        return self._exact(space, inputs, backend) is not None

    def nearest(self, space: str, inputs: Mapping[str, int], *,
                backend: Optional[str] = None,
                max_distance: float = 2.0,
                count: bool = True
                ) -> Optional[TuneRecord]:
        """Exact record if present, else the closest tuned shape.

        Distance is L2 over log2-transformed numeric input dims; dtype and
        layout flags must match exactly.  ``max_distance=2.0`` admits
        neighbors within a combined ~4x dimension drift — past that a
        config says more about the other shape than about this one.
        ``backend`` restricts both tiers to records of one fingerprint.

        Accounting: an exact hit counts as ``hits``, a served neighbor as
        ``nearest_hits``; a full miss is NOT counted here — the exact-tier
        ``get()`` that precedes this call in dispatch already attributed it,
        and double-counting made the miss column overstate store gaps.
        ``count=False`` skips the statistics entirely: planning-time probes
        (the controller's projected-gain gate) are not serving events.
        """
        inputs = normalize_inputs(inputs)
        exact = self._exact(space, inputs, backend)
        if exact is not None:
            if count:
                self.hits += 1
            return exact
        memo_key = (space, backend, shape_key(inputs), max_distance)
        # single atomic read: add() clears the memo concurrently, so a
        # check-then-index pair could KeyError between the two operations
        best = self._nearest_memo.get(memo_key, _MEMO_MISS)
        if best is _MEMO_MISS:
            best = self._nearest_indexed(space, inputs, backend, max_distance)
            if len(self._nearest_memo) > 4096:
                self._nearest_memo.clear()
            self._nearest_memo[memo_key] = best
        if best is not None and count:
            self.nearest_hits += 1
        return best

    # -- the log2-bucketed neighbor index ------------------------------------
    #
    # The pre-PR-5 nearest() walked EVERY serving record per un-memoized
    # query — O(index) python-loop work on the dispatch hot path, painful at
    # the fleet-scale stores PR 4 produces.  The index groups records by
    # (space, input-dim names, exact-match values) — the only records
    # _shape_distance can even compare — precomputes each record's log2
    # feature vector, and buckets rows by round(sum(log2 dims)).  Because
    # |sum(a) - sum(b)| <= sqrt(d) * ||a - b||_2  (Cauchy-Schwarz), every
    # neighbor within ``max_distance`` lives in a bucket within
    # ceil(max_distance * sqrt(d)) + 1 of the query's, so a lookup scans a
    # handful of buckets and resolves them with one vectorized distance
    # computation instead of a per-record python loop.

    def _build_nearest_index(self) -> Dict[tuple, dict]:
        """Group the serving index for neighbor queries (caller holds lock)."""
        groups: Dict[tuple, dict] = {}
        for rec in self._index.values():
            keys = tuple(sorted(rec.inputs))
            exact = tuple((k, rec.inputs[k]) for k in keys
                          if k in EXACT_MATCH_PARAMS)
            g = groups.setdefault((rec.space, keys, exact),
                                  {"vecs": [], "recs": [], "buckets": {}})
            vec = [math.log2(1 + abs(rec.inputs[k])) for k in keys
                   if k not in EXACT_MATCH_PARAMS]
            g["buckets"].setdefault(int(round(sum(vec))),
                                    []).append(len(g["recs"]))
            g["vecs"].append(vec)
            g["recs"].append(rec)
        for g in groups.values():
            g["vecs"] = np.asarray(g["vecs"], np.float64).reshape(
                len(g["recs"]), -1)
        return groups

    def _nearest_indexed(self, space: str, inputs: Mapping[str, int],
                         backend: Optional[str], max_distance: float
                         ) -> Optional[TuneRecord]:
        keys = tuple(sorted(inputs))
        exact = tuple((k, inputs[k]) for k in keys if k in EXACT_MATCH_PARAMS)
        with self._lock:
            index = self._nearest_index
            if index is None:
                index = self._nearest_index = self._build_nearest_index()
        group = index.get((space, keys, exact))
        if group is None:
            return None
        qvec = np.asarray([math.log2(1 + abs(inputs[k])) for k in keys
                           if k not in EXACT_MATCH_PARAMS], np.float64)
        d = qvec.shape[0]
        radius = int(math.ceil(max_distance * math.sqrt(d))) + 1 if d else 0
        qb = int(round(float(qvec.sum())))
        rows: List[int] = []
        for b in range(qb - radius, qb + radius + 1):
            rows.extend(group["buckets"].get(b, ()))
        if not rows:
            return None
        dist = np.sqrt(((group["vecs"][rows] - qvec) ** 2).sum(axis=1))
        recs = group["recs"]
        for j in np.argsort(dist, kind="stable"):
            if dist[j] > max_distance:
                break                   # sorted: nothing closer remains
            rec = recs[rows[j]]
            if backend is None or rec.backend == backend:
                return rec
        return None

    def neighbors(self, space: str, inputs: Mapping[str, int]
                  ) -> List[TuneRecord]:
        """Every serving record COMPARABLE to ``inputs``: same space, same
        input-dim names, same exact-match values (dtype/layout/...).  This
        is the candidate set nearest() searches and admission bucketing
        scans — served from the log2 index's groups, so the cost is the
        group size, not the store size."""
        inputs = normalize_inputs(inputs)
        keys = tuple(sorted(inputs))
        exact = tuple((k, inputs[k]) for k in keys if k in EXACT_MATCH_PARAMS)
        with self._lock:
            index = self._nearest_index
            if index is None:
                index = self._nearest_index = self._build_nearest_index()
        group = index.get((space, keys, exact))
        return list(group["recs"]) if group is not None else []

    def _nearest_linear(self, space: str, inputs: Mapping[str, int],
                        backend: Optional[str] = None,
                        max_distance: float = 2.0) -> Optional[TuneRecord]:
        """The pre-index O(records) reference scan — kept for the E14 bench
        comparison and the index-equivalence tests."""
        inputs = normalize_inputs(inputs)
        best, best_d = None, max_distance
        with self._lock:
            candidates = list(self._index.values())
        for rec in candidates:
            if rec.space != space:
                continue
            if backend is not None and rec.backend != backend:
                continue
            d = _shape_distance(inputs, rec.inputs)
            if d is not None and d <= best_d:
                best, best_d = rec, d
        return best

    def records(self, *, backend: Optional[str] = None) -> List[TuneRecord]:
        """Latest serving record per (backend, shape), most recent first."""
        with self._lock:
            recs = [r for (b, _), r in self._index.items()
                    if backend is None or b == backend]
        return sorted(recs, key=lambda r: -r.created_at)

    def training_records(self, *, space: Optional[str] = None,
                         backend: Optional[str] = None) -> List[TuneRecord]:
        """The FULL measurement log (superseded re-tunes + sample records),
        chronological — the model-training harvest (model.py) reads this.

        Disk-backed stores re-parse the JSONL on demand: training is an
        offline path, and serving processes should not pay the memory of
        the whole sample log just to hold the serving index.
        """
        def keep(r: TuneRecord) -> bool:
            return ((space is None or r.space == space)
                    and (backend is None or r.backend == backend))

        if self.path is None:
            with self._lock:
                return [r for r in self._all if keep(r)]
        out: List[TuneRecord] = []
        if self.path.exists():
            with self.path.open("r", encoding="utf-8") as fh:
                for line in fh:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = TuneRecord.from_json(line)
                    except (ValueError, TypeError, KeyError):
                        continue                   # torn tail / garbage
                    if keep(rec):
                        out.append(rec)
        return out

    def backends(self) -> List[str]:
        """Distinct backend fingerprints with serving records."""
        with self._lock:
            return sorted({b for b, _ in self._index})

    def invalidate_memos(self) -> None:
        """Drop the nearest-lookup memo (called on serving-state installs)."""
        with self._lock:
            self._nearest_memo.clear()

    def __len__(self) -> int:
        return len(self._index)

    def __contains__(self, key: str) -> bool:
        return key in self._latest

    # -- merge / export ------------------------------------------------------
    def merge(self, other: "RecordStore", *,
              lineage: Optional[str] = None) -> int:
        """Append every latest record of `other` not already newer here.

        Merging moves the serving index (latest per (backend, shape)) only;
        training-sample records stay with the store that measured them.
        Provenance is preserved: the original ``source`` tag survives (it
        says why the record was measured — ``retune``/``session`` audits and
        the model harvest key on it); the merge itself is recorded separately
        in ``merged_from`` (``lineage``, defaulting to the other store's
        path — a fleet shard merge passes the worker id instead).
        """
        if lineage is None:
            lineage = str(other.path) if other.path is not None else "memory"
        n = 0
        for rec in other.records():
            cur = self._index.get((rec.backend, rec.key))
            if cur is None or rec.created_at > cur.created_at:
                self.add(dataclasses.replace(rec, merged_from=lineage))
                n += 1
        return n

    def export(self, path: os.PathLike) -> int:
        """Write a compacted store (latest record per key) atomically."""
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        recs = self.records()
        tmp = path.with_name(path.name + ".tmp")
        io = chaos._IO
        with tmp.open("w", encoding="utf-8") as fh:
            blob = "".join(rec.to_json() + "\n"
                           for rec in reversed(recs))   # chronological order
            if io is None:
                fh.write(blob)
            else:
                io.file_write(fh, blob, "store.export")
            fh.flush()
            if io is None:
                os.fsync(fh.fileno())
            else:
                io.fsync(fh, "store.export.fsync")
        if io is None:
            os.replace(tmp, path)
        else:
            io.replace(tmp, path, "store.export.replace")
        return len(recs)

    # -- reporting -----------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        per_space: Dict[str, int] = {}
        per_backend: Dict[str, int] = {}
        for rec in self.records():
            per_space[rec.space] = per_space.get(rec.space, 0) + 1
            per_backend[rec.backend] = per_backend.get(rec.backend, 0) + 1
        return {
            "path": str(self.path) if self.path else None,
            "schema_version": SCHEMA_VERSION,
            "shapes": len(self._latest),
            "records": len(self._index),
            "lines": self.n_lines,
            "skipped_lines": self.n_skipped,
            "sample_records": self.n_samples,
            "per_space": per_space,
            "per_backend": per_backend,
            "lookups": {"hits": self.hits, "nearest": self.nearest_hits,
                        "misses": self.misses},
        }


# ---------------------------------------------------------------------------
# Frozen dispatch plans: the install-time compilation of a serving generation.
#
# The paper splits tuning into an offline install stage and an O(1) online
# lookup; the PR 1-4 serving path re-paid resolution cost on every call
# (sha1 input_key per exact probe, memoized model scans, neighbor search).
# A DispatchPlan moves all of that to ``install_serving`` time: the current
# (store, ModelSet, telemetry hot set) compiles into ONE flat
# (space, shape_key) -> (config, tier) table, so steady-state ``_tuned_cfg``
# is a single lock-free dict probe with zero store or model traffic.
# ---------------------------------------------------------------------------

PLAN_HOT_K = 32         # telemetry hot shapes pre-resolved per space


class DispatchPlan:
    """One generation's frozen shape->config table.

    The base ``_table`` is built once at install time and never mutated; an
    ``_overlay`` accepts slow-path promotions (shapes the plan missed whose
    model/nearest resolution is worth freezing) — entries are only ever
    added within a generation, never changed or removed, so a lock-free
    reader sees either a miss or a complete entry, never a torn one.

    ``store_version`` pins the plan to the record-store state it was
    compiled from: the moment the store gains a record (a retune session
    committing mid-generation), every lookup stands aside and dispatch
    falls back to full slow-path resolution until the next
    ``install_serving`` recompiles — a frozen plan must never shadow a
    fresher tuning outcome.  Each entry carries the tier that produced it
    ("exact" | "model" | "nearest") so plan hits keep feeding the same
    per-tier serving statistics the slow path maintains.
    """

    __slots__ = ("generation", "fingerprint", "store_version", "hits",
                 "misses", "source", "digest", "_table", "_overlay", "_lock")

    OVERLAY_CAP = 4096          # runaway-shape backstop, like the memos

    def __init__(self, *, generation: int, fingerprint: Optional[str],
                 store_version: int,
                 table: Dict[tuple, Tuple[Dict[str, int], str]],
                 source: str = "compiled", digest: Optional[str] = None):
        self.generation = generation
        self.fingerprint = fingerprint
        self.store_version = store_version
        self.hits = 0
        self.misses = 0
        self.source = source        # "compiled" (install-time) | "loaded"
        self.digest = digest        # artifact sha256, when source=="loaded"
        self._table = table
        self._overlay: Dict[tuple, Tuple[Dict[str, int], str]] = {}
        self._lock = threading.Lock()

    def lookup(self, space: str, key: tuple
               ) -> Optional[Tuple[Dict[str, int], str]]:
        """(config, tier) for a planned shape, else None.  Lock-free."""
        entry = self._table.get((space, key))
        if entry is None:
            entry = self._overlay.get((space, key))
        return entry

    def promote(self, space: str, key: tuple, cfg: Mapping[str, int],
                tier: str) -> None:
        """Freeze a slow-path resolution so later calls are plan hits."""
        with self._lock:
            if len(self._overlay) < self.OVERLAY_CAP:
                self._overlay[(space, key)] = (dict(cfg), tier)

    def __len__(self) -> int:
        return len(self._table) + len(self._overlay)

    def stats(self) -> Dict[str, object]:
        tiers: Dict[str, int] = {}
        for _, tier in list(self._table.values()):
            tiers[tier] = tiers.get(tier, 0) + 1
        return {"generation": self.generation, "entries": len(self),
                "built": len(self._table), "promoted": len(self._overlay),
                "hits": self.hits, "misses": self.misses, "tiers": tiers,
                "source": self.source, "digest": self.digest}


def compile_plan(store: Optional[RecordStore], models, fingerprint:
                 Optional[str], *, telemetry=None, hot_k: int = PLAN_HOT_K,
                 generation: int = 0) -> Optional["DispatchPlan"]:
    """Compile a serving generation into a frozen DispatchPlan.

    Coverage: every serving record visible under ``fingerprint`` becomes an
    "exact" entry, then the telemetry hot set (top ``hot_k`` shapes per
    space) is pre-resolved through the model and nearest tiers — the §6
    model scan and the neighbor search run HERE, at install time, instead
    of on the first serving call of each hot shape.  Shapes no tier can
    resolve stay out of the plan so the slow path keeps owning the
    warn-once degradation story.

    Known accounting wart: the install-time ``predict`` calls count in the
    ModelSet's hit/miss/gated statistics (predict has no ``count=`` knob),
    so each install moves them by at most hot_k x spaces — bounded, and
    dwarfed by serving traffic.
    """
    if store is None and models is None:
        return None
    table: Dict[tuple, Tuple[Dict[str, int], str]] = {}
    store_version = -1
    if store is not None:
        store_version = store.version
        with store._lock:
            if fingerprint is None:
                recs = list(store._latest.values())
            else:
                recs = [r for (b, _), r in store._index.items()
                        if b == fingerprint]
        for rec in recs:
            table[(rec.space, shape_key(rec.inputs))] = (dict(rec.config),
                                                         "exact")
    if telemetry is not None and hot_k > 0:
        # tests hand install_serving duck-typed model stubs; only a real
        # predict() can pre-resolve (dispatch guards the same way)
        predict = getattr(models, "predict", None) if models is not None \
            else None
        for space in telemetry.spaces():
            for inputs, _count in telemetry.hot_shapes(space, hot_k):
                key = (space, shape_key(inputs))
                if key in table:
                    continue
                cfg, tier = None, ""
                if callable(predict):
                    got = predict(space, inputs, backend=fingerprint)
                    if got is not None:
                        cfg, tier = got[0], "model"
                if cfg is None and store is not None:
                    rec = store.nearest(space, inputs, backend=fingerprint,
                                        count=False)
                    if rec is not None:
                        cfg, tier = rec.config, "nearest"
                if cfg is not None:
                    table[key] = (dict(cfg), tier)
    return DispatchPlan(generation=generation, fingerprint=fingerprint,
                        store_version=store_version, table=table)


# ---------------------------------------------------------------------------
# Process-global serving state: the dispatcher's (store, models, fingerprint)
# view, swapped ATOMICALLY as one generation so a hot-swap mid-resolution can
# never hand dispatch a torn store/model pair (old store + new models).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServingState:
    """One immutable generation of the dispatcher's tuned-serving view."""

    store: Optional[RecordStore] = None
    models: Optional[object] = None          # tunedb.model.ModelSet
    fingerprint: Optional[str] = None        # backend pin (None = any)
    generation: int = 0                      # bumps on every install
    plan: Optional[DispatchPlan] = None      # frozen shape->config table


_STATE = ServingState()
_STATE_LOCK = threading.Lock()
_KEEP = object()          # sentinel: "leave this field as installed"


def serving_state() -> ServingState:
    """The current generation — ONE atomic read for a consistent view."""
    return _STATE


def install_generation() -> int:
    return _STATE.generation


def install_serving(*, store: object = _KEEP, models: object = _KEEP,
                    fingerprint: object = _KEEP,
                    build_plan: bool = True,
                    plan_hot_k: int = PLAN_HOT_K,
                    sentry: object = None,
                    plan: Optional[DispatchPlan] = None,
                    plan_dir: Optional[os.PathLike] = None) -> ServingState:
    """Atomically swap any subset of the dispatcher's serving state.

    Every install starts a new generation: the reference flips in one
    assignment (readers see either the old tuple or the new one, never a
    mix), the warn-once degradation latches re-arm (a fresh install deserves
    fresh warnings if IT degrades — the reinstall contract
    ``dispatch.reset_fallback_warnings`` documents), and the incoming
    store/ModelSet memos are invalidated so no pre-swap resolution leaks
    into the new generation.  Fields left at the default keep their
    installed value (e.g. a models-only hot-swap).

    Unless ``build_plan=False``, the install also COMPILES the incoming
    (store, ModelSet, telemetry hot set) into the generation's frozen
    :class:`DispatchPlan` — the paper's offline install stage: exact
    records, the §6 model scans for the hot set, and neighbor lookups all
    resolve here, once, so the online ``_tuned_cfg`` path is one lock-free
    table probe.  The build runs OUTSIDE the install lock (it can take
    real time when a measurer re-measures the hot set's top-k), then the
    flip itself is a compare-and-swap: if another install landed while we
    compiled, the build reruns against the fresh state — installs are rare
    enough that the retry is theoretical, and a half-published plan is
    never observable either way.

    ``sentry`` (a :class:`~repro.tunedb.obs.RegressionSentry`, or any object
    with ``blocks_install(cur_state, new_store)``) is the promotion gate:
    before anything is compiled or swapped, the sentry diffs the incoming
    store against the serving one (or replays the store's supersession log
    for an in-place retune).  If the new generation regresses a serving
    record beyond the noise margin, the install warns, publishes
    ``tunedb_sentry_*`` metrics, and returns the CURRENT state unchanged —
    callers detect the refusal by the unbumped ``generation``.

    ``plan`` (or ``plan_dir``, a persisted artifact directory — see
    :mod:`repro.tunedb.plans`) installs a PRE-BUILT plan instead of
    compiling one: the golden-artifact cold-start path, which skips the
    install-time model scans entirely.  The plan is re-pinned to the live
    store's ``version`` at flip time (a persisted artifact's recorded
    version counts another process's appends and means nothing here), and
    when no fingerprint is pinned yet the plan's own fingerprint is
    adopted.  Subsequent in-process appends still stand the plan aside
    exactly as they would a compiled one.
    """
    global _STATE
    if plan_dir is not None and plan is None:
        from .plans import load_plan
        plan = load_plan(plan_dir)      # PlanArtifactError propagates
    preplan = plan
    while True:
        cur = _STATE
        new_store = cur.store if store is _KEEP else store
        new_models = cur.models if models is _KEEP else models
        new_fp = cur.fingerprint if fingerprint is _KEEP else fingerprint
        if fingerprint is _KEEP and new_fp is None and preplan is not None:
            new_fp = preplan.fingerprint
        if sentry is not None and sentry.blocks_install(cur, new_store):
            return cur          # refused: previous generation stays live
        # invalidate BEFORE the plan compiles: resolutions memoized under
        # the old generation must not leak into the new plan's entries
        for obj in (new_store, new_models):
            invalidate = getattr(obj, "invalidate_memos", None)
            if callable(invalidate):
                invalidate()
        plan = preplan
        if plan is not None:
            # re-pin to the LIVE store's in-process version counter so the
            # stand-aside gate works; -1 (no store) never matches a store
            plan.store_version = (new_store.version
                                  if new_store is not None else -1)
        elif build_plan:
            from .telemetry import get_telemetry
            plan = compile_plan(new_store, new_models, new_fp,
                                telemetry=get_telemetry(), hot_k=plan_hot_k)
        with _STATE_LOCK:
            if _STATE is not cur:
                continue            # lost the race: rebuild against fresh
            generation = cur.generation + 1
            if plan is not None:    # stamp before publication, never after
                plan.generation = generation
            new = ServingState(store=new_store, models=new_models,
                               fingerprint=new_fp, generation=generation,
                               plan=plan)
            _STATE = new
        break
    from repro.kernels.dispatch import reset_fallback_warnings
    reset_fallback_warnings()
    try:        # installs are rare: publishing generation metadata is cheap
        from .obs.metrics import get_registry
        reg = get_registry()
        reg.counter("tunedb_installs_total",
                    "serving-state swaps (new generations)").inc(
                        planned="yes" if plan is not None else "no")
        if plan is not None:
            reg.gauge("tunedb_plan_built_entries",
                      "entries compiled into the current plan").set(
                          len(plan._table))
    except Exception:
        pass    # observability must never block an install
    return new


def install_store(store: Optional[RecordStore], *,
                  fingerprint: Optional[str] = None) -> None:
    """Make `store` visible to the kernel dispatcher (serve warm-start).

    ``fingerprint`` pins dispatch lookups (store AND model tiers) to one
    backend's records — the multi-backend serving mode.  ``None`` keeps the
    any-backend behavior a single-backend store expects.
    """
    install_serving(store=store, fingerprint=fingerprint)


def get_store() -> Optional[RecordStore]:
    return _STATE.store


def active_fingerprint() -> Optional[str]:
    """The backend fingerprint dispatch lookups are pinned to (None = any)."""
    return _STATE.fingerprint


def clear_store() -> None:
    install_store(None)
