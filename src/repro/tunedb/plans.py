"""Golden dispatch-plan artifacts: persist, publish, follow.

PR 5 made ``install_serving`` COMPILE each serving generation into a frozen
:class:`~repro.tunedb.store.DispatchPlan`; this module makes that plan a
first-class **artifact** — the MITuna "golden find-DB" shape applied to
compiled plans.  Three layers, one file:

**Artifact** (:func:`export_plan` / :func:`load_plan`) — one generation's
plan serialized to a directory::

    <store>.plan/<generation>/
        manifest.json     # schema version, generation, fingerprint,
                          # store_version, digest, n_entries, provenance
        entries.jsonl     # one canonical JSON line per (space, shape) entry

The entries blob is byte-deterministic (sorted entries, sorted keys) and the
manifest pins its SHA-256 ``digest``, so a loader can prove it holds exactly
what the exporter wrote.  Loading is gated like model artifacts: a manifest
from a newer schema, a torn file, or a digest mismatch raises
:class:`PlanArtifactError` — a plan is either verified whole or refused,
never half-read.  ``install_serving(plan_dir=...)`` in a cold process loads
the table directly and skips the install-time model scans entirely.

Export REFUSES a stale plan (:class:`StalePlanError`): if the live store's
``version`` has advanced past the plan's compiled ``store_version``, the
in-memory plan no longer reflects the store and must be recompiled before
it can be published as golden.

**Registry** (:class:`PlanRegistry`) — the fleet filesystem bus reused for
*distribution* instead of *collection*::

    <registry>/
        generations/<generation>/   # immutable plan artifacts (see above)
        CURRENT.json                # the atomic pointer: {generation,
                                    # fingerprint, digest, path, published_at}

``publish`` writes the artifact into a temp directory, renames it into
``generations/`` (atomic; a collision with a racing publisher retries at the
next generation number), then atomically replaces ``CURRENT.json``.  Readers
therefore see either the previous complete generation or the new complete
generation — never a torn one.

**Follower** (:class:`PlanFollower`) — the replica side: a daemon thread
polls ``CURRENT.json`` and, when the published generation advances, pulls
the artifact, verifies the digest, optionally runs a
:class:`~repro.tunedb.obs.RegressionSentry` coverage diff against the plan
it currently serves, and hot-swaps through the same atomic
``install_serving`` flip every other promotion uses.  A pull that fails any
check is counted and dropped — the replica keeps serving its current
generation, and the next poll retries.  Generations never move backwards:
a ``CURRENT`` older than what the follower already installed is refused as
stale, so no replica ever serves a torn or rolled-back plan.

See ``docs/PLANS.md`` for the written contract this module implements.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import threading
import time
from typing import Callable, Dict, List, Mapping, Optional, Tuple

from . import chaos
from .store import (DispatchPlan, RecordStore, normalize_config,
                    normalize_inputs, shape_key)

__all__ = [
    "PLAN_SCHEMA_VERSION", "PlanArtifactError", "StalePlanError",
    "PlanManifest", "default_plan_dir", "plan_entries", "entries_blob",
    "plan_digest", "export_plan", "load_plan", "read_manifest",
    "check_freshness", "PlanRegistry", "PlanFollower", "active_followers",
]

PLAN_SCHEMA_VERSION = 1

# lazily bound trace module (False = unavailable): follower install
# attempts probe one module attribute, so disabled tracing costs zero
# instrument calls on the plan-follow path
_TRACE = None

MANIFEST_NAME = "manifest.json"
ENTRIES_NAME = "entries.jsonl"
CURRENT_NAME = "CURRENT.json"
GENERATIONS = "generations"


class PlanArtifactError(RuntimeError):
    """A persisted plan cannot be loaded safely (schema from the future,
    torn manifest/entries, digest mismatch).  Callers degrade — recompile
    from the store — rather than serve a plan they cannot verify."""


class StalePlanError(PlanArtifactError):
    """The in-memory plan's compiled ``store_version`` is behind the live
    store: records were appended after the compile, so exporting this plan
    would publish a table that silently shadows fresher tuning outcomes.
    Recompile (``install_serving`` / ``compile_plan``) and export that."""


def default_plan_dir(store_path: os.PathLike) -> pathlib.Path:
    """Where a store's plan artifacts live: ``<store>.plan/`` sibling."""
    p = pathlib.Path(store_path)
    return p.with_name(p.name + ".plan")


# ---------------------------------------------------------------------------
# artifact serialization
# ---------------------------------------------------------------------------

def plan_entries(plan: DispatchPlan) -> List[Dict[str, object]]:
    """The plan's full table (base + overlay) as sorted, plain-JSON entries.

    Sorting makes the serialized blob byte-deterministic: the same plan
    always digests to the same value, so artifact equality is digest
    equality.  Overlay promotions are exported like built entries (their
    ``origin`` says where they came from); on load they are frozen into the
    base table — a promotion that proved itself in one generation IS part
    of the golden artifact.
    """
    out: List[Dict[str, object]] = []
    for origin, table in (("built", plan._table), ("promoted", plan._overlay)):
        for (space, key), (config, tier) in list(table.items()):
            out.append({
                "space": space,
                "inputs": {k: int(v) for k, v in key},
                "config": {k: int(v) for k, v in config.items()},
                "tier": tier,
                "origin": origin,
            })
    out.sort(key=lambda e: (e["space"], sorted(e["inputs"].items())))
    return out


def entries_blob(entries: List[Dict[str, object]]) -> bytes:
    """Canonical JSONL bytes for a list of plan entries."""
    return "".join(json.dumps(e, sort_keys=True) + "\n"
                   for e in entries).encode("utf-8")


def plan_digest(blob: bytes) -> str:
    return "sha256:" + hashlib.sha256(blob).hexdigest()


@dataclasses.dataclass(frozen=True)
class PlanManifest:
    """The verified identity of one exported plan artifact."""

    generation: int
    fingerprint: Optional[str]
    store_version: int
    digest: str
    n_entries: int
    created_at: float
    store_path: Optional[str] = None
    store_records: int = 0
    store_max_created_at: float = 0.0
    plan_schema_version: int = PLAN_SCHEMA_VERSION

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "PlanManifest":
        if not isinstance(d, Mapping) or "digest" not in d \
                or "generation" not in d:
            raise PlanArtifactError(f"not a plan manifest: {dict(d)!r:.120}")
        version = int(d.get("plan_schema_version", -1))
        if version > PLAN_SCHEMA_VERSION:
            raise PlanArtifactError(
                f"plan schema v{version} > v{PLAN_SCHEMA_VERSION} "
                "(refusing to misread a newer writer's artifact)")
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


def _write_artifact(plan: DispatchPlan, dest: pathlib.Path, *,
                    generation: int,
                    store: Optional[RecordStore]) -> PlanManifest:
    """Write ``dest/`` (manifest + entries) atomically via tmp-dir rename.

    ``dest`` must not exist: the artifact directory appears fully formed or
    not at all.  Raises :exc:`FileExistsError` when a racing writer won the
    name — registry publishers retry at the next generation number.
    """
    if store is not None and plan.store_version >= 0 \
            and store.version > plan.store_version:
        raise StalePlanError(
            f"plan was compiled at store version {plan.store_version} but "
            f"the store has advanced to {store.version}: "
            f"{store.version - plan.store_version} record(s) appended since "
            "the compile would be silently shadowed; recompile "
            "(install_serving) before exporting")
    entries = plan_entries(plan)
    blob = entries_blob(entries)
    meta: Dict[str, object] = {}
    if store is not None:
        recs = store.records()
        meta = {
            "store_path": str(store.path) if store.path else None,
            "store_records": len(recs),
            "store_max_created_at": max(
                (r.created_at for r in recs), default=0.0),
        }
    manifest = PlanManifest(
        generation=int(generation),
        fingerprint=plan.fingerprint,
        store_version=plan.store_version,
        digest=plan_digest(blob),
        n_entries=len(entries),
        created_at=time.time(),
        **meta)
    dest = pathlib.Path(dest)
    dest.parent.mkdir(parents=True, exist_ok=True)
    tmp = dest.parent / f".tmp-{dest.name}-{os.getpid()}-{id(plan) & 0xffff}"
    tmp.mkdir(parents=True, exist_ok=True)
    io = chaos._IO
    try:
        if io is None:
            (tmp / ENTRIES_NAME).write_bytes(blob)
            (tmp / MANIFEST_NAME).write_text(
                json.dumps(manifest.to_dict(), sort_keys=True),
                encoding="utf-8")
            os.rename(tmp, dest)        # atomic: whole artifact or nothing
        else:
            io.write_bytes(tmp / ENTRIES_NAME, blob, "plan.export.entries")
            io.write_text(tmp / MANIFEST_NAME,
                          json.dumps(manifest.to_dict(), sort_keys=True),
                          "plan.export.manifest")
            io.rename(tmp, dest, "plan.export.rename")
    except BaseException:
        for p in (tmp / ENTRIES_NAME, tmp / MANIFEST_NAME):
            p.unlink(missing_ok=True)
        if tmp.exists():
            tmp.rmdir()
        raise
    return manifest


def _generation_name(generation: int) -> str:
    return f"{int(generation):08d}"


def _next_generation(root: pathlib.Path) -> int:
    """One past the highest numeric artifact directory under ``root``."""
    latest = 0
    if root.is_dir():
        for p in root.iterdir():
            try:
                latest = max(latest, int(p.name))
            except ValueError:
                continue                # tmp dirs, foreign files
    return latest + 1


def export_plan(plan: DispatchPlan, out_dir: os.PathLike, *,
                store: Optional[RecordStore] = None,
                generation: Optional[int] = None) -> pathlib.Path:
    """Export ``plan`` under ``out_dir/<generation>/``; returns the path.

    ``out_dir`` is the artifact root (``<store>.plan/`` by convention);
    ``generation`` defaults to one past the highest generation already
    exported there.  ``store`` (when given) arms the staleness gate and
    records provenance in the manifest.  Raises :class:`StalePlanError`
    rather than silently truncating when the store outran the plan.
    """
    root = pathlib.Path(out_dir)
    gen = generation if generation is not None else _next_generation(root)
    while True:
        dest = root / _generation_name(gen)
        try:
            _write_artifact(plan, dest, generation=gen, store=store)
            return dest
        except FileExistsError:
            if generation is not None:
                raise
            gen += 1                    # racing exporter took the slot


def read_manifest(plan_dir: os.PathLike) -> PlanManifest:
    """Parse + schema-gate a plan directory's manifest (no entry read)."""
    path = pathlib.Path(plan_dir) / MANIFEST_NAME
    io = chaos._IO
    try:
        text = (path.read_text(encoding="utf-8") if io is None
                else io.read_text(path, "plan.pull.manifest"))
        doc = json.loads(text)
    except FileNotFoundError:
        raise PlanArtifactError(f"{path}: not a plan artifact (no manifest)")
    except (OSError, ValueError) as e:
        raise PlanArtifactError(f"{path}: torn or unreadable manifest ({e})")
    return PlanManifest.from_dict(doc)


def load_plan(plan_dir: os.PathLike) -> DispatchPlan:
    """Load + verify a persisted plan artifact into a :class:`DispatchPlan`.

    The manifest is schema-gated, the entries blob is digest-verified
    against it byte-for-byte BEFORE a single entry is parsed, and every
    entry lands in the base table (overlay promotions were frozen at
    export).  Any failure raises :class:`PlanArtifactError`; a loaded plan
    is whole by construction.  ``plan.source`` is ``"loaded"`` and
    ``plan.digest`` carries the verified digest so observability can tell
    a golden plan from an install-time compile.
    """
    plan_dir = pathlib.Path(plan_dir)
    manifest = read_manifest(plan_dir)
    entries_path = plan_dir / ENTRIES_NAME
    io = chaos._IO
    try:
        blob = (entries_path.read_bytes() if io is None
                else io.read_bytes(entries_path, "plan.pull.entries"))
    except OSError as e:
        raise PlanArtifactError(f"{entries_path}: unreadable entries ({e})")
    digest = plan_digest(blob)
    if digest != manifest.digest:
        raise PlanArtifactError(
            f"{plan_dir}: digest mismatch (manifest {manifest.digest}, "
            f"entries {digest}) — torn or tampered artifact, refusing to "
            "serve it")
    table: Dict[tuple, Tuple[Dict[str, int], str]] = {}
    for i, line in enumerate(blob.decode("utf-8").splitlines()):
        if not line.strip():
            continue
        try:
            e = json.loads(line)
            key = (str(e["space"]), shape_key(normalize_inputs(e["inputs"])))
            table[key] = (normalize_config(e["config"]),
                          str(e.get("tier", "exact")))
        except (ValueError, TypeError, KeyError) as exc:
            # the digest already matched, so a bad line is a bad EXPORT,
            # not a torn file — still refuse: golden means verified whole
            raise PlanArtifactError(
                f"{entries_path}:{i + 1}: bad plan entry ({exc})")
    if len(table) != manifest.n_entries:
        raise PlanArtifactError(
            f"{plan_dir}: {len(table)} entries parsed but manifest "
            f"promises {manifest.n_entries}")
    return DispatchPlan(
        generation=manifest.generation, fingerprint=manifest.fingerprint,
        store_version=manifest.store_version, table=table,
        source="loaded", digest=manifest.digest)


def check_freshness(manifest: PlanManifest,
                    store: Optional[RecordStore]) -> Optional[str]:
    """Does the live store look NEWER than this artifact?  Returns a
    human-readable warning (or None).

    A cold process cannot compare ``store.version`` (it counts in-process
    appends, so a freshly-opened store is always at 0); the manifest's
    recorded ``store_max_created_at`` is the cross-process signal: serving
    records stamped after the export mean the artifact no longer reflects
    the store's best knowledge.  Advisory only — the caller decides whether
    to install anyway (the plan still stands aside on the next in-process
    append either way).
    """
    if store is None or manifest.store_max_created_at <= 0:
        return None
    newest = max((r.created_at for r in store.records()), default=0.0)
    if newest > manifest.store_max_created_at + 1e-6:
        return (f"store has records newer ({newest:.0f}) than the plan "
                f"artifact ({manifest.store_max_created_at:.0f}); the "
                "loaded plan may shadow fresher tuning — consider "
                "re-exporting")
    return None


# ---------------------------------------------------------------------------
# registry: publish/follow over a shared directory
# ---------------------------------------------------------------------------

def _atomic_write(path: pathlib.Path, text: str, *,
                  site: str = "plan.registry.write") -> None:
    io = chaos._IO
    tmp = path.with_name(path.name + f".tmp-{os.getpid()}")
    if io is None:
        tmp.write_text(text, encoding="utf-8")
        os.replace(tmp, path)
    else:
        io.write_text(tmp, text, site)
        io.replace(tmp, path, site + ".replace")


class PlanRegistry:
    """One coordinator publishes plan generations; N replicas follow.

    The fleet's filesystem-bus pattern reused for distribution: every
    mutation is a single atomic filesystem operation, so any number of
    follower processes and publishers share the directory with no locks.
    ``CURRENT.json`` is the only mutable file — an atomic tmp+replace
    pointer at the latest complete artifact under ``generations/``.
    """

    def __init__(self, root: os.PathLike):
        self.root = pathlib.Path(root)
        self.generations_dir = self.root / GENERATIONS

    def init(self) -> "PlanRegistry":
        self.generations_dir.mkdir(parents=True, exist_ok=True)
        return self

    def generation_dir(self, generation: int) -> pathlib.Path:
        return self.generations_dir / _generation_name(generation)

    def publish(self, plan: DispatchPlan, *,
                store: Optional[RecordStore] = None) -> PlanManifest:
        """Export ``plan`` as the registry's next generation and flip
        ``CURRENT`` to it.  Artifact first, pointer second: a follower that
        reads the new pointer always finds a complete, digest-verified
        artifact behind it.  Stale plans are refused (see
        :class:`StalePlanError`) before anything touches the registry.
        """
        if plan is None:
            raise ValueError("nothing to publish: plan is None")
        self.init()
        gen = _next_generation(self.generations_dir)
        while True:
            dest = self.generation_dir(gen)
            try:
                manifest = _write_artifact(plan, dest, generation=gen,
                                           store=store)
                break
            except FileExistsError:
                gen += 1                # racing publisher took the slot
        pointer = dict(manifest.to_dict())
        pointer["path"] = f"{GENERATIONS}/{_generation_name(gen)}"
        pointer["published_at"] = time.time()
        _atomic_write(self.root / CURRENT_NAME,
                      json.dumps(pointer, sort_keys=True))
        self._count("published")
        return manifest

    def current(self) -> Optional[Dict[str, object]]:
        """The published pointer, or None (no publish yet / torn write on a
        filesystem without atomic replace — indistinguishable, and both
        mean "try again next poll")."""
        io = chaos._IO
        try:
            path = self.root / CURRENT_NAME
            text = (path.read_text(encoding="utf-8") if io is None
                    else io.read_text(path, "plan.registry.current"))
            doc = json.loads(text)
        except (OSError, ValueError):
            return None
        if not isinstance(doc, dict) or "generation" not in doc:
            return None
        return doc

    def pull(self, pointer: Mapping[str, object]) -> DispatchPlan:
        """Load the artifact behind a ``current()`` pointer and verify that
        it is exactly the one the pointer promised (digest equality) —
        a publisher overwriting generations out from under a reader (which
        the protocol never does) would be caught here, not served."""
        rel = str(pointer.get("path")
                  or f"{GENERATIONS}/{_generation_name(int(pointer['generation']))}")
        plan = load_plan(self.root / rel)
        want = pointer.get("digest")
        if want and plan.digest != want:
            raise PlanArtifactError(
                f"{self.root / rel}: artifact digest {plan.digest} does not "
                f"match the published pointer ({want})")
        return plan

    def _count(self, event: str) -> None:
        try:
            from .obs.metrics import get_registry
            get_registry().counter(
                "tunedb_plan_registry_events_total",
                "plan registry publishes/pulls").inc(event=event)
        except Exception:
            pass        # observability never blocks the protocol


# ---------------------------------------------------------------------------
# follower: the replica side of the protocol
# ---------------------------------------------------------------------------

# live followers, for the scrape-time metrics collector (obs.metrics reads
# this at /metrics render time — zero instrumentation on the poll path)
_FOLLOWERS: List["PlanFollower"] = []
_FOLLOWERS_LOCK = threading.Lock()


def active_followers() -> List["PlanFollower"]:
    with _FOLLOWERS_LOCK:
        return list(_FOLLOWERS)


class PlanFollower:
    """Poll a :class:`PlanRegistry` and atomically adopt new generations.

    By default an adopted plan is installed into the process-global serving
    state (``install_serving(plan=...)``) — the same one-reference flip the
    retune controller uses, so a replica mid-resolution sees either the old
    generation or the new one, never a mix.  Tests and synthetic fleets
    inject ``install=`` / ``current_plan=`` to follow into a private
    replica state instead.

    Refusal, not failure, is the steady state of a distributed puller:

    * **torn pull** — the artifact fails digest verification (or vanished
      mid-read): counted as ``refused_digest``, retried next poll;
    * **stale generation** — ``CURRENT`` points at or below what this
      follower already installed (a rolled-back or replayed pointer):
      counted as ``refused_stale``, never installed;
    * **sentry refusal** — the new plan's coverage regresses the serving
      plan beyond the sentry margin: counted as ``refused_sentry`` and the
      current generation keeps serving.
    """

    def __init__(self, registry: os.PathLike, *,
                 store: Optional[RecordStore] = None,
                 fingerprint: Optional[str] = None,
                 poll_s: float = 2.0,
                 sentry=None,
                 install: Optional[Callable] = None,
                 current_plan: Optional[Callable] = None,
                 name: Optional[str] = None):
        self.registry = (registry if isinstance(registry, PlanRegistry)
                         else PlanRegistry(registry))
        self.store = store
        self.fingerprint = fingerprint
        self.poll_s = float(poll_s)
        self.sentry = sentry
        self.name = name or f"follower-{os.getpid()}-{id(self) & 0xffff}"
        self.generation = -1            # last INSTALLED registry generation
        self.installed_at: Optional[float] = None
        self.lag_s: Optional[float] = None   # publish -> install delay
        self.polls = 0
        self.installs = 0
        self.refused_digest = 0
        self.refused_stale = 0
        self.refused_sentry = 0
        self.errors = 0
        self._install = install or self._install_serving
        self._current_plan = current_plan or self._serving_plan
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        with _FOLLOWERS_LOCK:
            _FOLLOWERS.append(self)

    # -- default install target: the process-global serving state ----------
    @staticmethod
    def _serving_plan():
        from .store import serving_state
        return serving_state().plan

    def _install_serving(self, plan: DispatchPlan,
                         pointer: Mapping[str, object]) -> bool:
        from .store import _KEEP, install_serving
        install_serving(
            store=self.store if self.store is not None else _KEEP,
            fingerprint=(self.fingerprint if self.fingerprint is not None
                         else _KEEP),
            plan=plan)
        return True

    # -- one protocol round --------------------------------------------------
    def poll_once(self) -> Optional[Dict[str, object]]:
        """Check the registry once; returns the pointer installed this
        round, or None (nothing new, or the candidate was refused)."""
        self.polls += 1
        pointer = self.registry.current()
        if pointer is None:
            return None
        try:
            gen = int(pointer["generation"])
        except (TypeError, ValueError):
            self.errors += 1
            return None
        if gen <= self.generation:
            if gen < self.generation:
                self.refused_stale += 1     # rollback: refuse, keep serving
            return None
        # a new candidate generation: the pull→verify→install attempt is
        # rare (one per publish), so its span is always kept when tracing
        # is on — the probe itself is one module-attribute read
        global _TRACE
        t = _TRACE
        if t is None:
            try:
                from .obs import trace as t
            except Exception:
                t = False
            _TRACE = t
        tr = t._TRACER if t else None
        sp = (tr.begin("plan.install", trace_id=t.new_trace_id(),
                       follower=self.name, generation=gen)
              if tr is not None else None)
        outcome = "installed"
        try:
            try:
                plan = self.registry.pull(pointer)
            except PlanArtifactError:
                self.refused_digest += 1    # torn pull: retry next poll
                outcome = "refused_digest"
                return None
            if self.sentry is not None:
                cur = self._current_plan()
                if cur is not None:
                    from .obs.snapshot import plan_snapshot
                    report = self.sentry.diff_plans(plan_snapshot(cur),
                                                    plan_snapshot(plan))
                    if not report.ok:
                        self.refused_sentry += 1
                        outcome = "refused_sentry"
                        import warnings
                        warnings.warn(
                            f"plan follower {self.name} refused generation "
                            f"{gen}: {len(report.regressions)} planned "
                            "shape(s) lose coverage vs the serving plan; "
                            f"keeping generation {self.generation}",
                            RuntimeWarning, stacklevel=2)
                        return None
            if not self._install(plan, pointer):
                self.errors += 1
                outcome = "error"
                return None
            self.generation = gen
            self.installs += 1
            self.installed_at = time.time()
            published = pointer.get("published_at")
            if isinstance(published, (int, float)) and published > 0:
                self.lag_s = max(self.installed_at - float(published), 0.0)
            return dict(pointer)
        finally:
            if sp is not None:
                tr.end(sp, outcome=outcome)

    # -- daemon loop ---------------------------------------------------------
    def start(self) -> "PlanFollower":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name=self.name, daemon=True)
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                self.poll_once()
            except Exception:
                self.errors += 1        # a broken poll must not kill the loop
            self._stop.wait(self.poll_s)

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None
        with _FOLLOWERS_LOCK:
            if self in _FOLLOWERS:
                _FOLLOWERS.remove(self)

    # -- reporting -----------------------------------------------------------
    def published_generation(self) -> Optional[int]:
        pointer = self.registry.current()
        if pointer is None:
            return None
        try:
            return int(pointer["generation"])
        except (TypeError, ValueError):
            return None

    def lag_generations(self) -> int:
        """How many generations behind the registry this follower is."""
        published = self.published_generation()
        if published is None:
            return 0
        return max(published - max(self.generation, 0), 0)

    def stats(self) -> Dict[str, object]:
        return {
            "name": self.name,
            "registry": str(self.registry.root),
            "generation": self.generation,
            "published_generation": self.published_generation(),
            "lag_generations": self.lag_generations(),
            "lag_s": self.lag_s,
            "polls": self.polls,
            "installs": self.installs,
            "refused_digest": self.refused_digest,
            "refused_stale": self.refused_stale,
            "refused_sentry": self.refused_sentry,
            "errors": self.errors,
            "running": self._thread is not None,
        }
