"""HLO text parsing: collective-communication byte accounting.

``compiled.cost_analysis()`` does not expose collective traffic, so we parse
the (SPMD-partitioned, hence per-device) HLO text and sum the bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Byte counting: per instruction we take the RESULT shape — in partitioned HLO
that is the per-device buffer the collective produces, i.e. the data that
crossed links into each chip (all-gather: the gathered buffer; all-reduce:
the reduced buffer ~ ring traffic within a small constant; reduce-scatter:
the shard).  The CPU backend upcasts bf16 compute to f32, dragging some
collectives to f32 — `normalize_bits` rescales any f32 collective down to the
deployment dtype so the roofline is not distorted by a CPU lowering artifact
(recorded in EXPERIMENTS.md §Dry-run notes).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BITS = {
    "pred": 8, "s8": 8, "u8": 8, "s16": 16, "u16": 16, "bf16": 16, "f16": 16,
    "s32": 32, "u32": 32, "f32": 32, "s64": 64, "u64": 64, "f64": 64,
    "c64": 64, "c128": 128,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# e.g.:  %ag = f32[512,1024]{1,0} all-gather(%x), channel_id=1, ...
#        ROOT %ar = (f32[8,128]{...}, f32[8,128]{...}) all-reduce(...)
_INSTR = re.compile(
    r"=\s*(?P<shape>\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(?P<kind>all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")

_SHAPE = re.compile(r"(?P<dtype>[a-z][a-z0-9]*)\[(?P<dims>[0-9,]*)\]")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    dtype: str
    shape: Tuple[int, ...]
    bytes: int


def _shape_bytes(dtype: str, dims_s: str) -> Tuple[Tuple[int, ...], int]:
    dims = tuple(int(d) for d in dims_s.split(",") if d) or (1,)
    n = 1
    for d in dims:
        n *= d
    bits = _DTYPE_BITS.get(dtype, 32)
    return dims, n * bits // 8


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    out: List[CollectiveOp] = []
    for m in _INSTR.finditer(hlo_text):
        kind = m.group("kind").replace("-start", "")
        # result may be a tuple shape: sum every component
        total = 0
        shape: Tuple[int, ...] = ()
        for sm in _SHAPE.finditer(m.group("shape")):
            dims, b = _shape_bytes(sm.group("dtype"), sm.group("dims"))
            total += b
            shape = dims
        if total:
            out.append(CollectiveOp(kind=kind, dtype=sm.group("dtype"),
                                    shape=shape, bytes=total))
    return out


def collective_bytes(hlo_text: str, *, normalize_bits: Optional[int] = None
                     ) -> Dict[str, int]:
    """Per-kind byte totals (+ 'total').  normalize_bits: rescale f32
    collectives to the deployment dtype width (CPU-upcast correction)."""
    totals: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    for op in parse_collectives(hlo_text):
        b = op.bytes
        if normalize_bits and _DTYPE_BITS.get(op.dtype, 32) == 32 \
                and normalize_bits < 32:
            b = b * normalize_bits // 32
        totals[op.kind] = totals.get(op.kind, 0) + b
    totals["total"] = sum(totals[k] for k in COLLECTIVE_KINDS)
    return totals
