from .hlo import collective_bytes, parse_collectives
from .roofline import RooflineTerms, roofline_from_artifacts, HW

__all__ = ["collective_bytes", "parse_collectives", "RooflineTerms",
           "roofline_from_artifacts", "HW"]
