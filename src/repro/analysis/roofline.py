"""Three-term roofline model from dry-run artifacts (TPU v5e target).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

cost_analysis() of an SPMD-partitioned executable is already per-device
(verified empirically — see EXPERIMENTS.md §Dry-run notes), so no /chips is
applied to the parsed numbers; the spec's "HLO_FLOPs / (chips x peak)" is the
same quantity expressed with global FLOPs.

MODEL_FLOPS accounting: 6*N*D for training (fwd 2ND + bwd 4ND), 2*N*D for
inference-only lowerings (prefill/decode), with N = active params (MoE).
The ratio MODEL_FLOPS / (HLO_FLOPs * chips) flags remat/redundancy waste.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (spec formula: 1 link)

HW = {"peak_flops_bf16": PEAK_FLOPS_BF16, "hbm_bw": HBM_BW, "ici_bw": ICI_BW}


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device raw quantities
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    # the three terms, in seconds
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    # accounting
    model_flops_global: float = 0.0
    useful_ratio: float = 0.0         # MODEL_FLOPS / (HLO_FLOPs * chips)
    bottleneck: str = ""
    roofline_fraction: float = 0.0    # t_compute / max(all terms)
    note: str = ""

    def finalize(self) -> "RooflineTerms":
        self.t_compute = self.flops_per_device / PEAK_FLOPS_BF16
        self.t_memory = self.bytes_per_device / HBM_BW
        self.t_collective = self.collective_bytes_per_device / ICI_BW
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        bound = max(max(terms.values()), 1e-30)
        # fraction of the step spent doing useful MXU work if perfectly
        # overlapped: the closer the dominant term is to the compute term,
        # the closer to roofline
        useful_t = (self.model_flops_global / self.chips) / PEAK_FLOPS_BF16
        self.roofline_fraction = useful_t / bound
        if self.flops_per_device * self.chips > 0:
            self.useful_ratio = (self.model_flops_global
                                 / (self.flops_per_device * self.chips))
        return self

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def model_flops(cfg, shape, *, kind: str) -> float:
    """Useful-work FLOPs, PaLM-style MFU accounting: parameter FLOPs
    (2*N_active per token forward) PLUS attention score/PV FLOPs (the S^2
    term, causal-halved) and SSD chunk FLOPs — at 32k context the quadratic
    term dominates every transformer, so 6ND alone would make the
    MODEL/HLO ratio meaningless there."""
    n = cfg.active_param_count
    B, S = shape.global_batch, shape.seq_len
    n_attn = sum(1 for mix, _ in cfg.pattern if mix == "attn") \
        * cfg.n_repeats
    n_ssd = cfg.n_layers - n_attn
    H, hd = cfg.n_heads, cfg.hd
    if cfg.is_encdec:
        n_attn += cfg.encoder_layers          # + cross attn below

    if kind in ("train", "prefill"):
        tokens = B * S
        param_f = 2.0 * n * tokens
        # causal self-attention: 2 matmuls x 2BHS^2*hd x 1/2 (causal)
        attn_f = 2.0 * B * H * S * S * hd * n_attn
        if cfg.is_encdec:
            attn_f += 4.0 * B * H * S * cfg.encoder_len * hd * cfg.n_layers
        ssd_f = 0.0
        if n_ssd:
            Q = cfg.ssd_chunk
            di = 2 * cfg.d_model
            Hs = di // cfg.ssm_head_dim
            P, St = cfg.ssm_head_dim, cfg.ssm_state
            # intra-chunk (masked quadratic) + chunk states + inter-chunk
            ssd_f = n_ssd * B * Hs * (S * Q * (P + St)      # intra
                                      + 2 * S * P * St * 2)  # states+inter
        fwd = param_f + attn_f + ssd_f
        return 3.0 * fwd if kind == "train" else fwd
    # decode: one token per sequence against an S-long cache
    param_f = 2.0 * n * B
    attn_f = 4.0 * B * H * S * hd * n_attn
    return param_f + attn_f


def roofline_from_artifacts(artifact: Dict[str, Any],
                            recompute_model_flops: bool = True
                            ) -> RooflineTerms:
    """Build terms from one dry-run JSON artifact (launch/dryrun.py).

    bytes_accessed is halved: all assigned full configs run bf16 on TPU but
    XLA:CPU lowers their compute in f32 (collective bytes get the same
    correction, per-op, in analysis/hlo.py).  It remains an HLO-op-
    granularity UPPER BOUND on HBM traffic — TPU fusion coalesces
    elementwise chains this count charges individually (EXPERIMENTS.md
    §Roofline notes).
    """
    mf = artifact["model_flops"]
    if recompute_model_flops:
        from repro.configs import SHAPES, get_config
        cfg = get_config(artifact["arch"])
        mf = model_flops(cfg, SHAPES[artifact["shape"]],
                         kind=artifact["kind"])
    rt = RooflineTerms(
        arch=artifact["arch"], shape=artifact["shape"], mesh=artifact["mesh"],
        chips=artifact["chips"],
        flops_per_device=artifact["cost"]["flops"],
        bytes_per_device=artifact["cost"]["bytes_accessed"] / 2.0,
        collective_bytes_per_device=artifact["collectives"]["total"],
        model_flops_global=mf,
        note=artifact.get("note", ""),
    )
    return rt.finalize()


def format_table(rows, *, title: str = "") -> str:
    """Markdown table for EXPERIMENTS.md."""
    hdr = ("| arch | shape | mesh | t_compute | t_memory | t_collective | "
           "bottleneck | MODEL/HLO | roofline frac |")
    sep = "|" + "---|" * 9
    lines = [f"### {title}", "", hdr, sep] if title else [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.t_compute*1e3:.2f} ms "
            f"| {r.t_memory*1e3:.2f} ms | {r.t_collective*1e3:.2f} ms "
            f"| {r.bottleneck} | {r.useful_ratio:.2f} "
            f"| {r.roofline_fraction:.1%} |")
    return "\n".join(lines)
