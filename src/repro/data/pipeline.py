"""Deterministic, index-sharded, resumable synthetic token pipeline.

Properties a production loader needs and this one has:
  * deterministic function of (seed, step, shard) — restart-safe: resuming
    from a checkpoint at step k regenerates exactly the batches k, k+1, ...;
  * index-sharded: each data-parallel host pulls only its slice, no host ever
    materializes the global batch;
  * stateless iteration (the "state" is the integer step in the checkpoint).

The token stream is a mixture of Zipfian unigrams and short Markov motifs so
small-model training (examples/train_smollm.py) has learnable structure
instead of uniform noise.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1          # data-parallel host count
    shard: int = 0             # this host's index
    zipf_a: float = 1.2
    motif_len: int = 8
    n_motifs: int = 256


class SyntheticTokenPipeline:
    """batch(step) -> {'tokens': (local_batch, seq_len) int32} deterministic."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_shards == 0, (
            "global batch must divide across data shards")
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_shards
        base = np.random.default_rng(cfg.seed)
        # fixed motif table: short token sequences the model can learn
        self._motifs = base.integers(
            0, cfg.vocab, (cfg.n_motifs, cfg.motif_len), dtype=np.int32)
        # Zipf-ish unigram distribution over the vocab
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks ** cfg.zipf_a
        self._unigram = p / p.sum()

    def _rng(self, step: int, row: int) -> np.random.Generator:
        # independent stream per (seed, step, global row) — shard-invariant
        return np.random.default_rng(
            (self.cfg.seed, step, row))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rows = range(cfg.shard * self.local_batch,
                     (cfg.shard + 1) * self.local_batch)
        out = np.empty((self.local_batch, cfg.seq_len), np.int32)
        for i, row in enumerate(rows):
            rng = self._rng(step, row)
            seq = rng.choice(cfg.vocab, size=cfg.seq_len,
                             p=self._unigram).astype(np.int32)
            # overwrite random spans with motifs (learnable bigram structure)
            n_spans = cfg.seq_len // (2 * cfg.motif_len)
            starts = rng.integers(0, cfg.seq_len - cfg.motif_len, n_spans)
            which = rng.integers(0, cfg.n_motifs, n_spans)
            for s, w in zip(starts, which):
                seq[s:s + cfg.motif_len] = self._motifs[w]
            out[i] = seq
        return {"tokens": out}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1
