"""E8/E9 — roofline tables from the dry-run artifacts (results/dryrun/).

Reads the JSON artifacts produced by ``python -m repro.launch.dryrun`` —
never recompiles.  Emits the per-cell three-term roofline table used by
EXPERIMENTS.md §Roofline."""

from __future__ import annotations

import json
import pathlib

from repro.analysis.roofline import format_table, roofline_from_artifacts
from .common import save

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_artifacts(tag: str = ""):
    arts = []
    for p in sorted(DRYRUN.glob("*.json")):
        a = json.loads(p.read_text())
        if a.get("tag", "") != tag:
            continue
        arts.append(a)
    return arts


def run(fast: bool = True, tag: str = "") -> dict:
    arts = load_artifacts(tag)
    if not arts:
        print("no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first")
        return {}
    rows, skipped = [], []
    for a in arts:
        if "skipped" in a:
            skipped.append(a)
            continue
        rows.append(roofline_from_artifacts(a))
    rows.sort(key=lambda r: (r.mesh, r.arch, r.shape))
    print(format_table(rows, title="E9 — roofline terms per (arch x shape "
                                   "x mesh), from compiled dry-run"))
    print(f"\nskipped cells (rule): {len(skipped)}")
    for a in skipped:
        print(f"  {a['arch']} x {a['shape']} x {a['mesh']}: {a['skipped']}")
    save("roofline" + (f"_{tag}" if tag else ""),
         {"rows": [r.to_dict() for r in rows],
          "skipped": [{k: a[k] for k in ("arch", "shape", "mesh", "skipped")}
                      for a in skipped]})
    return {"n": len(rows)}


if __name__ == "__main__":
    run()
