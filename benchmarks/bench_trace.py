"""E18 — end-to-end tracing: zero-cost disabled, bounded sampled overhead,
Perfetto-loadable artifact.

The PR-9 tentpole claim, gated three ways:

  1. ZERO INSTRUMENT CALLS DISABLED — with ``trace_sample=0`` a live
     engine run (admission, router decision, decode ticks, telemetry
     drain) must invoke NO ``Tracer`` method at all.  Every call site
     reads the one module global and takes the byte-identical untraced
     path; this is proven by monkeypatch-counting ``Tracer.root`` /
     ``span`` / ``begin`` over a full ``generate``, same technique as
     E15's registry-instrument gate.

  2. SAMPLED OVERHEAD — at 1% sampling the median decode-tick wall time
     must stay within 2% of the untraced engine (budget widened by 2x the
     box's own A/A noise floor, measured from the quiet blocks of each
     quiet/traced/quiet triplet — E15's drift-cancelling methodology).

  3. ARTIFACT — a fully-traced run (sample=1.0, tunedb + router +
     measure) exports Chrome trace-event JSON to ``results/bench/`` that
     parses, carries schema v1, and contains the linked span taxonomy a
     Perfetto view needs: router decision, decode tick, dispatch tier
     resolution (with tier attribute), and a measurement.  CI uploads it.
"""

from __future__ import annotations

import json
import shutil
import statistics
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tuner import clear_tuners
from repro.kernels import dispatch
from repro.models import ModelConfig, init_params
from repro.serve import Engine, ServeConfig
from repro.tunedb import (RecordStore, TuneRecord, clear_store,
                          clear_telemetry)
from repro.tunedb.model import clear_models
from repro.tunedb.obs.trace import Tracer, enable_tracing, reset_tracing

from .common import RESULTS, save, table

OVERHEAD_THRESHOLD = 0.02       # <= 2% median tick overhead at 1% sampling
SAMPLE_RATE = 0.01
ARTIFACT = "trace_E18.json"
CFG = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
       "order": 0, "acc32": 1, "prefetch": 2}


def _reset() -> None:
    reset_tracing()
    clear_tuners()
    clear_store()
    clear_models()
    clear_telemetry()
    dispatch.reset_fallback_warnings()


def _small_engine(tmp: Path, **serve_kw) -> Engine:
    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=2, n_kv=1,
                      d_ff=64, vocab=64, dtype=jnp.float32, attn_chunk=16,
                      logit_chunk=16, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, ServeConfig(max_len=64, slots=2, **serve_kw))


def _prompts(n: int = 2, length: int = 6):
    rng = np.random.default_rng(0)
    return [rng.integers(0, 64, length) for _ in range(n)]


# ---------------------------------------------------------------------------
# 1. tracing disabled: zero Tracer calls over a live engine run
# ---------------------------------------------------------------------------

def _bench_disabled(tmp: Path) -> dict:
    _reset()
    eng = _small_engine(tmp, router="round_robin", record_tick_times=True,
                        trace_sample=0.0)
    eng.generate(_prompts(), max_new=8)         # warm: compile both paths

    calls = 0

    def _counting(orig):
        def wrapped(self, *a, **kw):
            nonlocal calls
            calls += 1
            return orig(self, *a, **kw)
        return wrapped

    patched = ["root", "span", "begin"]
    originals = [(name, getattr(Tracer, name)) for name in patched]
    try:
        for name, orig in originals:
            setattr(Tracer, name, _counting(orig))
        eng.generate(_prompts(4), max_new=16)
    finally:
        for name, orig in originals:
            setattr(Tracer, name, orig)

    ticks = eng.ticks
    print(f"E18.1 — tracing disabled: {calls} Tracer calls over "
          f"{ticks} decode ticks (gate: 0)")
    return {"instrument_calls": calls, "ticks": ticks,
            "pass": calls == 0}


# ---------------------------------------------------------------------------
# 2. median tick overhead at 1% sampling (quiet/traced/quiet triplets)
# ---------------------------------------------------------------------------

def _bench_overhead(fast: bool, tmp: Path) -> dict:
    _reset()
    eng = _small_engine(tmp, record_tick_times=True, trace_sample=0.0)
    n_prompts, max_new = (3, 24) if fast else (6, 48)
    repeats = 9 if fast else 21

    def block(traced: bool) -> float:
        """Median per-tick wall seconds for one generate run."""
        if traced:
            eng.tracer = enable_tracing(SAMPLE_RATE)
        else:
            reset_tracing()
            eng.tracer = None
        eng.tick_times.clear()
        eng.generate(_prompts(n_prompts), max_new=max_new)
        return statistics.median(w for _t0, w, _c in eng.tick_times)

    block(False)                            # warm both compiled paths
    block(True)
    ratios, aa = [], []
    quiet_best = traced_best = float("inf")
    # quiet/traced/quiet: the centered ratio cancels linear machine-load
    # drift; the quiet pair gives the A/A noise floor the budget widens by
    for _ in range(repeats):
        q1, s, q2 = block(False), block(True), block(False)
        ratios.append(2.0 * s / (q1 + q2))
        aa.append(abs(q2 / q1 - 1.0))
        quiet_best = min(quiet_best, q1, q2)
        traced_best = min(traced_best, s)
    reset_tracing()
    overhead = sorted(ratios)[len(ratios) // 2] - 1.0
    noise = sorted(aa)[len(aa) // 2]
    budget = OVERHEAD_THRESHOLD + 2.0 * noise

    rows = [
        {"engine loop": "untraced", "us/tick": f"{quiet_best*1e6:.0f}"},
        {"engine loop": f"traced @ {SAMPLE_RATE:.0%} sampling",
         "us/tick": f"{traced_best*1e6:.0f}"},
    ]
    print(table(rows, ["engine loop", "us/tick"],
                "E18.2 — decode tick cost under sampled tracing"))
    print(f"\nsampled-tracing overhead {overhead:+.2%} "
          f"(gate <= {OVERHEAD_THRESHOLD:.0%} + 2x the {noise:.2%} A/A "
          f"noise floor = {budget:.2%}) over {repeats} triplets")
    return {"quiet_us": quiet_best * 1e6, "traced_us": traced_best * 1e6,
            "overhead": overhead, "noise": noise, "budget": budget,
            "sample": SAMPLE_RATE, "repeats": repeats,
            "threshold": OVERHEAD_THRESHOLD,
            "pass": overhead <= budget}


# ---------------------------------------------------------------------------
# 3. the Perfetto artifact: fully-traced run, exported + validated
# ---------------------------------------------------------------------------

REQUIRED_SPANS = ("request.route", "engine.admit", "engine.tick",
                  "dispatch.resolve")


def _bench_artifact(tmp: Path) -> dict:
    _reset()
    db = tmp / "store.jsonl"
    store = RecordStore.open(db)
    from repro.core.space import gemm_input
    store.add(TuneRecord(space="gemm", inputs=gemm_input(512, 16, 2048),
                         config=dict(CFG), tflops=100.0, backend="bench",
                         source="tuner", created_at=time.time()))
    eng = _small_engine(tmp, tunedb=str(db), router="round_robin",
                        trace_sample=1.0, measure="sim")
    eng.generate(_prompts(3), max_new=12)

    out = RESULTS / ARTIFACT
    RESULTS.mkdir(parents=True, exist_ok=True)
    n = eng.tracer.export(out)
    reset_tracing()

    doc = json.loads(out.read_text())       # must parse — Perfetto will
    evs = doc.get("traceEvents", [])
    names = {e.get("name") for e in evs}
    ids = {e["args"]["span_id"] for e in evs}
    well_formed = all(e.get("ph") == "X" and "ts" in e and "dur" in e
                      and "trace_id" in e.get("args", {}) for e in evs)
    linked = sum(1 for e in evs if e["args"]["parent_id"] in ids)
    missing = [s for s in REQUIRED_SPANS if s not in names]
    has_measure = any(str(s).startswith("measure.") for s in names)
    tiers = {e["args"].get("tier") for e in evs
             if e.get("name") == "dispatch.resolve"}
    ok = (n > 0 and well_formed and not missing and has_measure
          and doc.get("otherData", {}).get("schema") == 1
          and None not in tiers and linked > 0)
    print(f"E18.3 — artifact {out.name}: {n} spans, "
          f"{linked} parent-linked, tiers {sorted(tiers)}, "
          f"span names {sorted(names)} "
          f"({'OK' if ok else 'MISSING ' + ','.join(missing)})")
    return {"artifact": str(out), "spans": n, "linked": linked,
            "well_formed": well_formed, "names": sorted(names),
            "tiers": sorted(t for t in tiers if t is not None),
            "missing": missing, "has_measure": has_measure,
            "pass": bool(ok)}


def run(fast: bool = True) -> dict:
    tmp = Path(tempfile.mkdtemp(prefix="bench_trace_"))
    try:
        disabled = _bench_disabled(tmp)
        overhead = _bench_overhead(fast, tmp)
        artifact = _bench_artifact(tmp)
    finally:
        _reset()
        shutil.rmtree(tmp, ignore_errors=True)
    out = {"disabled": disabled, "overhead": overhead,
           "artifact": artifact,
           "pass": bool(disabled["pass"] and overhead["pass"]
                        and artifact["pass"])}
    save("trace", out)
    print(f"\nE18 verdict: {'PASS' if out['pass'] else 'FAIL'}")
    return out


if __name__ == "__main__":
    run()
