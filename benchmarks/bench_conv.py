"""E5 — paper Table 5 / Figs 9-11: the CONV evaluation suite (DeepBench
subset spanning DeepSpeech / OCR / Face Recognition / Vision / Speaker ID /
ResNet)."""

from __future__ import annotations

import numpy as np

from repro.core.backend import SimulatedTPUBackend
from repro.core.heuristics import VendorHeuristicLibrary
from repro.core.space import CONV_SPACE, conv_input
from .common import get_trained_tuner, save, table

# paper Table 5: (N, P(H), Q(W), K, C, R, S, name)
TABLE5 = [
    (16, 79, 341, 32, 1, 5, 20, "Conv1-DeepSpeech"),
    (16, 38, 166, 32, 32, 5, 10, "Conv2-DeepSpeech"),
    (16, 24, 240, 32, 16, 3, 3, "Conv3-OCR"),
    (16, 12, 120, 64, 32, 3, 3, "Conv4-OCR"),
    (8, 54, 54, 64, 64, 3, 3, "Conv5-Face"),
    (8, 27, 27, 128, 128, 3, 3, "Conv6-Face"),
    (16, 14, 14, 48, 512, 5, 5, "Conv7-Face"),
    (16, 7, 7, 128, 832, 5, 5, "Conv8-Face"),
    (8, 112, 112, 128, 64, 3, 3, "Conv9-Vision"),
    (8, 56, 56, 256, 128, 3, 3, "Conv10-Vision"),
    (16, 128, 39, 174, 64, 5, 5, "Conv11-Speaker"),
    (16, 256, 19, 87, 128, 5, 5, "Conv12-Speaker"),
    (16, 7, 7, 512, 512, 3, 3, "Conv13-ResNET"),
    (16, 7, 7, 2048, 1024, 1, 1, "Conv14-ResNET"),
]


def run(fast: bool = True, dtype_bits: int = 16) -> dict:
    be = SimulatedTPUBackend(noise=0.0)
    tuner = get_trained_tuner("conv", fast=fast)
    vendor = VendorHeuristicLibrary.conv(CONV_SPACE)

    rows, speedups = [], []
    for n, h, w, k, c, r, s, name in TABLE5:
        inputs = conv_input(n, h, w, c, k, r, s, dtype_bits=dtype_bits)
        meas = lambda cfg: be.measure("conv", cfg, inputs)
        v = be.measure("conv", vendor.select(inputs), inputs)
        _, bk = vendor.best_kernel(inputs, meas)
        res = tuner.search(inputs)
        ours = be.measure("conv", res.best, inputs)
        speedups.append(ours / v)
        rows.append({"conv": name, "NPQ": n * h * w, "CRS": c * r * s,
                     "vendor": f"{v:.1f}", "best-kernel": f"{bk:.1f}",
                     "isaac": f"{ours:.1f}",
                     "vs vendor": f"{ours / v:.2f}x"})

    dt = {16: "bf16", 32: "fp32"}[dtype_bits]
    print(table(rows, ["conv", "NPQ", "CRS", "vendor", "best-kernel",
                       "isaac", "vs vendor"],
                f"E5 / Table 5 + Fig 9-11 — CONV TFLOPS ({dt}, "
                f"simulated TPU v5e)"))
    print(f"\ngeo-mean speedup vs vendor heuristic: "
          f"{np.exp(np.mean(np.log(speedups))):.2f}x")
    save(f"conv_{dt}", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    run()
