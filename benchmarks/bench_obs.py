"""E15 — serving observability: metrics overhead, sentry, status endpoint.

The PR-6 tentpole claim, gated three ways:

  1. OVERHEAD — steady-state plan-probe resolution (the E14 hot set:
     exact hits + nearest-promoted novel shapes) with the metrics
     registry live AND an adversarial background scraper hammering
     ``render_prometheus`` must cost <= 2% over the same loop with no
     scraper.  The registry is pull-model — tier counts are derived at
     scrape time from counters dispatch already maintains — so the hot
     path executes the same bytecode either way; the gate catches any
     future "just one counter on the hot path" regression.

  2. SENTRY — an injected regressed record (same key, newer, -50%
     TFLOPS) must be flagged by ``check_supersessions``, must make
     ``install_serving(sentry=...)`` refuse the swap (generation
     unchanged), and must drive ``tunedb diff <old> <new>`` to a
     non-zero exit.

  3. ENDPOINT — a live StatusServer must answer /metrics (Prometheus
     text with the serving-generation gauge) and /status (JSON carrying
     per-tier counts, telemetry and plan metadata); the /status document
     is saved under results/bench/ so CI uploads it as an artifact.
"""

from __future__ import annotations

import json
import tempfile
import threading
import time
import urllib.request
import warnings

from repro.core.space import gemm_input
from repro.core.tuner import clear_tuners
from repro.kernels import dispatch
from repro.tunedb import (RecordStore, TuneRecord, clear_store,
                          clear_telemetry, get_telemetry, install_serving)
from repro.tunedb.model import clear_models
from repro.tunedb.obs import (RegressionSentry, StatusServer, get_registry,
                              reset_metrics)

from .common import RESULTS, save, table

OVERHEAD_THRESHOLD = 0.02       # scraped-vs-quiet plan-probe cost ratio - 1
# a real Prometheus pull lands every 15s; 250ms is 60x that.  The gate
# compares best-block times — per-call instrumentation sneaking onto the
# hot path slows EVERY block and trips it, while the discrete GIL slice a
# concurrent scrape steals from an unlucky block does not (that cost is
# reported separately as us/scrape against the real pull cadence)
SCRAPE_INTERVAL_S = 0.25

CFG = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
       "order": 0, "acc32": 1, "prefetch": 2}


def _hot_serving_state():
    """The E14 serving reality: 8 tuned shapes + 8 nearest-served ones."""
    store = RecordStore()
    tuned = [gemm_input(256 * (i + 1), 64, 1024) for i in range(8)]
    for inputs in tuned:
        store.add(TuneRecord(space="gemm", inputs=inputs, config=CFG,
                             tflops=100.0, backend="sim"))
    novel = [gemm_input(256 * (i + 1) + 48, 64, 1024) for i in range(8)]
    hot = tuned + novel
    tel = get_telemetry()
    for inputs in hot:
        tel.record("gemm", inputs, n=10)
    install_serving(store=store)
    return hot


def _block_time(fn, iters: int) -> float:
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


# ---------------------------------------------------------------------------
# 1. metrics-on overhead over the E14 plan-probe path
# ---------------------------------------------------------------------------

def _bench_overhead(fast: bool) -> dict:
    hot = _hot_serving_state()
    # many short triplet blocks: the median ratio then has enough
    # samples to hold steady under ambient machine noise
    iters = 600 if fast else 3000
    repeats = 15

    def resolve_hot_set():
        for inputs in hot:
            dispatch._tuned_cfg("gemm", inputs)

    # one scraper thread for the whole study, gated by an Event so quiet
    # and scraped blocks can INTERLEAVE — clock-speed / machine-load drift
    # then lands on both sides of the ratio instead of biasing one
    active, stop = threading.Event(), threading.Event()
    scrapes = 0

    def scraper():
        nonlocal scrapes
        reg = get_registry()
        while not stop.is_set():
            if not active.wait(timeout=0.2):
                continue
            reg.render_prometheus()
            scrapes += 1
            time.sleep(SCRAPE_INTERVAL_S)

    def timed(scraping: bool) -> float:
        (active.set if scraping else active.clear)()
        return _block_time(resolve_hot_set, iters)

    th = threading.Thread(target=scraper, daemon=True)
    th.start()
    resolve_hot_set()                    # warm: promotes novel into the plan
    ratios, aa = [], []
    quiet_best = scraped_best = float("inf")
    try:
        # quiet / scraped / quiet triplets: the centered ratio cancels
        # linear machine-load drift, and the two quiet blocks of each
        # triplet give an A/A measurement of the box's OWN noise floor —
        # the gate budget widens by it, so a loaded CI machine doesn't
        # flake the gate while a genuine per-call regression (which
        # inflates every triplet's ratio alike) still trips it
        for _ in range(repeats):
            q1, s, q2 = timed(False), timed(True), timed(False)
            ratios.append(2.0 * s / (q1 + q2))
            aa.append(abs(q2 / q1 - 1.0))
            quiet_best = min(quiet_best, q1, q2)
            scraped_best = min(scraped_best, s)
    finally:
        stop.set()
        active.set()
        th.join(5)
    t_quiet = quiet_best / len(hot)
    t_scraped = scraped_best / len(hot)
    overhead = sorted(ratios)[len(ratios) // 2] - 1.0
    noise = sorted(aa)[len(aa) // 2]
    budget = OVERHEAD_THRESHOLD + 2.0 * noise

    # the deterministic half of the gate: the pull-model claim itself.
    # NO registry instrument may fire while the hot set resolves — any
    # per-call inc()/set()/observe() is a regression regardless of what
    # the clock says.
    from repro.tunedb.obs import metrics as _metrics
    calls = 0

    def _counting(orig):
        def wrapped(self, *a, **kw):
            nonlocal calls
            calls += 1
            return orig(self, *a, **kw)
        return wrapped

    patched = [(_metrics.Counter, "inc"), (_metrics.Gauge, "set"),
               (_metrics.Histogram, "observe")]
    originals = [(c, n, getattr(c, n)) for c, n in patched]
    try:
        for cls, name, orig in originals:
            setattr(cls, name, _counting(orig))
        resolve_hot_set()
    finally:
        for cls, name, orig in originals:
            setattr(cls, name, orig)
    instrument_calls = calls

    # the out-of-band cost a real puller pays, for the record
    reg = get_registry()
    scrape_s = _block_time(reg.render_prometheus, 50)

    rows = [
        {"path": "plan probe, no scraper", "us/call": f"{t_quiet*1e6:.2f}"},
        {"path": f"plan probe + {SCRAPE_INTERVAL_S*1e3:.0f}ms scrape loop",
         "us/call": f"{t_scraped*1e6:.2f}"},
    ]
    print(table(rows, ["path", "us/call"],
                "E15 — dispatch cost under live metrics scraping"))
    print(f"\nmetrics-on overhead {overhead:+.2%} over {scrapes} scrapes "
          f"(gate <= {OVERHEAD_THRESHOLD:.0%} + 2x the {noise:.2%} A/A "
          f"noise floor = {budget:.2%}); {instrument_calls} instrument "
          f"calls on the hot path (gate: 0).  One exposition render costs "
          f"{scrape_s*1e6:.0f}us ({scrape_s/15.0:.5%} of a 15s pull "
          f"cadence)")
    return {"quiet_us": t_quiet * 1e6, "scraped_us": t_scraped * 1e6,
            "overhead": overhead, "noise": noise, "budget": budget,
            "scrapes": scrapes, "scrape_us": scrape_s * 1e6,
            "instrument_calls": instrument_calls,
            "threshold": OVERHEAD_THRESHOLD,
            "pass": overhead <= budget and instrument_calls == 0}


# ---------------------------------------------------------------------------
# 2. the regression sentry catches an injected regression
# ---------------------------------------------------------------------------

def _bench_sentry(tmp) -> dict:
    from repro.tunedb.__main__ import main as tunedb_main

    live = RecordStore(tmp / "live.jsonl")
    live.add(TuneRecord(space="gemm", inputs=gemm_input(512, 16, 2048),
                        config=CFG, tflops=80.0, backend="sim"))
    st1 = install_serving(store=live)

    # the injection: a newer record for the same key, half the throughput
    live.add(TuneRecord(space="gemm", inputs=gemm_input(512, 16, 2048),
                        config=dict(CFG, bm=128), tflops=40.0, backend="sim"))
    sentry = RegressionSentry(noise_margin=0.10)
    report = sentry.check_supersessions(
        live, since_version=st1.plan.store_version)
    flagged = (not report.ok) and len(report.regressions) == 1

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        st2 = install_serving(store=live, sentry=sentry)
    refused = st2.generation == st1.generation

    # the CLI path diffs two pristine generations of the same key
    old = RecordStore(tmp / "old.jsonl")
    old.add(TuneRecord(space="gemm", inputs=gemm_input(512, 16, 2048),
                       config=CFG, tflops=80.0, backend="sim"))
    new = RecordStore(tmp / "new.jsonl")
    new.add(TuneRecord(space="gemm", inputs=gemm_input(512, 16, 2048),
                       config=dict(CFG, bm=128), tflops=40.0, backend="sim"))
    cli_exit = tunedb_main(["diff", str(tmp / "old.jsonl"),
                            str(tmp / "new.jsonl")])

    drop = report.regressions[0].drop if report.regressions else 0.0
    print(f"\nsentry: injected -{drop:.0%} regression "
          f"{'flagged' if flagged else 'MISSED'}, serving swap "
          f"{'refused' if refused else 'PROMOTED (FAIL)'}, "
          f"`tunedb diff` exit {cli_exit} (want 1)")
    return {"flagged": flagged, "refused": refused, "drop": drop,
            "diff_exit": cli_exit,
            "pass": flagged and refused and cli_exit == 1}


# ---------------------------------------------------------------------------
# 3. status endpoint round-trip + CI snapshot artifact
# ---------------------------------------------------------------------------

def _bench_endpoint() -> dict:
    server = StatusServer(port=0).start()
    try:
        with urllib.request.urlopen(server.url + "/metrics",
                                    timeout=10) as resp:
            metrics = resp.read().decode()
        with urllib.request.urlopen(server.url + "/status",
                                    timeout=10) as resp:
            status = json.loads(resp.read().decode())
    finally:
        server.stop()
    ok = ("tunedb_serving_generation" in metrics
          and status.get("schema") == 1
          and "tiers" in status and "telemetry" in status
          and status["serving"]["plan"] is not None)

    RESULTS.mkdir(parents=True, exist_ok=True)
    snap_path = RESULTS / "obs_status_snapshot.json"
    snap_path.write_text(json.dumps(status, indent=1, sort_keys=True,
                                    default=str))
    gen = status["serving"]["generation"]
    print(f"\nendpoint: /metrics {len(metrics.splitlines())} lines, "
          f"/status generation {gen} "
          f"({'PASS' if ok else 'FAIL'}); snapshot -> {snap_path}")
    return {"metrics_lines": len(metrics.splitlines()),
            "generation": gen, "snapshot": str(snap_path), "pass": ok}


def run(fast: bool = True) -> dict:
    clear_tuners()
    clear_store()
    clear_models()
    clear_telemetry()
    dispatch.reset_fallback_warnings()
    reset_metrics()

    overhead = _bench_overhead(fast)
    # endpoint scrapes the hot serving state the overhead section installed
    endpoint = _bench_endpoint()
    clear_store()
    clear_telemetry()
    with tempfile.TemporaryDirectory() as td:
        import pathlib
        sentry = _bench_sentry(pathlib.Path(td))

    ok = overhead["pass"] and sentry["pass"] and endpoint["pass"]
    print(f"\nacceptance: overhead "
          f"{'PASS' if overhead['pass'] else 'FAIL'} "
          f"({overhead['overhead']:+.2%} <= {overhead['budget']:.2%}, "
          f"{overhead['instrument_calls']} hot-path instrument calls), "
          f"sentry {'PASS' if sentry['pass'] else 'FAIL'} "
          f"(diff exit {sentry['diff_exit']}), "
          f"endpoint {'PASS' if endpoint['pass'] else 'FAIL'}")
    payload = {"overhead": overhead, "sentry": sentry,
               "endpoint": endpoint, "pass": ok}
    save("obs", payload)
    clear_store()
    clear_telemetry()
    reset_metrics()
    return payload


if __name__ == "__main__":
    run()
