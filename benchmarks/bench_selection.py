"""E6 — paper Table 6: the parameterizations ISAAC actually selects.

The qualitative claims to reproduce: smaller tiles for smaller problems,
reduction splitting on deep-K problems (ICA), no splitting on square/
outer-product shapes, k_split chosen instead of oversized tiles for
skinny-N DeepBench."""

from __future__ import annotations

from repro.core.space import gemm_input
from .common import get_trained_tuner, save, table

PROBLEMS = [
    ("LINPACK (512)", gemm_input(512, 512, 512, trans_b=True)),
    ("LINPACK (2048)", gemm_input(2048, 2048, 2048, trans_b=True)),
    ("DeepBench-F (16)", gemm_input(2560, 16, 2560)),
    ("DeepBench-F (128)", gemm_input(2560, 128, 2560)),
    ("DeepBench-B (16)", gemm_input(2560, 16, 2560, trans_a=True)),
    ("DeepBench-B (128)", gemm_input(2560, 128, 2560, trans_a=True)),
    ("ICA (32)", gemm_input(32, 32, 60000, trans_b=True)),
    ("ICA (256)", gemm_input(256, 256, 60000, trans_b=True)),
    ("LAPACK (896)", gemm_input(896, 896, 32, trans_b=True)),
    ("LAPACK (4096)", gemm_input(4096, 4096, 32, trans_b=True)),
]


def run(fast: bool = True) -> dict:
    tuner = get_trained_tuner("gemm", fast=fast)
    rows = []
    for name, inputs in PROBLEMS:
        cfg = tuner.best_config(inputs)
        rows.append({"problem": name, **{k: cfg[k] for k in
                                         ("bm", "bn", "bk", "k_unroll",
                                          "k_split", "prefetch")}})
    print(table(rows, ["problem", "bm", "bn", "bk", "k_unroll", "k_split",
                       "prefetch"],
                "E6 / Table 6 — parameterizations selected by the tuner"))
    # qualitative checks (mirrors §8.2's reading of Table 6)
    by = {r["problem"]: r for r in rows}
    checks = {
        "deep-K splits (ICA 32)": by["ICA (32)"]["k_split"] > 1,
        "square does not split (LINPACK 2048)":
            by["LINPACK (2048)"]["k_split"] == 1,
        "outer-product does not split (LAPACK 4096)":
            by["LAPACK (4096)"]["k_split"] == 1,
        "small problems use smaller tiles":
            by["LINPACK (512)"]["bm"] * by["LINPACK (512)"]["bn"]
            <= by["LINPACK (2048)"]["bm"] * by["LINPACK (2048)"]["bn"],
        "skinny-N picks small bn":
            by["DeepBench-F (16)"]["bn"] == 128,
    }
    print()
    for k, v in checks.items():
        print(f"  [{'ok' if v else 'MISS'}] {k}")
    save("selection", {"rows": rows,
                       "checks": {k: bool(v) for k, v in checks.items()}})
    return {"rows": rows, "checks": checks}


if __name__ == "__main__":
    run()
