"""E7 — kernel harness: every Pallas kernel validated (interpret mode)
against its ref.py oracle on tuner-selected configurations, plus the
wall-clock end-to-end path on the host backend."""

from __future__ import annotations

import time

from repro.core.backend import InterpretBackend, WallClockBackend
from repro.core.space import conv_input, gemm_input
from .common import get_trained_tuner, save, table


CASES = {
    "gemm": [gemm_input(256, 256, 512), gemm_input(512, 16, 1024),
             gemm_input(64, 64, 4096)],
    "conv": [conv_input(2, 12, 12, 32, 64, 3, 3),
             conv_input(2, 8, 8, 64, 128, 1, 1)],
    "attention": [
        {"B": 2, "Hq": 4, "Hkv": 2, "Lq": 256, "Lkv": 256, "D": 64,
         "dtype_bits": 16, "causal": 1},
    ],
    "ssd": [{"B": 2, "L": 256, "H": 4, "P": 32, "S": 32, "dtype_bits": 32}],
}


def run(fast: bool = True) -> dict:
    interp = InterpretBackend()
    rows = []
    for space, inputs_list in CASES.items():
        tuner = get_trained_tuner(space, fast=True) if space == "gemm" \
            else None
        for inputs in inputs_list:
            if tuner is not None:
                cfg = tuner.best_config(inputs, remeasure=False)
            else:
                from repro.kernels.ops import (DEFAULT_ATTN, DEFAULT_CONV,
                                               DEFAULT_GEMM, DEFAULT_SSD)
                cfg = {"gemm": DEFAULT_GEMM, "conv": DEFAULT_CONV,
                       "attention": DEFAULT_ATTN, "ssd": DEFAULT_SSD}[space]
            t0 = time.time()
            tput = interp.measure(space, cfg, inputs)   # raises on mismatch
            rows.append({"kernel": space, "inputs": str(inputs)[:48],
                         "config": str({k: cfg[k] for k in list(cfg)[:4]}),
                         "allclose": "pass",
                         "sim TFLOPS": f"{tput_fmt(tput)}",
                         "check_s": f"{time.time()-t0:.1f}"})
    print(table(rows, ["kernel", "inputs", "config", "allclose",
                       "sim TFLOPS", "check_s"],
                "E7 — Pallas kernels vs jnp oracles (interpret mode)"))

    # wall-clock path: real timed executions on the host backend
    wc = WallClockBackend()
    inputs = gemm_input(512, 512, 512, dtype_bits=32)
    t = wc.measure("gemm", {"k_split": 1}, inputs)
    t4 = wc.measure("gemm", {"k_split": 4}, inputs)
    print(f"\nwall-clock (host XLA) 512^3 fp32: k_split=1 {t:.3f} TFLOPS, "
          f"k_split=4 {t4:.3f} TFLOPS")
    save("kernels", {"rows": rows})
    return {"rows": rows}


def tput_fmt(x: float) -> str:
    return f"{x:.1f}"


if __name__ == "__main__":
    run()
