"""E2/E3 — paper Table 2 (MLP architecture sweep, +/- log transform) and
Fig. 5 (cross-validation MSE vs dataset size)."""

from __future__ import annotations

import jax
from repro.core.backend import SimulatedTPUBackend
from repro.core.dataset import generate_dataset
from repro.core.features import Featurizer, target_transform
from repro.core.mlp import MLP, TABLE2_ARCHS
from repro.core.space import GEMM_SPACE
from .common import save, table


def _fit_mse(ds_tr, ds_val, hidden, log, epochs, seed=0):
    f = Featurizer(GEMM_SPACE, log=log)
    X_raw = f.raw_batch(list(zip(ds_tr.inputs, ds_tr.configs)))
    f.fit(X_raw)
    X, y = f.transform(X_raw), target_transform(ds_tr.tflops)
    Xv = f.transform(f.raw_batch(list(zip(ds_val.inputs, ds_val.configs))))
    yv = target_transform(ds_val.tflops)
    m = MLP.create(jax.random.PRNGKey(seed), f.dim, hidden=hidden)
    m.fit(X, y, epochs=epochs, verbose=False)
    return m.mse(Xv, yv)


def run(fast: bool = True) -> dict:
    n = 20000 if fast else 200000
    epochs = 25 if fast else 60
    ds, _ = generate_dataset(GEMM_SPACE, n, seed=0,
                             backend=SimulatedTPUBackend(noise=0.03))
    tr, val = ds.split(val_frac=0.08)

    # -- Table 2: architecture sweep, with and without log features --------
    archs = TABLE2_ARCHS if not fast else TABLE2_ARCHS[:5]
    rows = []
    for hidden in archs:
        mse_log = _fit_mse(tr, val, hidden, True, epochs)
        mse_raw = (_fit_mse(tr, val, hidden, False, epochs)
                   if len(hidden) <= 3 else None)   # paper leaves '-' too
        nw = sum(a * b for a, b in zip(
            (val.featurize()[0].dim,) + hidden, hidden + (1,)))
        rows.append({"hidden layers": str(list(hidden)),
                     "#weights": f"{nw/1e3:.0f}k",
                     "MSE (log)": f"{mse_log:.3f}",
                     "MSE (no log)": ("-" if mse_raw is None
                                      else f"{mse_raw:.3f}")})
    print(table(rows, ["hidden layers", "#weights", "MSE (log)",
                       "MSE (no log)"],
                "E2 / Table 2 — MLP architecture sweep"))

    # -- Fig. 5: MSE vs dataset size ----------------------------------------
    sizes = [1000, 4000, 16000, len(tr)] if fast else \
        [5000, 20000, 50000, 100000, len(tr)]
    curve = []
    for s in sizes:
        mse = _fit_mse(tr.subset(s), val, (64, 128, 64), True, epochs)
        curve.append({"n_train": s, "MSE": f"{mse:.3f}"})
    print()
    print(table(curve, ["n_train", "MSE"],
                "E3 / Fig. 5 — cross-validation MSE vs dataset size"))
    save("mlp", {"table2": rows, "fig5": curve})
    return {"table2": rows, "fig5": curve}


if __name__ == "__main__":
    run()
