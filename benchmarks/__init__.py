"""Benchmark suites (one per paper table/figure) + the CI gate checker."""
