"""E4 — paper Table 4 / Figs 6-8: the GEMM evaluation suite.

Four bars per problem, mirroring the paper's protocol:
  vendor     — handcrafted-heuristic pick from a fixed kernel menu
               (the 'cuBLAS' bar; core/heuristics.py)
  best-kernel— exhaustive search over that same fixed menu
               (the 'cublasGemmEx' bypass bar)
  isaac      — our input-aware tuner (MLP + exhaustive inference + top-k
               re-measurement)
  oracle     — exhaustive search over the FULL space on the backend
               (the '10 hours on hardware' ground truth)

All four are measured on the same simulated-TPU backend, so ratios are
apples-to-apples.  The paper's LINPACK / DeepBench / ICA / LAPACK shape
table is reproduced verbatim (fp16x2 -> bf16-vs-fp32 dtype study included).
"""

from __future__ import annotations

import numpy as np

from repro.core.backend import SimulatedTPUBackend
from repro.core.heuristics import VendorHeuristicLibrary
from repro.core.search import oracle_search
from repro.core.space import GEMM_SPACE, gemm_input
from .common import get_trained_tuner, save, table

# paper Table 4 (M, N, K, trans_a, trans_b, suite)
TABLE4 = [
    (512, 512, 512, 0, 1, "LINPACK"),
    (1024, 1024, 1024, 0, 1, "LINPACK"),
    (2048, 2048, 2048, 0, 1, "LINPACK"),
    (2560, 16, 2560, 0, 0, "DeepBench-F"),
    (2560, 32, 2560, 0, 0, "DeepBench-F"),
    (2560, 64, 2560, 0, 0, "DeepBench-F"),
    (2560, 128, 2560, 0, 0, "DeepBench-F"),
    (2560, 16, 2560, 1, 0, "DeepBench-B"),
    (2560, 32, 2560, 1, 0, "DeepBench-B"),
    (2560, 64, 2560, 1, 0, "DeepBench-B"),
    (2560, 128, 2560, 1, 0, "DeepBench-B"),
    (32, 32, 60000, 0, 1, "ICA"),
    (64, 64, 60000, 0, 1, "ICA"),
    (256, 256, 60000, 0, 1, "ICA"),
    (4096, 4096, 32, 0, 1, "LAPACK"),
    (3456, 3456, 32, 0, 1, "LAPACK"),
    (896, 896, 32, 0, 1, "LAPACK"),
]


def run(fast: bool = True, dtype_bits: int = 16) -> dict:
    be = SimulatedTPUBackend(noise=0.0)       # measurement oracle
    tuner = get_trained_tuner("gemm", fast=fast)
    vendor = VendorHeuristicLibrary.gemm(GEMM_SPACE)
    measure = lambda inputs: (lambda cfg: be.measure("gemm", cfg, inputs))

    rows, speedups, speedups_best = [], [], []
    for m, n, k, ta, tb, suite in TABLE4:
        inputs = gemm_input(m, n, k, dtype_bits=dtype_bits,
                            trans_a=ta, trans_b=tb)
        v_cfg = vendor.select(inputs)
        v = be.measure("gemm", v_cfg, inputs)
        _, bk = vendor.best_kernel(inputs, measure(inputs))
        res = tuner.search(inputs)
        ours = be.measure("gemm", res.best, inputs)
        if fast:
            oracle = max(ours, bk)            # skip the full sweep
            o_str = "-"
        else:
            _, oracle = oracle_search(GEMM_SPACE, inputs, measure(inputs))
            o_str = f"{oracle:.1f}"
        speedups.append(ours / v)
        speedups_best.append(ours / bk)
        rows.append({
            "suite": suite, "M": m, "N": n, "K": k,
            "vendor": f"{v:.1f}", "best-kernel": f"{bk:.1f}",
            "isaac": f"{ours:.1f}", "oracle": o_str,
            "vs vendor": f"{ours / v:.2f}x",
            "vs best": f"{ours / bk:.2f}x"})

    name = {16: "bf16", 32: "fp32"}[dtype_bits]
    print(table(rows, ["suite", "M", "N", "K", "vendor", "best-kernel",
                       "isaac", "oracle", "vs vendor", "vs best"],
                f"E4 / Table 4 + Fig 6-8 — GEMM TFLOPS ({name}, "
                f"simulated TPU v5e)"))
    print(f"\ngeo-mean speedup vs vendor heuristic: "
          f"{np.exp(np.mean(np.log(speedups))):.2f}x ; "
          f"vs vendor best kernel: "
          f"{np.exp(np.mean(np.log(speedups_best))):.2f}x")
    save(f"gemm_{name}", {"rows": rows})
    return {"rows": rows, "geomean_vs_vendor":
            float(np.exp(np.mean(np.log(speedups))))}


if __name__ == "__main__":
    run()
