"""Generate the data-driven sections of EXPERIMENTS.md from dry-run
artifacts + bench results.  ``python -m benchmarks.gen_experiments``"""

from __future__ import annotations

import json
import pathlib

from repro.analysis.roofline import roofline_from_artifacts

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "results" / "dryrun"


def load(tag=""):
    out = []
    for p in sorted(DRYRUN.glob("*.json")):
        a = json.loads(p.read_text())
        if a.get("tag", "") == tag:
            out.append(a)
    return out


def dryrun_table() -> str:
    rows = ["| arch | shape | mesh | FLOPs/dev | peak/dev (meas / bf16-est)"
            " | collective/dev | compile |",
            "|---|---|---|---|---|---|---|"]
    skipped = []
    for a in load():
        if "skipped" in a:
            skipped.append(a)
            continue
        m = a["memory"]
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a['cost']['flops']:.2e} "
            f"| {m['peak_per_device']/2**30:.1f} / "
            f"{m['peak_per_device_bf16_est']/2**30:.1f} GiB "
            f"| {a['collectives']['total']/2**30:.1f} GiB "
            f"| {a['compile_s']:.0f}s |")
    sk = [f"- **{a['arch']} × {a['shape']} × {a['mesh']}** — skipped: "
          f"{a['skipped']}" for a in skipped]
    return "\n".join(rows) + "\n\n**Rule-skipped cells (" + str(len(sk)) + \
        "):**\n" + "\n".join(sk)


def roofline_table() -> str:
    rows = ["| arch | shape | mesh | t_compute | t_memory† | t_collective |"
            " bottleneck | MODEL/HLO | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    rts = []
    for a in load():
        if "skipped" in a:
            continue
        rts.append(roofline_from_artifacts(a))
    rts.sort(key=lambda r: (-r.roofline_fraction))
    for r in rts:
        f = lambda s: f"{s*1e3:,.1f} ms" if s < 10 else f"{s:,.2f} s"
        rows.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {f(r.t_compute)} "
            f"| {f(r.t_memory)} | {f(r.t_collective)} | {r.bottleneck} "
            f"| {r.useful_ratio:.2f} | {r.roofline_fraction:.2%} |")
    return "\n".join(rows)


def perf_compare(arch, shape, mesh, tags):
    rows = ["| config | t_compute | t_memory | t_collective | bottleneck |"
            " roofline frac | coll GiB/dev |",
            "|---|---|---|---|---|---|---|"]
    for tag in tags:
        t = f"--{tag}" if tag else ""
        p = DRYRUN / f"{arch}--{shape}--{mesh}{t}.json"
        if not p.exists():
            continue
        a = json.loads(p.read_text())
        r = roofline_from_artifacts(a)
        f = lambda s: f"{s*1e3:,.1f} ms" if s < 10 else f"{s:,.2f} s"
        rows.append(f"| {tag or 'baseline'} | {f(r.t_compute)} "
                    f"| {f(r.t_memory)} | {f(r.t_collective)} "
                    f"| {r.bottleneck} | {r.roofline_fraction:.2%} "
                    f"| {a['collectives']['total']/2**30:.1f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    print("## §Dry-run table\n")
    print(dryrun_table())
    print("\n\n## §Roofline table\n")
    print(roofline_table())
    print("\n\n## §Perf H1 (dbrx-132b × train_4k × pod)\n")
    print(perf_compare("dbrx-132b", "train_4k", "pod",
                       ["", "h1i1", "h1i2", "h1i3", "h1i4"]))
    print("\n\n## §Perf H2 (llama3-405b × decode_32k × multipod)\n")
    print(perf_compare("llama3-405b", "decode_32k", "multipod",
                       ["", "h2i1", "h2i2", "h2i3", "h2i4", "h2i5", "h2i6"]))
    print("\n\n## §Perf H3 (smollm-135m × train_4k × pod)\n")
    print(perf_compare("smollm-135m", "train_4k", "pod",
                       ["", "h3i1", "h3i2", "h3i3", "h3i4", "h3i5", "h3i6",
                        "h3i7"]))
