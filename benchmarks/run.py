"""Benchmark orchestrator: ``python -m benchmarks.run [--full]``.

One benchmark per paper table/figure (DESIGN.md §8 experiment index):
  E1 sampler   — Table 1        E5 conv      — Table 5 / Fig 9-11
  E2/E3 mlp    — Table 2 / Fig5 E6 selection — Table 6
  E4 gemm      — Table 4 / Fig 6-8 (bf16 + fp32 dtype study)
  E7 kernels   — §3 correctness harness
  E9 roofline  — from dry-run artifacts (run launch.dryrun first)
  E10 tunedb   — record-store lookup overhead on the dispatch hot path
  E11 model    — model-guided dispatch: quality vs oracle + overhead
  E12 retune   — continuous retuning: traffic shift -> session -> hot-swap
  E13 fleet    — distributed tuning: 4-worker throughput + merge equivalence
  E14 dispatch — frozen dispatch plans: plan vs PR-4 resolution, indexed
                 nearest lookup, store-aware admission TFLOPS lift
  E15 obs      — serving observability: metrics-on dispatch overhead,
                 regression sentry, /metrics + /status endpoint snapshot
  E16 plans    — golden plan artifacts: cold-start-from-artifact resolution
                 parity, 3-replica plan-following fleet (no torn/stale reads)
  E17 router   — fleet-global telemetry + shape-affinity routing: affinity
                 vs round-robin TFLOPS/hit-rate, fleet-only retune trigger
  E18 trace    — end-to-end tracing: zero instrument calls disabled,
                 <=2% tick overhead at 1% sampling, Perfetto artifact
  E19 chaos    — deterministic fault injection: zero shim calls disarmed,
                 SIGKILL-safe store, fleet + plan followers under a seeded
                 FaultPlan (no lost acks, no torn/stale installs), serving
                 stays up under armed chaos

Gate validation: ``python -m benchmarks.check_gates`` after a run.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true",
                   help="paper-scale dataset sizes (hours)")
    p.add_argument("--only", default=None,
                   help="comma-separated subset, e.g. gemm,conv")
    args = p.parse_args()
    fast = not args.full

    from . import (bench_chaos, bench_conv, bench_dispatch, bench_fleet,
                   bench_gemm, bench_kernels, bench_mlp, bench_model,
                   bench_obs, bench_plans, bench_retune, bench_roofline,
                   bench_router, bench_sampler, bench_selection,
                   bench_trace, bench_tunedb)
    suites = {
        "sampler": lambda: bench_sampler.run(fast),
        "mlp": lambda: bench_mlp.run(fast),
        "gemm": lambda: bench_gemm.run(fast),
        "gemm_fp32": lambda: bench_gemm.run(fast, dtype_bits=32),
        "conv": lambda: bench_conv.run(fast),
        "selection": lambda: bench_selection.run(fast),
        "kernels": lambda: bench_kernels.run(fast),
        "roofline": lambda: bench_roofline.run(fast),
        "tunedb": lambda: bench_tunedb.run(fast),
        "model": lambda: bench_model.run(fast),
        "retune": lambda: bench_retune.run(fast),
        "fleet": lambda: bench_fleet.run(fast),
        "dispatch": lambda: bench_dispatch.run(fast),
        "obs": lambda: bench_obs.run(fast),
        "plans": lambda: bench_plans.run(fast),
        "router": lambda: bench_router.run(fast),
        "trace": lambda: bench_trace.run(fast),
        "chaos": lambda: bench_chaos.run(fast),
    }
    chosen = (args.only.split(",") if args.only else list(suites))
    t_all = time.time()
    for name in chosen:
        print(f"\n{'='*72}\n== {name}\n{'='*72}")
        t0 = time.time()
        suites[name]()
        print(f"[{name} done in {time.time()-t0:.1f}s]")
    print(f"\nall benchmarks done in {time.time()-t_all:.1f}s")


if __name__ == "__main__":
    main()
