"""E17 — fleet-global telemetry + shape-affinity routing.

The PR-8 tentpole claim, gated two ways:

  1. ROUTING — a synthetic 3-replica fleet serves a mixed gemm workload.
     The coordinator partitions the global hot set into per-replica
     affinity classes and publishes one SMALL specialized plan per replica
     (the real ``publish_replica_plans`` -> ``PlanRegistry`` round trip);
     each request then dispatches at the TFLOPS of the config its landing
     replica actually resolves — the tuned record when the replica's plan
     covers the shape, the vendor heuristic config when it does not.  The
     ``ShapeAffinityRouter`` must beat (or match) round-robin on BOTH
     geomean dispatched TFLOPS and plan hit rate, with ZERO starved
     request class and the load bound respected.

  2. FLEET TRIGGER — three replicas each record a window BELOW the retune
     controller's ``min_calls`` floor, so a process-local controller never
     triggers.  Their cumulative dumps aggregate on the bus, and the SAME
     controller reading the ``FleetTelemetryView`` must trigger — the
     retune fires off fleet-wide mass no single replica's window would
     have tripped.
"""

from __future__ import annotations

import math
import random
import shutil
import tempfile
import time
from pathlib import Path

from repro.core.backend import SimulatedTPUBackend
from repro.core.search import enumerate_legal
from repro.core.space import gemm_input
from repro.core.tuner import clear_tuners
from repro.kernels import dispatch
from repro.serve.router import make_router
from repro.tunedb import (RecordStore, TuneRecord, clear_store,
                          clear_telemetry, install_store, shape_key)
from repro.tunedb.controller import RetuneConfig, RetuneController
from repro.tunedb.fleet import Coordinator
from repro.tunedb.model import clear_models
from repro.tunedb.plans import PlanRegistry
from repro.tunedb.session import backend_fingerprint
from repro.tunedb.telemetry import (FleetTelemetryView, ShapeTelemetry,
                                    TelemetryExporter)

from .common import save, table

REPLICAS = 3
POLICIES = ("affinity", "round_robin", "random")


def _reset() -> None:
    clear_tuners()
    clear_store()
    clear_models()
    clear_telemetry()


def _geomean(xs) -> float:
    return math.exp(sum(math.log(x) for x in xs) / len(xs)) if xs else 0.0


# ---------------------------------------------------------------------------
# 1. shape-affinity routing vs the baselines on a mixed workload
# ---------------------------------------------------------------------------

def _tuned_and_heuristic_tflops(backend, inputs, *, sample=48):
    """(best config, its TFLOPS, heuristic TFLOPS) for one gemm shape."""
    from repro.core.space import GEMM_SPACE
    legal = enumerate_legal(GEMM_SPACE, inputs)
    stride = max(1, len(legal) // sample)
    scored = [(float(backend.measure("gemm", cfg, inputs)), cfg)
              for cfg in legal[::stride]]
    best_tf, best_cfg = max(scored, key=lambda p: p[0])
    heur = dispatch._heuristic_cfg("gemm", inputs)
    heur_tf = float(backend.measure("gemm", heur, inputs))
    return best_cfg, best_tf, min(heur_tf, best_tf)


def _bench_routing(fast: bool, tmp: Path) -> dict:
    _reset()
    backend = SimulatedTPUBackend(noise=0.0)
    fp = backend_fingerprint(backend)
    n_classes = 6 if fast else 12
    n_requests = 600 if fast else 2400

    # the hot set: n_classes shape classes in distinct log2 buckets, each
    # tuned into the store with its measured-best config
    store = RecordStore.open(tmp / "store.jsonl")
    classes = []                 # (inputs, tuned_tflops, heuristic_tflops)
    tel = ShapeTelemetry()
    for i in range(n_classes):
        inputs = gemm_input(128 * 2 ** (i % 4) + 128 * i, 64, 1024)
        cfg, best_tf, heur_tf = _tuned_and_heuristic_tflops(backend, inputs)
        store.add(TuneRecord(space="gemm", inputs=inputs, config=cfg,
                             tflops=best_tf, backend=fp))
        classes.append((inputs, best_tf, heur_tf))
        tel.record("gemm", inputs, n=100 - 5 * i)   # skewed hot-shape mass

    # the real specialization path: partition -> per-replica plan registries
    coord = Coordinator(tmp / "fleet", store)
    published = coord.publish_replica_plans(tmp / "registries", REPLICAS,
                                            telemetry=tel, fingerprint=fp)
    plans = []
    for entry in published:
        reg = PlanRegistry(entry["registry"])
        pointer = reg.current()
        plans.append(reg.pull(pointer) if pointer is not None else None)

    # mixed workload: hot classes plus a cold class NO plan covers
    cold = gemm_input(96, 96, 96)
    cold_tf = _tuned_and_heuristic_tflops(backend, cold)[2]
    rng = random.Random(0)
    workload = [rng.randrange(n_classes + 1) for _ in range(n_requests)]

    results = {}
    for policy in POLICIES:
        router = make_router(policy)
        for i, plan in enumerate(plans):
            router.add_replica(f"replica-{i}", plan=plan)
        hits = 0
        tflops = []
        served = [0] * (n_classes + 1)
        t0 = time.perf_counter()
        for cls in workload:
            inputs = classes[cls][0] if cls < n_classes else cold
            replica = router.route([("gemm", inputs)])
            served[cls] += 1
            plan = replica.current_plan()
            covered = plan is not None and \
                plan.lookup("gemm", shape_key(inputs)) is not None
            if covered:
                hits += 1
                tflops.append(classes[cls][1])
            else:
                tflops.append(classes[cls][2] if cls < n_classes
                              else cold_tf)
        route_us = (time.perf_counter() - t0) / n_requests * 1e6
        loads = [r.assigned for r in router.replicas]
        results[policy] = {
            "geomean_tflops": _geomean(tflops),
            "hit_rate": hits / n_requests,
            "starved_classes": sum(1 for n in served if n == 0),
            "load_spread": max(loads) - min(loads),
            "route_us": route_us,
            "outcomes": dict(router.outcomes),
        }

    aff, rr = results["affinity"], results["round_robin"]
    max_imbalance = make_router("affinity").max_imbalance
    rows = [dict({"policy": p},
                 **{"geomean TFLOPS": f"{r['geomean_tflops']:.1f}",
                    "hit rate": f"{r['hit_rate']:.3f}",
                    "starved": r["starved_classes"],
                    "load spread": r["load_spread"],
                    "us/route": f"{r['route_us']:.2f}"})
            for p, r in results.items()]
    print(table(rows, ["policy", "geomean TFLOPS", "hit rate", "starved",
                       "load spread", "us/route"],
                "E17 — shape-affinity routing vs baselines "
                f"({REPLICAS} replicas, {n_requests} requests, "
                f"{n_classes}+1 classes)"))
    print(f"\naffinity/round-robin: TFLOPS x"
          f"{aff['geomean_tflops'] / rr['geomean_tflops']:.2f}, hit rate "
          f"{aff['hit_rate']:.3f} vs {rr['hit_rate']:.3f}; outcomes "
          f"{aff['outcomes']}")
    ok = (aff["geomean_tflops"] >= rr["geomean_tflops"]
          and aff["hit_rate"] >= rr["hit_rate"]
          and aff["starved_classes"] == 0
          and aff["load_spread"] <= max_imbalance + 1)
    _reset()
    return {"policies": results, "replicas": REPLICAS,
            "requests": n_requests, "classes": n_classes + 1,
            "plan_entries": [entry["entries"] for entry in published],
            "tflops_ratio_vs_rr": (aff["geomean_tflops"]
                                   / rr["geomean_tflops"]),
            "hit_rate_affinity": aff["hit_rate"],
            "hit_rate_round_robin": rr["hit_rate"],
            "starved_classes": aff["starved_classes"],
            "pass": bool(ok)}


# ---------------------------------------------------------------------------
# 2. the retune trigger only the aggregated fleet view can trip
# ---------------------------------------------------------------------------

def _bench_fleet_trigger(fast: bool, tmp: Path) -> dict:
    _reset()
    bus = tmp / "telemetry"
    cfg = RetuneConfig(min_calls=32, untuned_mass_threshold=0.5)
    store = RecordStore()
    install_store(store)              # empty store: the window is untuned
    shape = gemm_input(4096, 64, 1024)
    per_replica = 15                  # < min_calls: alone, never triggers

    local = ShapeTelemetry()
    fleet_view = FleetTelemetryView(bus, local=local, refresh_s=0.0)
    ctl_fleet = RetuneController(store, telemetry=fleet_view, cfg=cfg)
    ctl_local = RetuneController(store, telemetry=local, cfg=cfg)

    local.record("gemm", shape, n=per_replica)
    for i in range(REPLICAS - 1):
        tel = ShapeTelemetry()
        tel.record("gemm", shape, n=per_replica)
        TelemetryExporter(tel, bus, worker_id=f"peer{i}").export_once()

    dec_local = ctl_local.check().get("gemm")
    dec_fleet = ctl_fleet.check().get("gemm")
    local_trigger = bool(dec_local and dec_local.trigger)
    fleet_trigger = bool(dec_fleet and dec_fleet.trigger)
    window_local = dec_local.window_calls if dec_local else 0
    window_fleet = dec_fleet.window_calls if dec_fleet else 0

    rows = [
        {"scope": "process (one replica)", "window calls": window_local,
         "min_calls": cfg.min_calls, "trigger": local_trigger},
        {"scope": f"fleet ({REPLICAS} replicas)",
         "window calls": window_fleet, "min_calls": cfg.min_calls,
         "trigger": fleet_trigger},
    ]
    print(table(rows, ["scope", "window calls", "min_calls", "trigger"],
                "E17 — retune trigger off aggregated fleet telemetry"))
    print(f"\n{REPLICAS} replicas x {per_replica} calls: each window sits "
          f"below min_calls={cfg.min_calls}; only the aggregated view "
          f"({window_fleet} calls, scope "
          f"{ctl_fleet.stats()['telemetry_scope']}) trips the controller")
    _reset()
    return {"replicas": REPLICAS, "calls_per_replica": per_replica,
            "min_calls": cfg.min_calls,
            "window_calls_local": window_local,
            "window_calls_fleet": window_fleet,
            "local_trigger": local_trigger, "fleet_trigger": fleet_trigger,
            "pass": bool(fleet_trigger and not local_trigger)}


def run(fast: bool = True) -> dict:
    tmp = Path(tempfile.mkdtemp(prefix="bench_router_"))
    try:
        routing = _bench_routing(fast, tmp)
        trigger = _bench_fleet_trigger(fast, tmp)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    out = {"routing": routing, "fleet_trigger": trigger,
           "pass": bool(routing["pass"] and trigger["pass"])}
    save("router", out)
    print(f"\nE17 verdict: {'PASS' if out['pass'] else 'FAIL'}")
    return out


if __name__ == "__main__":
    run()
