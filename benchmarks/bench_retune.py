"""E12 — continuous retuning: a traffic shift triggers tune+retrain+hot-swap.

The closed-loop claim of PR 3: when serving traffic's hot-shape mass moves to
shapes nobody tuned, the RetuneController must notice (telemetry epoch
drift), tune the novel shapes in-process, retrain the affected regressors,
and atomically hot-swap the serving store/ModelSet — no restart.  Two gates:

  1. QUALITY — after a synthetic traffic shift (a new hot GEMM set absent
     from the store) and one controller pass, dispatch resolution for the
     new hot set must reach >= 90% of the oracle-best TFLOPS (geomean).
     The oracle is an exhaustive noise-free scan per shape; the pre-retune
     resolution (model/nearest tiers trained on yesterday's shapes) is
     reported alongside as the staleness baseline.

  2. OVERHEAD — the controller must be ~free when traffic is steady: the
     per-tick cost it adds to a decode loop (jit tick-telemetry replay +
     an epoch-diff poll every ``retune_interval`` ticks, amortized) must
     stay < 2% of a decode tick.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.backend import SimulatedTPUBackend
from repro.core.search import enumerate_legal
from repro.core.space import GEMM_SPACE, gemm_input
from repro.core.tuner import clear_tuners
from repro.kernels import dispatch
from repro.tunedb import (RecordStore, TuneRecord, clear_store,
                          clear_telemetry, get_telemetry, install_serving,
                          serving_state)
from repro.tunedb.controller import RetuneConfig, RetuneController
from repro.tunedb.model import clear_models, collect_samples, train_models
from repro.tunedb.session import backend_fingerprint

from .common import get_trained_tuner, save, table

QUALITY_THRESHOLD = 0.90        # post-retune fraction of oracle-best TFLOPS
OVERHEAD_THRESHOLD = 0.02       # controller's share of a decode tick
RETUNE_INTERVAL = 64            # ticks between polls (ServeConfig default)

# yesterday's hot set: what the fleet tuned before the shift ...
OLD_HOT = [(m, n, k)
           for m in (256, 1024, 4096)
           for n in (16, 64, 256)
           for k in (512, 2560)]
# ... and where traffic moves: novel shapes with no store record
NEW_HOT = [(384, 48, 1536), (1792, 24, 896), (896, 320, 896),
           (2304, 96, 1152), (576, 160, 2304)]


def _geomean(xs) -> float:
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-9)))))


def _build_store(label: SimulatedTPUBackend, fp: str, topk: int
                 ) -> RecordStore:
    """Tuned best + measured top-k per OLD shape (a past session's output)."""
    store = RecordStore()
    for m, n, k in OLD_HOT:
        inputs = gemm_input(m, n, k)
        scored = sorted(((c, label.measure("gemm", c, inputs))
                         for c in enumerate_legal(GEMM_SPACE, inputs)),
                        key=lambda t: -t[1])
        store.add(TuneRecord(space="gemm", inputs=inputs, config=scored[0][0],
                             tflops=scored[0][1], backend=fp,
                             source="session"))
        for cfg, tf in scored[1:1 + topk]:
            store.add(TuneRecord(space="gemm", inputs=inputs,
                                 config=dict(cfg), tflops=tf, backend=fp,
                                 source="sample"))
    return store


def _resolution_ratios(oracle: SimulatedTPUBackend) -> dict:
    """dispatch._tuned_cfg quality on the NEW hot set vs the oracle best."""
    out = {}
    for m, n, k in NEW_HOT:
        inputs = gemm_input(m, n, k)
        best = max(oracle.measure("gemm", c, inputs)
                   for c in enumerate_legal(GEMM_SPACE, inputs))
        cfg = dispatch._tuned_cfg("gemm", inputs)
        out[(m, n, k)] = (oracle.measure("gemm", cfg, inputs) / best
                          if cfg else 0.0)
    return out


def _overhead(controller: RetuneController, fast: bool) -> dict:
    """Steady-state controller cost against a real jitted decode tick."""
    import jax
    import jax.numpy as jnp

    from repro.models import ModelConfig, init_params
    from repro.serve import Engine, ServeConfig

    cfg = ModelConfig(name="bench", n_layers=2, d_model=128, n_heads=4,
                      n_kv=2, d_ff=256, vocab=128, dtype=jnp.float32,
                      attn_chunk=16, logit_chunk=16, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, ServeConfig(max_len=128, slots=2))
    rng = np.random.default_rng(0)
    engine.generate([rng.integers(0, 128, 8)], max_new=4)   # compile
    n_ticks = 24 if fast else 96
    ticks_before = engine.ticks
    t0 = time.perf_counter()
    engine.generate([rng.integers(0, 128, 8)], max_new=n_ticks)
    t_tick = ((time.perf_counter() - t0)
              / max(engine.ticks - ticks_before, 1))

    # the two costs retuning adds to that tick: the jit tick-telemetry
    # replay, and (amortized) the controller's no-trigger epoch-diff poll
    shapes = engine._decode_shapes or []
    tel = get_telemetry()
    iters = 300 if fast else 2000
    t1 = time.perf_counter()
    for _ in range(iters):
        tel.record_ticks(shapes)
    t_hook = (time.perf_counter() - t1) / iters

    # steady-state polls: the window holds only already-tuned traffic, so
    # every poll runs the full epoch diff + untuned-mass scan and declines
    controller.reset_baseline()
    for m, n, k in OLD_HOT:
        tel.record("gemm", gemm_input(m, n, k), n=5)
    t2 = time.perf_counter()
    for _ in range(iters):
        assert controller.maybe_retune() is None     # steady: no trigger
    t_poll = (time.perf_counter() - t2) / iters

    added = (t_hook + t_poll / RETUNE_INTERVAL) / t_tick
    rows = [
        {"path": "decode tick (jitted, 2L/128d engine)",
         "cost": f"{t_tick*1e3:.2f} ms"},
        {"path": f"tick hook: record_ticks x{len(shapes)} shapes",
         "cost": f"{t_hook*1e6:.1f} us"},
        {"path": f"controller poll (1/{RETUNE_INTERVAL} ticks, no trigger)",
         "cost": f"{t_poll*1e6:.1f} us"},
    ]
    print()
    print(table(rows, ["path", "cost"], "E12 — steady-state controller cost"))
    print(f"\ncontroller adds {added*100:.3f}% of a decode tick "
          f"(gate < {OVERHEAD_THRESHOLD:.0%})")
    return {"tick_ms": t_tick * 1e3, "hook_us": t_hook * 1e6,
            "poll_us": t_poll * 1e6, "interval": RETUNE_INTERVAL,
            "n_decode_shapes": len(shapes), "added_frac": added,
            "threshold": OVERHEAD_THRESHOLD,
            "pass": added < OVERHEAD_THRESHOLD}


def run(fast: bool = True) -> dict:
    clear_tuners()
    clear_store()
    clear_models()
    clear_telemetry()

    label = SimulatedTPUBackend(noise=0.03)
    oracle = SimulatedTPUBackend(noise=0.0)
    fp = backend_fingerprint(label)
    topk, per_shape, epochs = (10, 40, 60) if fast else (20, 100, 150)

    # yesterday: a fleet tuned OLD_HOT, trained regressors, serving installed
    t0 = time.time()
    store = _build_store(label, fp, topk)
    collect_samples(store, label, per_shape=per_shape, seed=0)
    models = train_models(store, epochs=epochs, hidden=(64, 128, 64), seed=0)
    models.measurer = label.measure
    install_serving(store=store, models=models, fingerprint=None)
    print(f"[retune] warm store: {len(store)} shapes, "
          f"{store.n_samples} samples in {time.time()-t0:.1f}s")

    # steady traffic on the old hot set, then the controller opens its epoch
    tel = get_telemetry()
    for m, n, k in OLD_HOT:
        tel.record("gemm", gemm_input(m, n, k), n=20)
    # a wider §6 re-measure pool than the tuner default: the retune session
    # serves these configs as exact hits forever after, so spending a few
    # extra measurements per novel shape buys real post-retune throughput
    import dataclasses
    tuner = dataclasses.replace(get_trained_tuner("gemm", fast=fast),
                                top_k=24)
    controller = RetuneController(
        store, tuners={"gemm": tuner},
        cfg=RetuneConfig(drift_threshold=0.25, untuned_mass_threshold=0.5,
                         min_calls=32, top_k_shapes=len(NEW_HOT),
                         workers=2, remeasure=True, retrain=True,
                         train_epochs=40))
    gen_before = serving_state().generation

    # the shift: traffic moves to NEW_HOT, none of it in the store
    pre = _resolution_ratios(oracle)         # stale tiers serve the new set
    for m, n, k in NEW_HOT:
        tel.record("gemm", gemm_input(m, n, k), n=40)

    decisions = controller.check()
    dec = decisions["gemm"]
    print(f"[retune] shift detected: drift {dec.drift:.3f}, untuned mass "
          f"{dec.untuned_mass:.3f}, {len(dec.novel_shapes)} novel shapes")
    t0 = time.time()
    report = controller.maybe_retune()
    assert report is not None, "traffic shift failed to trigger a retune"
    gen_after = serving_state().generation
    print(f"[retune] epoch {report.epoch}: tuned {report.tuned}, retrained "
          f"{report.retrained}, generation {gen_before} -> {gen_after} "
          f"in {report.wall_s:.1f}s")

    post = _resolution_ratios(oracle)        # exact hits on the fresh records
    rows = [{"shape": f"{m}x{n}x{k}",
             "pre-retune": f"{pre[(m, n, k)]:.3f}",
             "post-retune": f"{post[(m, n, k)]:.3f}"}
            for m, n, k in NEW_HOT]
    g_pre, g_post = _geomean(list(pre.values())), _geomean(list(post.values()))
    print()
    print(table(rows, ["shape", "pre-retune", "post-retune"],
                "E12 — fraction of oracle-best TFLOPS on the shifted hot set"))
    print(f"\ngeomean: pre-retune {g_pre:.3f} -> post-retune {g_post:.3f} "
          f"(gate >= {QUALITY_THRESHOLD})")
    quality = {"geomean": g_post, "geomean_pre": g_pre,
               "min": float(min(post.values())), "rows": rows,
               "threshold": QUALITY_THRESHOLD,
               "pass": g_post >= QUALITY_THRESHOLD}

    overhead = _overhead(controller, fast)

    ok = (quality["pass"] and overhead["pass"]
          and report.tuned > 0 and gen_after > gen_before)
    print(f"\nacceptance: quality {'PASS' if quality['pass'] else 'FAIL'} "
          f"(geomean {g_post:.3f} >= {QUALITY_THRESHOLD}), overhead "
          f"{'PASS' if overhead['pass'] else 'FAIL'} "
          f"({overhead['added_frac']*100:.3f}% < {OVERHEAD_THRESHOLD:.0%})")
    payload = {
        "quality": quality, "overhead": overhead,
        "shift": {"drift": dec.drift, "untuned_mass": dec.untuned_mass,
                  "window_calls": dec.window_calls},
        "retune": {"tuned": report.tuned, "retrained": report.retrained,
                   "generation": gen_after, "wall_s": report.wall_s},
        "pass": ok,
    }
    save("retune", payload)
    clear_store()
    clear_models()
    clear_telemetry()
    return payload


if __name__ == "__main__":
    run()
