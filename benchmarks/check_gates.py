"""Validate every benchmark pass/fail gate in ``results/bench/*.json``.

One place defines what "CI green" means for the performance trajectory:
each known gate names the result file it reads, the field it checks, and a
human-readable statement of the bound.  Any result file carrying a
top-level ``"pass"`` field is additionally held to it, so a new benchmark
that records a verdict is gated without touching this file.

Usage:
  $ python -m benchmarks.run --only tunedb,model
  $ python -m benchmarks.check_gates --require tunedb,model

Exit code 0 iff every required file exists and every gate holds.  CI and
local runs call exactly this — no inline-CI-heredoc drift.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
from typing import Callable, Dict, List, Optional

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "bench"


@dataclasses.dataclass(frozen=True)
class Gate:
    file: str                                 # results/bench/<file>.json
    name: str                                 # what the bound promises
    check: Callable[[dict], bool]
    detail: Callable[[dict], str]             # measured-vs-bound, for the report


def _get(r: dict, *path, default=None):
    for p in path:
        if not isinstance(r, dict) or p not in r:
            return default
        r = r[p]
    return r


GATES: List[Gate] = [
    Gate(
        file="tunedb",
        name="store-lookup overhead < 5% of interpret dispatch",
        check=lambda r: r["overhead_frac"] < 0.05,
        detail=lambda r: f"{r['overhead_frac']:.3%} of dispatch",
    ),
    Gate(
        file="model",
        name="model-guided config >= 90% of oracle TFLOPS (geomean, held-out)",
        check=lambda r: _get(r, "quality", "pass") is True,
        detail=lambda r: (
            f"geomean {_get(r, 'quality', 'geomean', default=0):.3f} "
            f"(threshold {_get(r, 'quality', 'threshold', default=0.9)}, "
            f"nearest-neighbor "
            f"{_get(r, 'quality', 'geomean_nearest', default=0):.3f})"),
    ),
    Gate(
        file="model",
        name="model resolution adds < 10% over nearest-neighbor dispatch",
        check=lambda r: _get(r, "overhead", "pass") is True,
        detail=lambda r: (
            f"adds {_get(r, 'overhead', 'added_frac', default=1):.3%} "
            f"of a dispatch call (cold search "
            f"{_get(r, 'overhead', 'cold_model_ms', default=0):.0f} ms, "
            "paid once per novel shape)"),
    ),
    Gate(
        file="retune",
        name="post-retune dispatch >= 90% of oracle TFLOPS on shifted hot set",
        check=lambda r: _get(r, "quality", "pass") is True,
        detail=lambda r: (
            f"geomean {_get(r, 'quality', 'geomean', default=0):.3f} "
            f"(threshold {_get(r, 'quality', 'threshold', default=0.9)}, "
            f"pre-retune {_get(r, 'quality', 'geomean_pre', default=0):.3f})"),
    ),
    Gate(
        file="retune",
        name="retune controller adds < 2% to a steady-state decode tick",
        check=lambda r: _get(r, "overhead", "pass") is True,
        detail=lambda r: (
            f"adds {_get(r, 'overhead', 'added_frac', default=1):.3%} "
            f"of a decode tick (hook "
            f"{_get(r, 'overhead', 'hook_us', default=0):.1f} us + poll "
            f"{_get(r, 'overhead', 'poll_us', default=0):.1f} us / "
            f"{_get(r, 'overhead', 'interval', default=64)} ticks)"),
    ),
    Gate(
        file="fleet",
        name="4-worker fleet >= 3x single-session job throughput",
        check=lambda r: _get(r, "speedup", "pass") is True,
        detail=lambda r: (
            f"{_get(r, 'speedup', 'speedup', default=0):.2f}x with "
            f"{_get(r, 'speedup', 'workers', default=4)} workers "
            f"({_get(r, 'speedup', 'fleet_jobs_per_s', default=0):.2f} vs "
            f"{_get(r, 'speedup', 'serial_jobs_per_s', default=0):.2f} "
            f"jobs/s, threshold "
            f"{_get(r, 'speedup', 'threshold', default=3.0):.0f}x)"),
    ),
    Gate(
        file="dispatch",
        name="frozen-plan resolution <= 20% of the PR-4 _tuned_cfg path",
        check=lambda r: _get(r, "resolution", "pass") is True,
        detail=lambda r: (
            f"{_get(r, 'resolution', 'ratio', default=1):.1%} of the PR-4 "
            f"path ({_get(r, 'resolution', 'plan_us', default=0):.2f} vs "
            f"{_get(r, 'resolution', 'legacy_us', default=0):.2f} us/call, "
            f"threshold {_get(r, 'resolution', 'threshold', default=0.2):.0%})"
        ),
    ),
    Gate(
        file="dispatch",
        name="indexed nearest() >= 5x the linear scan on a 10k-record store",
        check=lambda r: _get(r, "nearest", "pass") is True,
        detail=lambda r: (
            f"{_get(r, 'nearest', 'speedup', default=0):.1f}x "
            f"({_get(r, 'nearest', 'indexed_us', default=0):.0f} vs "
            f"{_get(r, 'nearest', 'linear_us', default=0):.0f} us/query, "
            f"{_get(r, 'nearest', 'mismatches', default='?')} mismatches)"),
    ),
    Gate(
        file="dispatch",
        name="store-aware admission lifts geomean dispatched TFLOPS",
        check=lambda r: _get(r, "admission", "pass") is True,
        detail=lambda r: (
            f"lift {_get(r, 'admission', 'lift', default=0):.3f} "
            f"({_get(r, 'admission', 'geomean_agnostic', default=0):.1f} -> "
            f"{_get(r, 'admission', 'geomean_aware', default=0):.1f} TFLOPS, "
            f"{_get(r, 'admission', 'padded', default=0)} padded, "
            f"{_get(r, 'admission', 'regressions', default='?')} regressed)"),
    ),
    Gate(
        file="obs",
        name="metrics-on dispatch overhead <= 2% + A/A noise, 0 hot-path "
             "instrument calls",
        check=lambda r: _get(r, "overhead", "pass") is True,
        detail=lambda r: (
            f"{_get(r, 'overhead', 'overhead', default=1):+.2%} vs budget "
            f"{_get(r, 'overhead', 'budget', default=0.02):.2%} "
            f"(A/A noise {_get(r, 'overhead', 'noise', default=0):.2%}), "
            f"{_get(r, 'overhead', 'instrument_calls', default='?')} "
            f"instrument calls, one scrape "
            f"{_get(r, 'overhead', 'scrape_us', default=0):.0f} us"),
    ),
    Gate(
        file="obs",
        name="regression sentry flags the injected regression and blocks "
             "the swap",
        check=lambda r: _get(r, "sentry", "pass") is True,
        detail=lambda r: (
            f"flagged={_get(r, 'sentry', 'flagged')}, "
            f"refused={_get(r, 'sentry', 'refused')}, drop "
            f"{_get(r, 'sentry', 'drop', default=0):.0%}, `tunedb diff` "
            f"exit {_get(r, 'sentry', 'diff_exit', default='?')} (want 1)"),
    ),
    Gate(
        file="obs",
        name="status endpoint serves /metrics + /status and saves the "
             "CI snapshot",
        check=lambda r: _get(r, "endpoint", "pass") is True,
        detail=lambda r: (
            f"{_get(r, 'endpoint', 'metrics_lines', default=0)} metric "
            f"lines, generation "
            f"{_get(r, 'endpoint', 'generation', default='?')}, snapshot "
            f"{_get(r, 'endpoint', 'snapshot', default='missing')}"),
    ),
    Gate(
        file="fleet",
        name="fleet-merged store record-equivalent to a serial session",
        check=lambda r: _get(r, "equivalence", "pass") is True,
        detail=lambda r: (
            f"{_get(r, 'equivalence', 'records_fleet', default=0)} records, "
            f"views match={_get(r, 'equivalence', 'views_match')}, "
            f"log sizes match={_get(r, 'equivalence', 'log_sizes_match')}, "
            "provenance preserved="
            f"{_get(r, 'equivalence', 'provenance_preserved')}"),
    ),
    Gate(
        file="plans",
        name="cold start from a persisted plan artifact resolves within 5% "
             "of warm, identical configs",
        check=lambda r: _get(r, "resolution", "pass") is True,
        detail=lambda r: (
            f"cold/warm {_get(r, 'resolution', 'ratio', default=9):.3f} vs "
            f"{_get(r, 'resolution', 'threshold', default=1.05)} "
            f"({_get(r, 'resolution', 'cold_us', default=0):.2f} vs "
            f"{_get(r, 'resolution', 'warm_us', default=0):.2f} us/call, "
            f"identical configs="
            f"{_get(r, 'resolution', 'identical_configs')}, artifact "
            f"install {_get(r, 'resolution', 'install_load_ms', default=0):.1f} ms)"),
    ),
    Gate(
        file="plans",
        name="3-replica fleet converges to the published generation with "
             "zero torn/stale plan reads",
        check=lambda r: _get(r, "fleet", "pass") is True,
        detail=lambda r: (
            f"converged={_get(r, 'fleet', 'converged')}, "
            f"{_get(r, 'fleet', 'generations', default=0)} generations x "
            f"{_get(r, 'fleet', 'replicas', default=0)} replicas, "
            f"{_get(r, 'fleet', 'resolutions', default=0)} resolutions, "
            f"torn={_get(r, 'fleet', 'torn', default='?')}, "
            f"stale={_get(r, 'fleet', 'stale', default='?')}, max lag "
            f"{_get(r, 'fleet', 'max_lag_s', default=0)*1e3:.0f} ms"),
    ),
    Gate(
        file="router",
        name="shape-affinity routing >= round-robin on geomean TFLOPS and "
             "plan hit rate, zero starved class",
        check=lambda r: _get(r, "routing", "pass") is True,
        detail=lambda r: (
            f"TFLOPS x{_get(r, 'routing', 'tflops_ratio_vs_rr', default=0):.2f}"
            f" vs round-robin, hit rate "
            f"{_get(r, 'routing', 'hit_rate_affinity', default=0):.3f} vs "
            f"{_get(r, 'routing', 'hit_rate_round_robin', default=0):.3f}, "
            f"starved classes "
            f"{_get(r, 'routing', 'starved_classes', default='?')} "
            f"(plan entries {_get(r, 'routing', 'plan_entries', default=[])})"),
    ),
    Gate(
        file="router",
        name="retune triggers off aggregated fleet telemetry that no "
             "single replica's window trips",
        check=lambda r: _get(r, "fleet_trigger", "pass") is True,
        detail=lambda r: (
            f"local window "
            f"{_get(r, 'fleet_trigger', 'window_calls_local', default=0)} "
            f"calls -> trigger="
            f"{_get(r, 'fleet_trigger', 'local_trigger')}, fleet window "
            f"{_get(r, 'fleet_trigger', 'window_calls_fleet', default=0)} "
            f"calls -> trigger={_get(r, 'fleet_trigger', 'fleet_trigger')} "
            f"(min_calls "
            f"{_get(r, 'fleet_trigger', 'min_calls', default='?')})"),
    ),
    Gate(
        file="trace",
        name="tracing disabled makes zero Tracer calls on a live engine run",
        check=lambda r: _get(r, "disabled", "instrument_calls") == 0,
        detail=lambda r: (
            f"{_get(r, 'disabled', 'instrument_calls', default='?')} "
            f"Tracer calls over "
            f"{_get(r, 'disabled', 'ticks', default='?')} decode ticks"),
    ),
    Gate(
        file="trace",
        name="<=2% median decode-tick overhead at 1% trace sampling "
             "(+2x A/A noise)",
        check=lambda r: _get(r, "overhead", "pass") is True,
        detail=lambda r: (
            f"overhead {_get(r, 'overhead', 'overhead', default=0):+.2%} "
            f"(budget {_get(r, 'overhead', 'budget', default=0):.2%} = "
            f"{_get(r, 'overhead', 'threshold', default=0):.0%} + 2x "
            f"{_get(r, 'overhead', 'noise', default=0):.2%} noise), "
            f"{_get(r, 'overhead', 'quiet_us', default=0):.0f}us -> "
            f"{_get(r, 'overhead', 'traced_us', default=0):.0f}us/tick"),
    ),
    Gate(
        file="chaos",
        name="chaos shim disarmed makes zero calls on the dispatch hot path",
        check=lambda r: _get(r, "disarmed", "shim_calls") == 0,
        detail=lambda r: (
            f"{_get(r, 'disarmed', 'shim_calls', default='?')} shim calls "
            f"over {_get(r, 'disarmed', 'resolutions', default='?')} "
            f"resolutions "
            f"({_get(r, 'disarmed', 'resolve_us', default=0):.2f} us/call)"),
    ),
    Gate(
        file="chaos",
        name="SIGKILLed appender loses zero acknowledged records; fsck "
             "repairs the survivor",
        check=lambda r: _get(r, "store_crash", "pass") is True,
        detail=lambda r: (
            f"{_get(r, 'store_crash', 'lost', default='?')} lost of "
            f"{_get(r, 'store_crash', 'acked', default='?')} acked, "
            f"{_get(r, 'store_crash', 'torn_lines', default='?')} torn "
            f"line(s), fsck exits "
            f"{_get(r, 'store_crash', 'fsck_repair_exit', default='?')}/"
            f"{_get(r, 'store_crash', 'fsck_clean_exit', default='?')}"),
    ),
    Gate(
        file="chaos",
        name="3-worker fleet under seeded faults: every job exactly once, "
             "zero lost acks, zero torn/stale plan installs",
        check=lambda r: _get(r, "fleet", "pass") is True,
        detail=lambda r: (
            f"{_get(r, 'fleet', 'done', default='?')} done + "
            f"{_get(r, 'fleet', 'failed', default='?')} failed of "
            f"{_get(r, 'fleet', 'jobs', default='?')} jobs, lost "
            f"{_get(r, 'fleet', 'lost_acked', default='?')}, torn/stale "
            f"installs {_get(r, 'fleet', 'torn_installs', default='?')}/"
            f"{_get(r, 'fleet', 'stale_installs', default='?')}, "
            f"{_get(r, 'fleet', 'injected', default='?')} faults injected "
            f"({_get(r, 'fleet', 'by_kind', default={})}), fsck exit "
            f"{_get(r, 'fleet', 'fsck_exit', default='?')}"),
    ),
    Gate(
        file="chaos",
        name="serving completes its requests with chaos armed (deadlines + "
             "shedding, healthy after drain)",
        check=lambda r: _get(r, "serving", "pass") is True,
        detail=lambda r: (
            f"{_get(r, 'serving', 'served', default='?')} served + "
            f"{_get(r, 'serving', 'shed', default='?')} shed of "
            f"{_get(r, 'serving', 'requests', default='?')}, retired "
            f"{_get(r, 'serving', 'deadline_retired', default='?')}, "
            f"healthy={_get(r, 'serving', 'healthy_after_drain')}, "
            f"exception={_get(r, 'serving', 'exception')}"),
    ),
    Gate(
        file="trace",
        name="exported trace artifact is Perfetto-loadable with the linked "
             "span taxonomy (route/tick/dispatch-tier/measure)",
        check=lambda r: _get(r, "artifact", "pass") is True,
        detail=lambda r: (
            f"{_get(r, 'artifact', 'spans', default=0)} spans "
            f"({_get(r, 'artifact', 'linked', default=0)} parent-linked), "
            f"tiers {_get(r, 'artifact', 'tiers', default=[])}, missing "
            f"{_get(r, 'artifact', 'missing', default='?')}, artifact "
            f"{_get(r, 'artifact', 'artifact', default='?')}"),
    ),
]


def check(results_dir: pathlib.Path = RESULTS,
          require: Optional[List[str]] = None) -> int:
    """Run every applicable gate; print the report; return the exit code."""
    results: Dict[str, dict] = {}
    failures = 0
    for path in sorted(results_dir.glob("*.json")) if results_dir.is_dir() \
            else []:
        try:
            results[path.stem] = json.loads(path.read_text())
        except ValueError:
            # a torn result is a failed gate, not a skipped one
            print(f"[gate] FAIL {path.name}: unparseable JSON")
            results[path.stem] = None
            failures += 1
    for name in sorted(require or []):
        if name not in results:
            print(f"[gate] FAIL {name}.json: required result file missing "
                  f"(run `python -m benchmarks.run --only {name}`)")
            failures += 1

    seen_specific = set()
    for gate in GATES:
        r = results.get(gate.file)
        if r is None:
            continue                   # absent (or unparseable, counted above)
        seen_specific.add(gate.file)
        try:
            ok = bool(gate.check(r))
            detail = gate.detail(r)
        except (KeyError, TypeError) as e:
            ok, detail = False, f"malformed result ({type(e).__name__}: {e})"
        print(f"[gate] {'ok  ' if ok else 'FAIL'} {gate.file}.json: "
              f"{gate.name} — {detail}")
        failures += 0 if ok else 1

    # generic: any other result that records its own verdict is held to it
    for name, r in sorted(results.items()):
        if name in seen_specific or not isinstance(r, dict) or "pass" not in r:
            continue
        ok = r["pass"] is True
        print(f"[gate] {'ok  ' if ok else 'FAIL'} {name}.json: "
              f"self-reported pass field")
        failures += 0 if ok else 1

    print(f"\n{failures} gate failure(s)" if failures
          else "\nall gates pass")
    return 1 if failures else 0


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m benchmarks.check_gates",
        description=__doc__.splitlines()[0])
    p.add_argument("--results", default=str(RESULTS),
                   help="results directory (default: results/bench)")
    p.add_argument("--require", default="",
                   help="comma-separated result files that MUST exist, "
                        "e.g. tunedb,model")
    args = p.parse_args(argv)
    require = [s for s in args.require.split(",") if s]
    return check(pathlib.Path(args.results), require)


if __name__ == "__main__":
    raise SystemExit(main())
