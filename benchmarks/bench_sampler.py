"""E1 — paper Table 1: generative-model acceptance vs uniform sampling.

Paper (GPU space): GEMM 20% vs 0.1%, CONV 15% vs 0.1%.  Our TPU legality is
less hostile (VMEM is MiB not KiB), so uniform acceptance starts higher and
the attainable ratio is smaller; the mechanism and direction reproduce.
"""

from __future__ import annotations

import numpy as np

from repro.core.generative import CategoricalSampler, workload_inputs
from repro.core.space import SPACES
from .common import save, table


def run(fast: bool = True) -> dict:
    n_fit = 20000 if fast else 100000
    n_eval = 4000 if fast else 20000
    rows = []
    for name in ("gemm", "conv", "attention", "ssd"):
        space = SPACES[name]
        rng = np.random.default_rng(0)
        inputs = workload_inputs(space, 128, rng)
        sampler = CategoricalSampler(space=space).fit(inputs, n_fit, rng)
        cat = sampler.acceptance_rate(inputs, n_eval, rng)
        uni = sampler.acceptance_rate(inputs, n_eval, rng, uniform=True)
        rows.append({"space": name, "categorical": f"{cat:.1%}",
                     "uniform": f"{uni:.1%}",
                     "ratio": f"{cat / max(uni, 1e-6):.1f}x",
                     "paper (GPU)": {"gemm": "20% vs 0.1% (200x)",
                                     "conv": "15% vs 0.1% (150x)"}.get(
                                         name, "-")})
    print(table(rows, ["space", "categorical", "uniform", "ratio",
                       "paper (GPU)"],
                "E1 / Table 1 — sampler acceptance (categorical vs uniform)"))
    save("sampler", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    run()
