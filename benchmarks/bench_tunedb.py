"""E10 — tunedb: store-lookup overhead on the dispatch hot path.

Two questions gate shipping the record store into serving:

  1. What do the raw primitives cost?  (telemetry record, exact lookup,
     nearest-shape lookup, fsync'd append)
  2. What does the full dispatch-side stack — telemetry record + store
     lookup — add to an interpret-mode kernel dispatch?  Acceptance: < 5%.

The dispatch comparison runs the SAME Pallas kernel (interpret mode, CPU)
with the config injected directly (baseline) vs resolved through the
installed global store (telemetry + exact-hit lookup).  Because a ~200ms
interpret-mode kernel call carries several percent of wall-clock noise, the
acceptance verdict comes from timing the resolution stack in isolation and
dividing by the dispatch time — the A/B delta is reported alongside as a
noise-bounded sanity check.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.space import gemm_input
from repro.core.tuner import clear_tuners
from repro.kernels import dispatch, ops
from repro.tunedb import (RecordStore, TuneRecord, clear_store,
                          clear_telemetry, get_telemetry, install_store)

from .common import save, table

CFG = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
       "order": 0, "acc32": 1, "prefetch": 2}


def _time_per_call(fn, iters: int) -> float:
    fn()                                    # warm up (trace/compile/build)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def _paired_medians(fn_a, fn_b, pairs: int):
    """Median per-call time of two paths sampled back-to-back, so slow drift
    in the (noisy, hundreds-of-ms) interpret-mode kernel cancels out of the
    A/B delta instead of masquerading as dispatch overhead."""
    fn_a(), fn_b()                          # warm up both paths
    ta, tb = [], []
    for _ in range(pairs):
        t0 = time.perf_counter()
        fn_a()
        t1 = time.perf_counter()
        fn_b()
        t2 = time.perf_counter()
        ta.append(t1 - t0)
        tb.append(t2 - t1)
    return float(np.median(ta)), float(np.median(tb))


def _micro_ops(mem_store: RecordStore, iters: int) -> list:
    """Raw primitive costs, reported in microseconds per op."""
    import tempfile

    inputs = gemm_input(256, 256, 512, 32)
    tel = get_telemetry()

    # a NEW shape every call, else the nearest-memo turns the timed scan
    # into a dict hit and the row understates the true miss cost
    tick = iter(range(10_000_000))

    def nearest_cold():
        mem_store.nearest("gemm", gemm_input(300 + next(tick), 256, 512, 32))

    with tempfile.TemporaryDirectory() as d:
        disk_store = RecordStore.open(f"{d}/bench.jsonl")
        rows = []
        for name, fn in [
            ("telemetry.record", lambda: tel.record("gemm", inputs)),
            ("store.get (exact)", lambda: mem_store.get("gemm", inputs)),
            ("store.nearest (cold scan)", nearest_cold),
            ("store.nearest (memo hit)",
             lambda: mem_store.nearest("gemm", gemm_input(300, 256, 512, 32))),
            ("store.add (fsync append)",
             lambda: disk_store.add(TuneRecord(
                 space="gemm", inputs=inputs, config=CFG, tflops=1.0))),
        ]:
            n = max(iters // 10, 10) if "add" in name else iters
            rows.append({"op": name,
                         "us/op": f"{_time_per_call(fn, n)*1e6:.1f}"})
    return rows


def run(fast: bool = True) -> dict:
    clear_tuners()
    clear_store()
    clear_telemetry()
    iters = 200 if fast else 2000

    rng = np.random.default_rng(0)
    import jax.numpy as jnp
    a = jnp.asarray(rng.normal(size=(128, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
    inputs = gemm_input(128, 128, 256, 32)

    store = RecordStore()                    # in-memory; lookup cost only
    for m in (64, 128, 256, 512, 1024):      # realistic index population
        for k in (128, 256, 512, 1024):
            store.add(TuneRecord(space="gemm",
                                 inputs=gemm_input(m, 128, k, 32),
                                 config=CFG, tflops=1.0))

    disp_pairs = 15 if fast else 60
    install_store(store)
    t_direct, t_dispatch = _paired_medians(
        lambda: np.asarray(ops.matmul(a, b, CFG)),
        lambda: np.asarray(dispatch.matmul(a, b, prefer_kernel=True)),
        disp_pairs)

    # the exact per-call stack dispatch.matmul adds on top of ops.matmul:
    # shape-dict build + telemetry record + store resolution + config copy
    def resolve_only():
        cfg = dispatch._tuned_cfg("gemm", inputs)
        dispatch._record("gemm", inputs)
        return cfg

    assert resolve_only() is not None       # exact store hit, not a miss
    t_resolve = _time_per_call(resolve_only, iters)
    hits_after = store.hits
    clear_store()

    # A/B wall-clock of a ~200ms interpret kernel is dominated by machine
    # drift; the acceptance ratio uses the isolated resolution cost instead.
    overhead = t_resolve / t_dispatch
    rows = [
        {"path": "ops.matmul (config injected)",
         "ms/call": f"{t_direct*1e3:.2f}",
         "note": "paired-median baseline"},
        {"path": "dispatch.matmul (telemetry + store hit)",
         "ms/call": f"{t_dispatch*1e3:.2f}",
         "note": f"A/B delta {(t_dispatch-t_direct)/t_direct*100:+.2f}% "
                 "(noise-bounded)"},
        {"path": "resolution stack alone",
         "ms/call": f"{t_resolve*1e3:.4f}",
         "note": f"{overhead*100:.3f}% of dispatch"},
    ]
    print(table(rows, ["path", "ms/call", "note"],
                "E10 — store-lookup overhead on interpret-mode dispatch"))
    verdict = "PASS" if overhead < 0.05 else "FAIL"
    print(f"\nacceptance (<5% overhead): {verdict} "
          f"({overhead*100:.3f}%, {hits_after} exact store hits)")

    micro = _micro_ops(store, iters)
    print()
    print(table(micro, ["op", "us/op"], "tunedb primitive costs"))

    payload = {"overhead_frac": overhead, "pass": overhead < 0.05,
               "direct_ms": t_direct * 1e3, "dispatch_ms": t_dispatch * 1e3,
               "resolve_ms": t_resolve * 1e3, "micro": micro}
    save("tunedb", payload)
    clear_telemetry()
    return payload


if __name__ == "__main__":
    run()
