"""E19 — chaos: the tunedb bus under deterministic fault injection.

The robustness claim (docs/ROBUSTNESS.md) is that every filesystem-bus
protocol *absorbs* the faults a real filesystem produces instead of
losing work or serving damage.  Four gates:

  1. DISARMED — with no fault plan armed, the chaos shim makes ZERO
     calls on the frozen-plan dispatch hot path, the store append/load,
     the lease lifecycle, and plan export/load (monkeypatch-trapped, the
     same proof style as E15's zero-instrumentation gate).

  2. STORE-CRASH — an appender process is SIGKILLed mid-flight after N
     acknowledged (fsync-then-print) appends: a fresh open recovers
     every acknowledged record, and ``tunedb fsck`` verifies/repairs the
     surviving store (exit 0 after ``--repair``).

  3. FLEET — a 3-worker fleet runs a seeded ``FaultPlan`` (torn shard
     appends at >= 1%, >= 2 worker kill-points, EIO bursts on the lease
     protocol, torn/stale plan pulls against 2 plan followers).  Gate:
     every published job reaches done/failed exactly once, every done
     job's record is in the merged store (zero lost acknowledged
     records), and the followers install zero torn and zero stale plan
     generations while converging to the final publish.

  4. SERVING — while that same fault plan is armed, a serving engine
     with deadlines + shedding completes its admitted requests without
     an exception and reports healthy once the backlog drains (the bus
     burning must never take the request path down).

The surviving store + fleet bus are copied to
``results/bench/chaos-store/`` so CI can re-run ``tunedb fsck`` against
them as an independent step.
"""

from __future__ import annotations

import errno
import shutil
import signal
import subprocess
import sys
import tempfile
import threading
import time
import warnings
from pathlib import Path

from repro.core.backend import SimulatedTPUBackend
from repro.core.search import SearchResult
from repro.core.space import gemm_input
from repro.core.tuner import clear_tuners
from repro.kernels import dispatch
from repro.tunedb import (DispatchPlan, RecordStore, TuneRecord, chaos,
                          clear_store, clear_telemetry, install_serving,
                          shape_key)
from repro.tunedb.__main__ import main as tunedb_main
from repro.tunedb.chaos import FaultPlan, FaultRule, KillPoint
from repro.tunedb.fleet import Coordinator, FleetJob, Worker
from repro.tunedb.model import clear_models
from repro.tunedb.plans import PlanFollower, PlanRegistry, export_plan, \
    load_plan

from .common import RESULTS, save, table

SRC = str(Path(__file__).resolve().parents[1] / "src")
SEED = 23
N_WORKERS = 3
FOLLOWERS = 2

CFG = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
       "order": 0, "acc32": 1, "prefetch": 2}

# every FaultyIO entry point a disarmed run must never reach
_SHIM_METHODS = ("probe", "read_text", "read_bytes", "write_text",
                 "write_bytes", "file_write", "replace", "rename",
                 "fsync", "utime", "unlink")


def _reset() -> None:
    clear_tuners()
    clear_store()
    clear_models()
    clear_telemetry()


class _StubTuner:
    """Instant deterministic tuner: E19 measures the bus, not the search."""

    space = None
    backend = SimulatedTPUBackend(noise=0.0)

    def search(self, inputs, remeasure=True):
        tf = float(self.backend.measure("gemm", CFG, inputs))
        return SearchResult(best=dict(CFG), predicted_tflops=tf,
                            measured_tflops=tf, top_k=[(dict(CFG), tf)],
                            n_candidates=1, measured=[(dict(CFG), tf)])


def _rec(i: int) -> TuneRecord:
    return TuneRecord(space="gemm", inputs=gemm_input(128 * (i + 1), 64, 512),
                      config=dict(CFG), tflops=100.0, backend="sim")


# ---------------------------------------------------------------------------
# 1. disarmed: the shim is invisible on the hot path and the bus
# ---------------------------------------------------------------------------

def _bench_disarmed(fast: bool, tmp: Path) -> dict:
    chaos.disarm()
    store = RecordStore(tmp / "disarmed.jsonl")
    hot = [gemm_input(256 * (i + 1), 64, 1024) for i in range(8)]
    for inputs in hot:
        store.add(TuneRecord(space="gemm", inputs=inputs, config=CFG,
                             tflops=100.0, backend="sim"))
    install_serving(store=store)

    hits = {"n": 0}

    def trap(self, *a, **kw):
        hits["n"] += 1
        raise AssertionError("disarmed path touched the chaos shim")

    saved = {name: getattr(chaos.FaultyIO, name) for name in _SHIM_METHODS}
    for name in _SHIM_METHODS:
        setattr(chaos.FaultyIO, name, trap)
    iters = 2000 if fast else 20000
    try:
        for inputs in hot:                       # warm every memo
            dispatch._tuned_cfg("gemm", inputs)
        t0 = time.perf_counter()
        for _ in range(iters):
            for inputs in hot:
                dispatch._tuned_cfg("gemm", inputs)
        t_resolve = (time.perf_counter() - t0) / (iters * len(hot))
        # the bus surfaces the shim also guards, all disarmed
        store.add(_rec(98))
        RecordStore.open(tmp / "disarmed.jsonl")
        coord = Coordinator(tmp / "disarmed-fleet", store, lease_timeout_s=5.0)
        coord.publish([FleetJob(space="gemm",
                                inputs=gemm_input(128, 64, 512))])
        job, lp = coord.fleet.claim()
        coord.fleet.heartbeat(lp)
        coord.fleet.complete(job, lp, {"worker_id": "bench"})
        plan = DispatchPlan(generation=0, fingerprint="sim", store_version=-1,
                            table={("gemm", shape_key(hot[0])):
                                   (dict(CFG), "exact")})
        load_plan(export_plan(plan, tmp / "disarmed-plan"))
    finally:
        for name, fn in saved.items():
            setattr(chaos.FaultyIO, name, fn)
        _reset()

    n = iters * len(hot)
    print(f"disarmed: {hits['n']} shim calls over {n} hot-path resolutions "
          f"({t_resolve*1e6:.2f} us/call) + store/lease/plan round-trips")
    return {"shim_calls": hits["n"], "resolutions": n,
            "resolve_us": t_resolve * 1e6, "pass": hits["n"] == 0}


# ---------------------------------------------------------------------------
# 2. SIGKILL mid-append: acknowledged records survive, fsck repairs
# ---------------------------------------------------------------------------

_CHILD = """
import sys
sys.path.insert(0, {src!r})
from repro.tunedb.store import RecordStore, TuneRecord
s = RecordStore({path!r}, fsync=True)
i = 0
while True:
    s.add(TuneRecord(space="gemm", inputs={{"M": i, "N": 64, "K": 512}},
                     config={{"bm": 32}}, tflops=1.0, backend="sim"))
    print(i, flush=True)        # ACK: durable before this line prints
    i += 1
"""


def _bench_store_crash(fast: bool, tmp: Path) -> dict:
    path = str(tmp / "crash.jsonl")
    n_ack = 16 if fast else 64
    proc = subprocess.Popen(
        [sys.executable, "-c", _CHILD.format(src=SRC, path=path)],
        stdout=subprocess.PIPE, stderr=subprocess.DEVNULL, text=True)
    acked = []
    try:
        for line in proc.stdout:
            acked.append(int(line))
            if len(acked) >= n_ack:
                proc.send_signal(signal.SIGKILL)    # no cleanup, mid-flight
                break
    finally:
        proc.kill()
        proc.wait(timeout=30)

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")             # a torn tail may warn
        store = RecordStore.open(path)
        recovered = {r.inputs["M"] for r in store.records()}
        lost = sorted(set(acked) - recovered)
        torn_tail = store.n_skipped
        # fsck quarantines whatever the crash tore, then verifies clean
        fsck_repair = tunedb_main(["fsck", path, "--repair"])
    fsck_clean = tunedb_main(["fsck", path])

    print(f"store-crash: SIGKILL after {len(acked)} acked appends -> "
          f"{len(recovered)} recovered, {len(lost)} lost, "
          f"{torn_tail} torn line(s) quarantined; fsck --repair exit "
          f"{fsck_repair}, re-check exit {fsck_clean}")
    return {"acked": len(acked), "recovered": len(recovered),
            "lost": len(lost), "torn_lines": torn_tail,
            "fsck_repair_exit": fsck_repair, "fsck_clean_exit": fsck_clean,
            "pass": bool(not lost and fsck_repair == 0 and fsck_clean == 0)}


# ---------------------------------------------------------------------------
# 3. the fleet + plan followers under a seeded fault plan
# ---------------------------------------------------------------------------

class _Replica:
    """A follower's private install target with torn/stale read checks
    (the E16 harness shape: one atomically-swapped plan reference)."""

    def __init__(self, name: str):
        self.name = name
        self.installed = None
        self.torn = 0
        self.stale = 0
        self._last_gen = 0

    def install(self, plan, pointer) -> bool:
        self.installed = (plan, int(pointer["generation"]))
        return True

    def current_plan(self):
        got = self.installed
        return got[0] if got else None

    def read(self, shapes) -> None:
        got = self.installed
        if got is None:
            return
        plan, gen = got
        if gen < self._last_gen:
            self.stale += 1
        self._last_gen = max(self._last_gen, gen)
        markers = {entry[0]["g"] for i in shapes
                   for entry in [plan.lookup("gemm", shape_key(i))]
                   if entry is not None}
        if len(markers) > 1:            # mixed generations in one plan read
            self.torn += 1


def _marked_plan(gen: int, shapes) -> DispatchPlan:
    tbl = {("gemm", shape_key(i)): (dict(CFG, g=gen), "exact")
           for i in shapes}
    return DispatchPlan(generation=0, fingerprint="sim", store_version=-1,
                        table=tbl)


def _fault_plan() -> FaultPlan:
    return FaultPlan(seed=SEED, rules=[
        # >= 1% torn shard appends (the crashed-writer fault)
        FaultRule(site="store.append", kind="torn_write", p=0.05,
                  max_count=2),
        # >= 2 worker kill-points: crashes between protocol steps
        FaultRule(site="worker.*", kind="kill", p=0.25, max_count=2),
        # EIO bursts on the lease protocol (claims, heartbeats, completes)
        FaultRule(site="lease.*", kind="errno", p=0.08, errno=errno.EIO,
                  max_count=8),
        # torn + stale + unreadable plan pulls against the followers
        FaultRule(site="plan.pull.entries", kind="truncated_read", p=0.25,
                  max_count=4),
        FaultRule(site="plan.pull.manifest", kind="errno", p=0.15,
                  errno=errno.EIO, max_count=3),
        FaultRule(site="plan.registry.current", kind="stale_read", p=0.15,
                  max_count=3),
    ])


def _bench_fleet_chaos(fast: bool, tmp: Path) -> dict:
    n_jobs = 10 if fast else 24
    generations = 5 if fast else 10
    store = RecordStore(tmp / "fleet.jsonl")
    coord = Coordinator(tmp / "fleet", store, lease_timeout_s=0.3)
    jobs = [FleetJob(space="gemm", inputs=gemm_input(128 * (i + 1), 64, 512))
            for i in range(n_jobs)]
    assert coord.publish(jobs) == n_jobs

    shapes = [gemm_input(128 * (i + 1), 64, 512) for i in range(8)]
    registry = PlanRegistry(tmp / "registry")
    replicas = [_Replica(f"replica-{i}") for i in range(FOLLOWERS)]
    followers = [PlanFollower(registry, poll_s=0.01, name=r.name,
                              install=r.install, current_plan=r.current_plan)
                 for r in replicas]
    stop = threading.Event()

    def reader(replica):
        while not stop.is_set():
            replica.read(shapes)
            time.sleep(0.001)

    def run_worker(wid):
        w = Worker(tmp / "fleet", worker_id=wid,
                   tuners={"gemm": _StubTuner()}, poll_s=0.01,
                   heartbeat_s=0.05)
        try:
            w.run(max_jobs=n_jobs, idle_timeout_s=0.5)
        except KillPoint:
            pass                         # simulated crash: the thread dies

    fplan = _fault_plan()
    t0 = time.perf_counter()
    with chaos.armed(fplan) as io:
        for f in followers:
            f.start()
        readers = [threading.Thread(target=reader, args=(r,), daemon=True)
                   for r in replicas]
        for t in readers:
            t.start()
        workers = [threading.Thread(target=run_worker, args=(f"w{i}",))
                   for i in range(N_WORKERS)]
        for t in workers:
            t.start()
        for gen in range(1, generations + 1):   # publish while jobs burn
            registry.publish(_marked_plan(gen, shapes))
            time.sleep(0.05)
        for t in workers:
            t.join(timeout=60)
        report = io.report()

    # recovery, faults off: requeue expired leases, drain the remainder
    deadline = time.time() + 60
    while time.time() < deadline:
        time.sleep(0.31)
        coord.fleet.reclaim_expired(lease_timeout_s=0.3, max_attempts=10)
        c = coord.fleet.counts()
        if c["leases"] == 0 and c["queue"] == 0:
            break
        Worker(tmp / "fleet", worker_id=f"sweep-{time.monotonic_ns()}",
               tuners={"gemm": _StubTuner()}, poll_s=0.01,
               heartbeat_s=0.05).run(max_jobs=n_jobs, idle_timeout_s=0.2)
    # one clean publish; every follower must converge to it
    final_gen = registry.publish(
        _marked_plan(generations + 1, shapes)).generation
    deadline = time.time() + 30
    while time.time() < deadline and any(
            f.generation < final_gen for f in followers):
        time.sleep(0.01)
    wall_s = time.perf_counter() - t0
    stop.set()
    for f in followers:
        f.stop()

    counts = coord.fleet.counts()
    done = {p.stem for p in coord.fleet.done.glob("*.json")}
    failed = {p.stem for p in coord.fleet.failed.glob("*.json")}
    exactly_once = (done | failed == {j.job_id for j in jobs}
                    and not (done & failed))
    coord.poll()                         # final merge over torn shards
    merged = {tuple(sorted(r.inputs.items()))
              for r in store.records() if r.source == "fleet"}
    lost_acked = [j.job_id for j in jobs if j.job_id in done
                  and tuple(sorted(j.inputs.items())) not in merged]
    converged = all(f.generation == final_gen for f in followers)
    torn_installs = sum(r.torn for r in replicas)
    stale_installs = sum(r.stale for r in replicas)
    refused = sum(f.refused_digest for f in followers)

    by_kind = report.get("by_kind", {})
    engaged = (by_kind.get("kill", 0) >= 2 and by_kind.get("errno", 0) >= 1
               and report["injected_total"] >= 3)

    rows = [
        {"invariant": "jobs done/failed exactly once",
         "value": f"{len(done)} done + {len(failed)} failed / {n_jobs}",
         "ok": exactly_once},
        {"invariant": "acknowledged records lost",
         "value": len(lost_acked), "ok": not lost_acked},
        {"invariant": "bus drained (queue/leases)",
         "value": f"{counts['queue']}/{counts['leases']}",
         "ok": counts["queue"] == 0 and counts["leases"] == 0},
        {"invariant": "torn plan installs", "value": torn_installs,
         "ok": torn_installs == 0},
        {"invariant": "stale plan installs", "value": stale_installs,
         "ok": stale_installs == 0},
        {"invariant": f"followers at generation {final_gen}",
         "value": [f.generation for f in followers], "ok": converged},
    ]
    print(table(rows, ["invariant", "value", "ok"],
                f"E19 — {N_WORKERS}-worker fleet under seeded chaos "
                f"(seed {SEED})"))
    print(f"\nfaults injected: {report['injected_total']} "
          f"({dict(sorted(by_kind.items()))}) over {report['calls']} shim "
          f"calls in {wall_s:.2f}s; followers refused "
          f"{refused} torn pull(s)")

    ok = bool(exactly_once and not lost_acked and counts["queue"] == 0
              and counts["leases"] == 0 and torn_installs == 0
              and stale_installs == 0 and converged and engaged)

    # persist the surviving store + bus for the CI fsck step
    ci_dir = RESULTS / "chaos-store"
    shutil.rmtree(ci_dir, ignore_errors=True)
    ci_dir.mkdir(parents=True)
    shutil.copy2(tmp / "fleet.jsonl", ci_dir / "db.jsonl")
    shutil.copytree(tmp / "fleet", ci_dir / "fleet")
    fsck_exit = tunedb_main(["fsck", str(ci_dir / "db.jsonl"),
                             "--fleet", str(ci_dir / "fleet")])
    print(f"fsck over the surviving store + bus: exit {fsck_exit} "
          f"(artifact {ci_dir})")

    return {"jobs": n_jobs, "workers": N_WORKERS, "wall_s": wall_s,
            "done": len(done), "failed": len(failed),
            "exactly_once": exactly_once, "lost_acked": len(lost_acked),
            "queue": counts["queue"], "leases": counts["leases"],
            "torn_installs": torn_installs, "stale_installs": stale_installs,
            "refused_digest": refused, "converged": converged,
            "injected": report["injected_total"],
            "by_kind": by_kind, "fsck_exit": fsck_exit,
            "pass": ok and fsck_exit == 0}


# ---------------------------------------------------------------------------
# 4. serving keeps answering while the bus burns
# ---------------------------------------------------------------------------

def _bench_serving(fast: bool) -> dict:
    import jax
    import numpy as np
    from repro.models import ModelConfig, init_params
    from repro.serve import Engine, ServeConfig

    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=2, n_kv=1,
                      d_ff=64, vocab=128, dtype=jax.numpy.float32,
                      attn_chunk=16, logit_chunk=16, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 128, 5) for _ in range(8)]

    eng = Engine(cfg, params, ServeConfig(
        max_len=64, slots=2, shed_threshold=6, request_deadline_s=30.0))
    exception = None
    with chaos.armed(_fault_plan()):    # the bus faults are armed; the
        try:                            # request path must not notice
            outs = eng.generate(prompts, max_new=4)
        except Exception as e:          # noqa: BLE001 - the gate itself
            exception = repr(e)
            outs = []
    served = sum(1 for o in outs if o)
    complete = served and all(len(o) == 4 for o in outs if o)
    healthy = eng._health() is True

    print(f"serving under armed chaos: {served} served / "
          f"{eng.shed_requests} shed of {len(prompts)}, "
          f"deadline-retired {eng.deadline_retired}, healthy-after-drain "
          f"{healthy}, exception {exception or 'none'}")
    ok = bool(exception is None and complete
              and served + eng.shed_requests == len(prompts)
              and eng.deadline_retired == 0 and healthy)
    return {"requests": len(prompts), "served": served,
            "shed": eng.shed_requests,
            "deadline_retired": eng.deadline_retired,
            "healthy_after_drain": healthy, "exception": exception,
            "pass": ok}


def run(fast: bool = True) -> dict:
    tmp = Path(tempfile.mkdtemp(prefix="bench_chaos_"))
    try:
        chaos.disarm()
        disarmed = _bench_disarmed(fast, tmp)
        store_crash = _bench_store_crash(fast, tmp)
        fleet = _bench_fleet_chaos(fast, tmp)
        serving = _bench_serving(fast)
    finally:
        chaos.disarm()
        _reset()
        shutil.rmtree(tmp, ignore_errors=True)
    out = {"disarmed": disarmed, "store_crash": store_crash, "fleet": fleet,
           "serving": serving,
           "pass": bool(disarmed["pass"] and store_crash["pass"]
                        and fleet["pass"] and serving["pass"])}
    save("chaos", out)
    print(f"\nE19 verdict: {'PASS' if out['pass'] else 'FAIL'}")
    return out


if __name__ == "__main__":
    run()
