"""E16 — golden plan artifacts: cold-start parity + fleet propagation.

The PR-7 tentpole claim, gated two ways:

  1. COLD START — a fresh process that installs a PERSISTED plan artifact
     (``install_serving(plan_dir=)``: manifest schema-gated, entries
     digest-verified, zero install-time model scans) must resolve the
     steady-state hot set within 5% of the warm process that compiled the
     plan itself — and resolve every shape to the IDENTICAL config.  The
     artifact round trip may not cost anything where it matters: serving.

  2. FLEET — a synthetic 3-replica serving fleet follows a coordinator
     through several published plan generations (``PlanRegistry`` publish
     -> ``PlanFollower`` pull/verify/swap).  Every replica must converge
     to the final generation while concurrent readers observe ZERO torn
     plans (every entry of a read plan carries the same generation
     marker) and ZERO stale-generation installs (a replica's installed
     generation never moves backwards).

Timing noise note: both sides of gate 1 execute the identical lock-free
table probe — only the table's provenance differs — so the ratio sits at
~1.0 and the 5% bound is generous; the bench still retries a few times so
an ambient-load spike cannot fail CI.
"""

from __future__ import annotations

import shutil
import tempfile
import threading
import time
from pathlib import Path

from repro.core.space import gemm_input
from repro.core.tuner import clear_tuners
from repro.kernels import dispatch
from repro.tunedb import (DispatchPlan, RecordStore, TuneRecord, clear_store,
                          clear_telemetry, get_telemetry, install_serving,
                          serving_state, shape_key)
from repro.tunedb.model import clear_models
from repro.tunedb.plans import PlanFollower, PlanRegistry, export_plan

from .common import save, table

COLD_WARM_THRESHOLD = 1.05      # cold resolution within 5% of warm
REPLICAS = 3
CFG = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
       "order": 0, "acc32": 1, "prefetch": 2}


def _reset() -> None:
    clear_tuners()
    clear_store()
    clear_models()
    clear_telemetry()


def _time_per_call(fn, iters: int) -> float:
    fn()
    best = float("inf")
    for _ in range(3):              # best-of-3 against ambient noise
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        best = min(best, (time.perf_counter() - t0) / iters)
    return best


# ---------------------------------------------------------------------------
# 1. cold start from a persisted artifact vs the warm compiling process
# ---------------------------------------------------------------------------

def _bench_cold_start(fast: bool, tmp: Path) -> dict:
    _reset()
    store_path = tmp / "store.jsonl"
    store = RecordStore(store_path)
    tuned = [gemm_input(256 * (i + 1), 64, 1024) for i in range(12)]
    for inputs in tuned:
        store.add(TuneRecord(space="gemm", inputs=inputs, config=CFG,
                             tflops=100.0, backend="sim"))
    novel = [gemm_input(256 * (i + 1) + 48, 64, 1024) for i in range(12)]
    hot = tuned + novel
    tel = get_telemetry()
    for inputs in hot:
        tel.record("gemm", inputs, n=10)

    iters = 3000 if fast else 15000

    def resolve_hot_set():
        for inputs in hot:
            dispatch._tuned_cfg("gemm", inputs)

    # -- warm process: compile at install, export the golden artifact -------
    t0 = time.perf_counter()
    install_serving(store=store)
    install_compile_ms = (time.perf_counter() - t0) * 1e3
    warm_plan = serving_state().plan
    warm_cfgs = {shape_key(i): dispatch._tuned_cfg("gemm", i) for i in hot}
    plan_dir = export_plan(warm_plan, tmp / "store.jsonl.plan", store=store)

    ratio = float("inf")
    t_warm = t_cold = 0.0
    attempts = 0
    install_load_ms = 0.0
    for attempts in range(1, 6):    # retry: ambient noise must not fail CI
        install_serving(store=store)         # warm generation back in place
        t_warm = _time_per_call(resolve_hot_set, iters) / len(hot)

        # -- cold process: fresh store handle, plan LOADED not compiled ----
        clear_store()
        cold_store = RecordStore.open(store_path)
        t0 = time.perf_counter()
        install_serving(store=cold_store, plan_dir=plan_dir)
        install_load_ms = (time.perf_counter() - t0) * 1e3
        assert serving_state().plan.source == "loaded"
        t_cold = _time_per_call(resolve_hot_set, iters) / len(hot)
        ratio = t_cold / t_warm
        if ratio <= COLD_WARM_THRESHOLD:
            break

    cold_cfgs = {shape_key(i): dispatch._tuned_cfg("gemm", i) for i in hot}
    identical = cold_cfgs == warm_cfgs

    rows = [
        {"process": "warm (plan compiled at install)",
         "us/call": f"{t_warm*1e6:.2f}", "install ms": "-"},
        {"process": "cold (plan loaded from artifact)",
         "us/call": f"{t_cold*1e6:.2f}",
         "install ms": f"{install_load_ms:.1f}"},
    ]
    print(table(rows, ["process", "us/call", "install ms"],
                "E16 — cold start from a golden plan artifact"))
    print(f"\ncold/warm resolution ratio {ratio:.3f} "
          f"(gate <= {COLD_WARM_THRESHOLD}); configs identical: "
          f"{identical}; artifact install {install_load_ms:.1f} ms vs "
          f"compile install {install_compile_ms:.1f} ms "
          f"({attempts} timing attempt(s))")
    _reset()
    return {"warm_us": t_warm * 1e6, "cold_us": t_cold * 1e6,
            "ratio": ratio, "identical_configs": identical,
            "hot_shapes": len(hot), "attempts": attempts,
            "install_load_ms": install_load_ms,
            "install_compile_ms": install_compile_ms,
            "threshold": COLD_WARM_THRESHOLD,
            "pass": bool(ratio <= COLD_WARM_THRESHOLD and identical)}


# ---------------------------------------------------------------------------
# 2. synthetic 3-replica fleet: publish -> every replica swaps, never torn
# ---------------------------------------------------------------------------

class _Replica:
    """One synthetic serving replica: a private atomically-swapped plan ref.

    (Serving state is process-global, so the fleet is modeled with the
    follower's injectable install target — the swap is the same single
    reference assignment ``install_serving`` performs.)
    """

    def __init__(self, name: str):
        self.name = name
        self.installed = None           # (plan, generation), one ref
        self.resolutions = 0
        self.torn = 0
        self.stale = 0
        self._last_gen = 0

    def install(self, plan, pointer) -> bool:
        self.installed = (plan, int(pointer["generation"]))
        return True

    def current_plan(self):
        got = self.installed
        return got[0] if got else None

    def read(self, shapes) -> None:
        """One reader pass: every entry of the grabbed plan must carry the
        SAME generation marker (torn check), and the installed generation
        must never decrease (stale check)."""
        got = self.installed
        if got is None:
            return
        plan, gen = got
        if gen < self._last_gen:
            self.stale += 1
        self._last_gen = max(self._last_gen, gen)
        markers = set()
        for inputs in shapes:
            entry = plan.lookup("gemm", shape_key(inputs))
            if entry is not None:
                markers.add(entry[0]["g"])
                self.resolutions += 1
        if len(markers) > 1:            # mixed generations in one plan read
            self.torn += 1


def _make_plan(gen_marker: int, shapes) -> DispatchPlan:
    tbl = {("gemm", shape_key(i)): (dict(CFG, g=gen_marker), "exact")
           for i in shapes}
    return DispatchPlan(generation=0, fingerprint="sim", store_version=-1,
                        table=tbl)


def _bench_fleet(fast: bool, tmp: Path) -> dict:
    generations = 6 if fast else 12
    shapes = [gemm_input(128 * (i + 1), 64, 512) for i in range(16)]
    registry = PlanRegistry(tmp / "registry")

    replicas = [_Replica(f"replica-{i}") for i in range(REPLICAS)]
    followers = [PlanFollower(registry, poll_s=0.005, name=r.name,
                              install=r.install, current_plan=r.current_plan)
                 for r in replicas]
    stop = threading.Event()

    def reader(replica: _Replica) -> None:
        while not stop.is_set():
            replica.read(shapes)

    readers = [threading.Thread(target=reader, args=(r,), daemon=True)
               for r in replicas]
    for f in followers:
        f.start()
    for t in readers:
        t.start()

    t0 = time.perf_counter()
    for gen in range(1, generations + 1):   # the coordinator's retune loop
        manifest = registry.publish(_make_plan(gen, shapes))
        assert manifest.generation == gen
        time.sleep(0.02)

    deadline = time.time() + 30.0
    while time.time() < deadline and any(
            f.generation < generations for f in followers):
        time.sleep(0.01)
    wall_s = time.perf_counter() - t0
    stop.set()
    for t in readers:
        t.join(timeout=5.0)
    for f in followers:
        f.stop()

    converged = all(f.generation == generations for f in followers)
    torn = sum(r.torn for r in replicas)
    stale = sum(r.stale for r in replicas) + sum(
        f.refused_stale for f in followers)
    resolutions = sum(r.resolutions for r in replicas)
    lag_s = max((f.lag_s or 0.0) for f in followers)

    rows = [{"replica": r.name,
             "generation": f.generation,
             "installs": f.installs,
             "resolutions": r.resolutions,
             "torn": r.torn, "stale": r.stale}
            for r, f in zip(replicas, followers)]
    print(table(rows, ["replica", "generation", "installs", "resolutions",
                       "torn", "stale"],
                "E16 — 3-replica plan-following fleet"))
    print(f"\n{generations} generations propagated to {REPLICAS} replicas "
          f"in {wall_s:.2f}s (max publish->install lag {lag_s*1e3:.0f} ms); "
          f"{resolutions} concurrent resolutions, {torn} torn, "
          f"{stale} stale")
    return {"generations": generations, "replicas": REPLICAS,
            "converged": converged, "torn": torn, "stale": stale,
            "resolutions": resolutions, "wall_s": wall_s,
            "max_lag_s": lag_s,
            "pass": bool(converged and torn == 0 and stale == 0
                         and resolutions > 0)}


def run(fast: bool = True) -> dict:
    tmp = Path(tempfile.mkdtemp(prefix="bench_plans_"))
    try:
        resolution = _bench_cold_start(fast, tmp)
        fleet = _bench_fleet(fast, tmp)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    out = {"resolution": resolution, "fleet": fleet,
           "pass": bool(resolution["pass"] and fleet["pass"])}
    save("plans", out)
    print(f"\nE16 verdict: {'PASS' if out['pass'] else 'FAIL'}")
    return out


if __name__ == "__main__":
    run()
