"""E11 — model-guided dispatch: resolution quality and hot-path overhead.

Two CI gates guard serving the learned performance model (tunedb/model.py)
from the dispatch path:

  1. QUALITY — on *held-out* input shapes (absent from the store, so the
     exact-hit tier cannot serve them), the model-guided pick must reach
     >= 90% of the oracle-best measured TFLOPS (geomean).  The oracle is an
     exhaustive noise-free scan of every legal config — the "10 hours on
     hardware" baseline of §6.  Nearest-neighbor and vendor-heuristic picks
     are reported alongside: the claim worth gating is that the regressor
     generalizes across input shapes, not just that it exists.

  2. OVERHEAD — on the interpret-mode dispatch path, steady-state
     model-guided resolution (a per-shape memo hit after the first
     §6 search) must add < 10% of a dispatch call over plain
     nearest-neighbor resolution.  The one-time cold search cost is
     reported for context; it is paid once per novel shape.

The training store mirrors what a tuning fleet accumulates: one tuned best
per hot shape, the session's measured top-k (source="sample"), plus
exploration samples (model.collect_samples) — then `train_models` distills
it exactly as ``python -m repro.tunedb train`` would.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.backend import SimulatedTPUBackend
from repro.core.heuristics import VendorHeuristicLibrary
from repro.core.search import enumerate_legal
from repro.core.space import GEMM_SPACE, gemm_input
from repro.core.tuner import clear_tuners
from repro.kernels import dispatch
from repro.tunedb import (RecordStore, TuneRecord, clear_store,
                          clear_telemetry, install_store)
from repro.tunedb.model import (ModelSet, clear_models, collect_samples,
                                install_models, train_models)
from repro.tunedb.session import backend_fingerprint

from .common import save, table

QUALITY_THRESHOLD = 0.90        # geomean fraction of oracle-best TFLOPS
OVERHEAD_THRESHOLD = 0.10       # added resolution cost / dispatch call

# the tuned grid a fleet would have covered (hot shapes) ...
TRAIN_SHAPES = [(m, n, k)
                for m in (256, 1024, 4096)
                for n in (16, 32, 64, 128, 256, 512, 1024)
                for k in (512, 2560)]
# ... and the off-grid shapes serving traffic springs on it
HELDOUT_SHAPES = [(512, 64, 2560), (2048, 32, 1024), (768, 192, 768),
                  (1536, 128, 1536), (3072, 16, 2048), (640, 512, 640)]


def _geomean(xs) -> float:
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-9)))))


def _build_store(label: SimulatedTPUBackend, fp: str, topk_samples: int
                 ) -> RecordStore:
    """One tuned best + measured top-k per train shape (a session's output)."""
    store = RecordStore()
    for m, n, k in TRAIN_SHAPES:
        inputs = gemm_input(m, n, k)
        legal = enumerate_legal(GEMM_SPACE, inputs)
        scored = sorted(((c, label.measure("gemm", c, inputs)) for c in legal),
                        key=lambda t: -t[1])
        best_cfg, best_tf = scored[0]
        store.add(TuneRecord(space="gemm", inputs=inputs, config=best_cfg,
                             tflops=best_tf, backend=fp, source="session"))
        for cfg, tf in scored[1:1 + topk_samples]:
            store.add(TuneRecord(space="gemm", inputs=inputs,
                                 config=dict(cfg), tflops=tf, backend=fp,
                                 source="sample"))
    return store


def _quality(store: RecordStore, models: ModelSet, fp: str,
             label: SimulatedTPUBackend) -> dict:
    oracle = SimulatedTPUBackend(noise=0.0)
    vendor = VendorHeuristicLibrary.gemm(GEMM_SPACE)
    rows, ratios, pure, nn_ratios, heur_ratios = [], [], [], [], []
    pure_models = ModelSet()            # same weights, no re-measure pass
    pure_models.models = models.models
    for m, n, k in HELDOUT_SHAPES:
        inputs = gemm_input(m, n, k)
        cands = enumerate_legal(GEMM_SPACE, inputs)
        best = max(oracle.measure("gemm", c, inputs) for c in cands)

        cfg, _ = models.predict("gemm", inputs, backend=fp)
        r_model = oracle.measure("gemm", cfg, inputs) / best
        p_cfg, _ = pure_models.predict("gemm", inputs, backend=fp)
        r_pure = oracle.measure("gemm", p_cfg, inputs) / best
        rec = store.nearest("gemm", inputs, backend=fp)
        r_nn = (oracle.measure("gemm", rec.config, inputs) / best
                if rec else 0.0)
        r_heur = oracle.measure("gemm", vendor.select(inputs), inputs) / best

        ratios.append(r_model)
        pure.append(r_pure)
        nn_ratios.append(r_nn)
        heur_ratios.append(r_heur)
        rows.append({"shape": f"{m}x{n}x{k}",
                     "model": f"{r_model:.3f}",
                     "model (no re-measure)": f"{r_pure:.3f}",
                     "nearest": f"{r_nn:.3f}",
                     "heuristic": f"{r_heur:.3f}",
                     "legal configs": len(cands)})
    g = _geomean(ratios)
    print(table(rows, ["shape", "model", "model (no re-measure)", "nearest",
                       "heuristic", "legal configs"],
                "E11 — fraction of oracle-best TFLOPS on held-out shapes"))
    print(f"\ngeomean: model {g:.3f} | pure model {_geomean(pure):.3f} | "
          f"nearest {_geomean(nn_ratios):.3f} | "
          f"heuristic {_geomean(heur_ratios):.3f}")
    return {"geomean": g, "geomean_pure_model": _geomean(pure),
            "geomean_nearest": _geomean(nn_ratios),
            "geomean_heuristic": _geomean(heur_ratios),
            "min": float(min(ratios)), "rows": rows,
            "threshold": QUALITY_THRESHOLD,
            "pass": g >= QUALITY_THRESHOLD}


def _time_per_call(fn, iters: int) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def _overhead(models: ModelSet, fast: bool) -> dict:
    """Interpret-mode dispatch: model-tier resolution vs nearest-neighbor."""
    import jax.numpy as jnp
    iters = 300 if fast else 3000
    CFG = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
           "order": 0, "acc32": 1, "prefetch": 2}

    # a small store whose records neighbor (but never exactly hit) the
    # dispatched shape, as in bench_tunedb
    store = RecordStore()
    for m in (64, 128, 256, 512):
        for k in (128, 256, 512):
            store.add(TuneRecord(space="gemm",
                                 inputs=gemm_input(m, 128, k, 32),
                                 config=CFG, tflops=1.0))
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(96, 192)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(192, 128)), jnp.float32)
    inputs = gemm_input(96, 128, 192, 32)    # novel: no exact record

    install_store(store)
    clear_models()
    t_dispatch = _time_per_call(
        lambda: np.asarray(dispatch.matmul(a, b, prefer_kernel=True)),
        max(iters // 60, 5))
    t_nn = _time_per_call(
        lambda: dispatch._tuned_cfg("gemm", inputs), iters)

    install_models(models)
    t_cold0 = time.perf_counter()
    assert dispatch._tuned_cfg("gemm", inputs) is not None
    t_cold = time.perf_counter() - t_cold0       # one §6 search + re-measure
    t_model = _time_per_call(
        lambda: dispatch._tuned_cfg("gemm", inputs), iters)
    clear_models()
    clear_store()

    added = max(t_model - t_nn, 0.0) / t_dispatch
    rows = [
        {"path": "interpret dispatch (kernel call)",
         "cost": f"{t_dispatch*1e3:.2f} ms"},
        {"path": "resolution: nearest-neighbor (memoized)",
         "cost": f"{t_nn*1e6:.1f} us"},
        {"path": "resolution: model-guided (memoized)",
         "cost": f"{t_model*1e6:.1f} us"},
        {"path": "resolution: model-guided (cold, once per novel shape)",
         "cost": f"{t_cold*1e3:.1f} ms"},
    ]
    print()
    print(table(rows, ["path", "cost"],
                "E11 — dispatch-path resolution overhead"))
    print(f"\nmodel tier adds {added*100:.3f}% of a dispatch call "
          f"(gate < {OVERHEAD_THRESHOLD:.0%})")
    return {"dispatch_ms": t_dispatch * 1e3, "nn_resolve_us": t_nn * 1e6,
            "model_resolve_us": t_model * 1e6, "cold_model_ms": t_cold * 1e3,
            "added_frac": added, "threshold": OVERHEAD_THRESHOLD,
            "pass": added < OVERHEAD_THRESHOLD}


def run(fast: bool = True) -> dict:
    clear_tuners()
    clear_store()
    clear_models()
    clear_telemetry()

    label = SimulatedTPUBackend(noise=0.03)
    fp = backend_fingerprint(label)
    topk, per_shape, epochs = (14, 80, 120) if fast else (30, 150, 200)

    t0 = time.time()
    store = _build_store(label, fp, topk)
    n = collect_samples(store, label, per_shape=per_shape, seed=0)
    print(f"[model] store: {len(store)} tuned shapes, "
          f"{store.n_samples} samples ({n} exploration) "
          f"in {time.time()-t0:.1f}s")
    t0 = time.time()
    models = train_models(store, epochs=epochs, hidden=(64, 128, 64), seed=0)
    models.measurer = label.measure     # §6 top-k re-measurement at serve
    pm = models.resolve_model("gemm", fp)
    print(f"[model] trained on {pm.meta['n_samples']} samples, "
          f"val mse {pm.meta['val_mse']:.4f} in {time.time()-t0:.1f}s\n")

    quality = _quality(store, models, fp, label)
    overhead = _overhead(models, fast)

    ok = quality["pass"] and overhead["pass"]
    print(f"\nacceptance: quality {'PASS' if quality['pass'] else 'FAIL'} "
          f"(geomean {quality['geomean']:.3f} >= {QUALITY_THRESHOLD}), "
          f"overhead {'PASS' if overhead['pass'] else 'FAIL'} "
          f"({overhead['added_frac']*100:.3f}% < {OVERHEAD_THRESHOLD:.0%})")
    payload = {"quality": quality, "overhead": overhead, "pass": ok}
    save("model", payload)
    clear_telemetry()
    return payload


if __name__ == "__main__":
    run()
