"""E14 — frozen dispatch plans: zero-overhead serving resolution.

The PR-5 tentpole claim, gated three ways:

  1. RESOLUTION — with the frozen DispatchPlan installed, steady-state
     ``_tuned_cfg`` over a realistic hot set (a mix of exact-record hits
     and nearest-served novel shapes) must cost <= 20% of the PR-4 path
     (the same serving state installed with ``build_plan=False``: sha1
     input keys, per-tier probes, memoized neighbor scans).

  2. NEAREST — the log2-bucketed ``nearest()`` index on a 10k-record
     store must answer un-memoized queries >= 5x faster than the linear
     reference scan (``_nearest_linear``), and answer them identically
     (same distance, or both None).

  3. ADMISSION — store-aware admission (pad a work shape up to a tuned
     record when the recorded-TFLOPS arithmetic says the overhead beats
     the untuned config) must lift geomean dispatched TFLOPS on a
     mixed-shape synthetic batch vs shape-agnostic batching, with no
     single shape served worse.  Realized throughput is scored by the
     noise-free simulator: padded items deliver the tuned config's
     throughput at the padded shape scaled by the useful-work fraction.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.backend import SimulatedTPUBackend
from repro.core.search import enumerate_legal
from repro.core.space import GEMM_SPACE, gemm_input
from repro.core.tuner import clear_tuners
from repro.kernels import dispatch
from repro.serve.engine import StoreAwareAdmission
from repro.tunedb import (RecordStore, TuneRecord, clear_store,
                          clear_telemetry, get_telemetry, install_serving,
                          serving_state)
from repro.tunedb.model import clear_models

from .common import save, table

RESOLUTION_THRESHOLD = 0.20     # plan path as a fraction of the PR-4 path
NEAREST_THRESHOLD = 5.0         # indexed speedup over the linear scan
ADMISSION_THRESHOLD = 1.0       # geomean TFLOPS lift must exceed this

CFG = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
       "order": 0, "acc32": 1, "prefetch": 2}

# the admission study's tuned "bucket grid" and its mixed-shape traffic:
# some shapes sit just above a bucket (badly quantized by their neighbor's
# block, cheap to pad), others are large/memory-bound (padding must be
# declined — the floor arithmetic has to keep them exact)
ADMISSION_BUCKETS = [256, 512, 1024, 2048, 4096]
ADMISSION_BATCH = [270, 330, 530, 550, 700, 1050, 1100, 1500,
                   2100, 2200, 3000, 4200]


def _geomean(xs) -> float:
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-9)))))


def _time_per_call(fn, iters: int) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


# ---------------------------------------------------------------------------
# 1. steady-state resolution: frozen plan vs the PR-4 slow path
# ---------------------------------------------------------------------------

def _bench_resolution(fast: bool) -> dict:
    store = RecordStore()
    tuned = [gemm_input(256 * (i + 1), 64, 1024) for i in range(8)]
    for inputs in tuned:
        store.add(TuneRecord(space="gemm", inputs=inputs, config=CFG,
                             tflops=100.0, backend="sim"))
    # the serving reality: half the hot set is tuned, half rides neighbors
    novel = [gemm_input(256 * (i + 1) + 48, 64, 1024) for i in range(8)]
    hot = tuned + novel
    tel = get_telemetry()
    for inputs in hot:
        tel.record("gemm", inputs, n=10)

    iters = 4000 if fast else 20000

    def resolve_hot_set():
        for inputs in hot:
            dispatch._tuned_cfg("gemm", inputs)

    install_serving(store=store)                 # plan compiled at install
    plan = serving_state().plan
    t_plan = _time_per_call(resolve_hot_set, iters) / len(hot)
    install_serving(store=store, build_plan=False)   # the PR-4 path
    t_legacy = _time_per_call(resolve_hot_set, iters) / len(hot)
    ratio = t_plan / t_legacy

    rows = [
        {"path": "frozen plan (tier-0 probe)", "us/call": f"{t_plan*1e6:.2f}"},
        {"path": "PR-4 tiers (sha1 key + memos)",
         "us/call": f"{t_legacy*1e6:.2f}"},
    ]
    print(table(rows, ["path", "us/call"],
                "E14 — steady-state hot-set resolution"))
    print(f"\nplan resolution is {ratio:.1%} of the PR-4 path "
          f"(gate <= {RESOLUTION_THRESHOLD:.0%}); plan covered "
          f"{len(plan)} shapes at install")
    return {"plan_us": t_plan * 1e6, "legacy_us": t_legacy * 1e6,
            "ratio": ratio, "hot_shapes": len(hot),
            "plan_entries": len(plan), "threshold": RESOLUTION_THRESHOLD,
            "pass": ratio <= RESOLUTION_THRESHOLD}


# ---------------------------------------------------------------------------
# 2. nearest(): log2-bucketed index vs the linear reference scan
# ---------------------------------------------------------------------------

def _bench_nearest(fast: bool) -> dict:
    from repro.tunedb.store import _shape_distance

    rng = np.random.default_rng(0)
    n_records = 10_000
    store = RecordStore()
    for _ in range(n_records):
        m, n, k = (int(2 ** rng.uniform(4, 14)) for _ in range(3))
        store.add(TuneRecord(space="gemm", inputs=gemm_input(m, n, k),
                             config=CFG, tflops=50.0, backend="sim"))
    queries = [gemm_input(*(int(2 ** rng.uniform(4, 14)) for _ in range(3)))
               for _ in range(40 if fast else 200)]

    # equivalence first: the index must answer what the scan answers
    mismatches = 0
    for q in queries:
        got = store._nearest_indexed("gemm", q, None, 2.0)
        want = store._nearest_linear("gemm", q, None, 2.0)
        if (got is None) != (want is None):
            mismatches += 1
        elif got is not None:
            d_got = _shape_distance(q, got.inputs)
            d_want = _shape_distance(q, want.inputs)
            if abs(d_got - d_want) > 1e-9:
                mismatches += 1

    t0 = time.perf_counter()
    for q in queries:
        store._nearest_indexed("gemm", q, None, 2.0)
    t_indexed = (time.perf_counter() - t0) / len(queries)
    t0 = time.perf_counter()
    for q in queries:
        store._nearest_linear("gemm", q, None, 2.0)
    t_linear = (time.perf_counter() - t0) / len(queries)
    speedup = t_linear / t_indexed

    rows = [
        {"lookup": "log2-bucketed index", "us/query": f"{t_indexed*1e6:.0f}"},
        {"lookup": "linear scan (pre-PR-5)", "us/query": f"{t_linear*1e6:.0f}"},
    ]
    print()
    print(table(rows, ["lookup", "us/query"],
                f"E14 — nearest() on a {n_records}-record store"))
    print(f"\nindexed nearest is {speedup:.1f}x the linear scan "
          f"(gate >= {NEAREST_THRESHOLD:.0f}x), {mismatches} mismatches "
          f"over {len(queries)} queries")
    return {"records": n_records, "queries": len(queries),
            "indexed_us": t_indexed * 1e6, "linear_us": t_linear * 1e6,
            "speedup": speedup, "mismatches": mismatches,
            "threshold": NEAREST_THRESHOLD,
            "pass": speedup >= NEAREST_THRESHOLD and mismatches == 0}


# ---------------------------------------------------------------------------
# 3. store-aware admission: geomean dispatched TFLOPS on a mixed batch
# ---------------------------------------------------------------------------

def _bench_admission(fast: bool) -> dict:
    oracle = SimulatedTPUBackend(noise=0.0)
    store = RecordStore()
    for m in ADMISSION_BUCKETS:
        inputs = gemm_input(m, 64, 1024)
        cfg, tflops = max(
            ((c, oracle.measure("gemm", c, inputs))
             for c in enumerate_legal(GEMM_SPACE, inputs)),
            key=lambda t: t[1])
        store.add(TuneRecord(space="gemm", inputs=inputs, config=dict(cfg),
                             tflops=tflops, backend="sim"))
    install_serving(store=store)
    admission = StoreAwareAdmission()

    rows, agnostic, aware = [], [], []
    for m in ADMISSION_BATCH:
        inputs = gemm_input(m, 64, 1024)
        cfg = dispatch._tuned_cfg("gemm", inputs)
        baseline = oracle.measure("gemm", cfg, inputs)
        shape, how = admission.bucket("gemm", inputs)
        if how == "padded":
            padded_cfg = dispatch._tuned_cfg("gemm", shape)
            realized = (oracle.measure("gemm", padded_cfg, shape)
                        * (m / shape["M"]))
        else:
            realized = baseline
        agnostic.append(baseline)
        aware.append(realized)
        rows.append({"M": m, "decision": how,
                     "agnostic": f"{baseline:.1f}",
                     "store-aware": f"{realized:.1f}"})

    g_agn, g_aware = _geomean(agnostic), _geomean(aware)
    lift = g_aware / g_agn
    regressions = sum(1 for a, s in zip(agnostic, aware) if s < a - 1e-9)
    print()
    print(table(rows, ["M", "decision", "agnostic", "store-aware"],
                "E14 — dispatched TFLOPS, mixed-shape batch (N=64, K=1024)"))
    print(f"\ngeomean {g_agn:.1f} -> {g_aware:.1f} TFLOPS "
          f"(lift {lift:.3f}, gate > {ADMISSION_THRESHOLD:.1f}); "
          f"{admission.padded} padded / {admission.exact} exact, "
          f"{regressions} regressed shape(s)")
    return {"geomean_agnostic": g_agn, "geomean_aware": g_aware,
            "lift": lift, "padded": admission.padded,
            "exact": admission.exact, "regressions": regressions,
            "threshold": ADMISSION_THRESHOLD,
            "pass": lift > ADMISSION_THRESHOLD and regressions == 0}


def run(fast: bool = True) -> dict:
    clear_tuners()
    clear_store()
    clear_models()
    clear_telemetry()

    resolution = _bench_resolution(fast)
    clear_store()
    clear_telemetry()
    nearest = _bench_nearest(fast)
    admission = _bench_admission(fast)

    ok = resolution["pass"] and nearest["pass"] and admission["pass"]
    print(f"\nacceptance: resolution "
          f"{'PASS' if resolution['pass'] else 'FAIL'} "
          f"({resolution['ratio']:.1%} <= {RESOLUTION_THRESHOLD:.0%}), "
          f"nearest {'PASS' if nearest['pass'] else 'FAIL'} "
          f"({nearest['speedup']:.1f}x >= {NEAREST_THRESHOLD:.0f}x), "
          f"admission {'PASS' if admission['pass'] else 'FAIL'} "
          f"(lift {admission['lift']:.3f} > {ADMISSION_THRESHOLD:.1f})")
    payload = {"resolution": resolution, "nearest": nearest,
               "admission": admission, "pass": ok}
    save("dispatch", payload)
    clear_store()
    clear_telemetry()
    return payload


if __name__ == "__main__":
    run()
