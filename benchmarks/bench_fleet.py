"""E13 — distributed tuning fleet: coordinator + sharded workers.

The fleet exists so tuning throughput scales with hardware instead of being
pinned to one in-process session loop (the paper's "a few hours of
auto-tuning" budget, MITuna's worker-fleet shape).  Two gates:

  1. THROUGHPUT — on a synthetic plan whose per-job cost is a fixed
     simulated measurement latency (so the benchmark times the
     *coordination fabric*: lease claims, heartbeats, shard appends,
     merges — not the tuner's Python search), a 4-worker fleet must reach
     >= 3x the job throughput of a single-worker session over the same
     plan.

  2. EQUIVALENCE — distribution must be invisible in the artifact: the
     fleet-merged parent store must be record-equivalent (same config and
     TFLOPS per (space, shape, backend), same measurement-log size) to a
     serial session over the same plan, with provenance preserved
     (``source`` intact, ``merged_from`` = the shard that measured it).
"""

from __future__ import annotations

import tempfile
import time
from pathlib import Path

from repro.core.backend import SimulatedTPUBackend
from repro.core.search import SearchResult, enumerate_legal
from repro.core.space import GEMM_SPACE, gemm_input
from repro.tunedb import RecordStore
from repro.tunedb.fleet import FleetJob, run_fleet_inline
from repro.tunedb.session import TuningSession

from .common import save, table

SPEEDUP_THRESHOLD = 3.0
N_WORKERS = 4
# simulated per-job measurement latency.  Real tuning jobs run seconds to
# minutes (top-k re-measurement on hardware); 100 ms is already a severe
# stress on the coordination fabric (lease claim + heartbeat + shard append
# + sync + done marker cost ~10-15 ms of filesystem work per job).
JOB_COST_S = 0.15


def _plan(fast: bool):
    ms = (256, 512, 1024, 2048) if fast else (256, 384, 512, 768, 1024, 2048)
    ns = (16, 64, 256)
    ks = (512, 2560) if fast else (512, 1024, 2560, 4096)
    return [gemm_input(m, n, k) for m in ms for n in ns for k in ks]


class _PlanTuner:
    """Deterministic fixed-latency tuner over a precomputed config table.

    Each ``search`` costs exactly ``JOB_COST_S`` of (GIL-releasing)
    simulated measurement latency — the job cost is identical for the
    serial session and every fleet worker, so the throughput ratio
    measures the fleet fabric, nothing else.
    """

    def __init__(self, answers):
        self.space = GEMM_SPACE
        self.backend = SimulatedTPUBackend(noise=0.0)
        self.answers = answers

    def search(self, inputs, remeasure=True):
        time.sleep(JOB_COST_S)
        cfg, tf = self.answers[tuple(sorted(inputs.items()))]
        return SearchResult(best=dict(cfg), predicted_tflops=tf,
                            measured_tflops=tf, top_k=[(dict(cfg), tf)],
                            n_candidates=1, measured=[(dict(cfg), tf)])


def _store_view(store: RecordStore):
    return {(r.space, r.key, r.backend): (r.config, round(r.tflops, 9))
            for r in store.records()}


def run(fast: bool = True) -> dict:
    shapes = _plan(fast)
    backend = SimulatedTPUBackend(noise=0.0)
    answers = {}
    for inputs in shapes:               # config table, outside all timing
        cfg = enumerate_legal(GEMM_SPACE, inputs)[0]
        answers[tuple(sorted(inputs.items()))] = (
            cfg, float(backend.measure("gemm", cfg, inputs)))
    print(f"[fleet] synthetic plan: {len(shapes)} jobs x "
          f"{JOB_COST_S*1e3:.0f} ms simulated measurement each")

    with tempfile.TemporaryDirectory(prefix="bench-fleet-") as tmp:
        tmp = Path(tmp)
        # baseline: ONE session worker grinding the plan serially
        serial_store = RecordStore.open(tmp / "serial.jsonl")
        session = TuningSession(_PlanTuner(answers), serial_store, None,
                                workers=1, source="fleet")
        t0 = time.perf_counter()
        serial_report = session.run(shapes=shapes)
        t_serial = time.perf_counter() - t0
        tput_serial = serial_report.tuned / t_serial

        # the fleet: same plan, 4 workers, lease-file coordination.
        # Best of two repetitions: an ambient scheduler stall landing inside
        # the (short) fleet window must not fail a throughput gate the
        # fabric actually clears.
        best = None
        for rep in range(2):
            fleet_store = RecordStore.open(tmp / f"fleet{rep}.jsonl")
            report = run_fleet_inline(
                tmp / f"fleet{rep}", fleet_store,
                [FleetJob(space="gemm", inputs=s) for s in shapes],
                n_workers=N_WORKERS, tuners={"gemm": _PlanTuner(answers)})
            if best is None or report.wall_s < best[1].wall_s:
                best = (fleet_store, report)
        fleet_store, report = best
        tput_fleet = report.done / report.wall_s
        speedup = tput_fleet / tput_serial

        rows = [
            {"run": "single-session (1 worker)",
             "jobs": serial_report.tuned, "wall": f"{t_serial:.2f} s",
             "jobs/s": f"{tput_serial:.2f}"},
            {"run": f"fleet ({N_WORKERS} workers)",
             "jobs": report.done, "wall": f"{report.wall_s:.2f} s",
             "jobs/s": f"{tput_fleet:.2f}"},
        ]
        print()
        print(table(rows, ["run", "jobs", "wall", "jobs/s"],
                    "E13 — tuning-job throughput, same synthetic plan"))
        print(f"\nspeedup {speedup:.2f}x "
              f"(gate >= {SPEEDUP_THRESHOLD:.0f}x with {N_WORKERS} workers)")

        equivalent = _store_view(fleet_store) == _store_view(serial_store)
        same_log = (len(fleet_store.training_records())
                    == len(serial_store.training_records()))
        provenance = all(r.source == "fleet" and r.merged_from
                         for r in fleet_store.records())
        print(f"record-equivalence: views {'match' if equivalent else 'DIFFER'}"
              f", log sizes {'match' if same_log else 'DIFFER'}, provenance "
              f"{'preserved' if provenance else 'LOST'}")

        ok = (speedup >= SPEEDUP_THRESHOLD and report.failed == 0
              and equivalent and same_log and provenance)
        payload = {
            "speedup": {
                "serial_jobs_per_s": tput_serial,
                "fleet_jobs_per_s": tput_fleet,
                "speedup": speedup,
                "workers": N_WORKERS,
                "jobs": len(shapes),
                "job_cost_s": JOB_COST_S,
                "threshold": SPEEDUP_THRESHOLD,
                "pass": speedup >= SPEEDUP_THRESHOLD and report.failed == 0,
            },
            "equivalence": {
                "records_serial": len(serial_store),
                "records_fleet": len(fleet_store),
                "views_match": equivalent,
                "log_sizes_match": same_log,
                "provenance_preserved": provenance,
                "pass": equivalent and same_log and provenance,
            },
            "fleet_report": report.to_dict(),
            "pass": ok,
        }
    print(f"\nacceptance: {'PASS' if ok else 'FAIL'}")
    save("fleet", payload)
    return payload


if __name__ == "__main__":
    run()
