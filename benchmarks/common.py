"""Shared benchmark infrastructure: tuner training cache, result sinks."""

from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "bench"


def save(name: str, payload: dict) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(payload, indent=1))


def table(rows: List[Dict], cols: List[str], title: str = "") -> str:
    out = []
    if title:
        out.append(f"### {title}\n")
    out.append("| " + " | ".join(cols) + " |")
    out.append("|" + "---|" * len(cols))
    for r in rows:
        out.append("| " + " | ".join(str(r.get(c, "")) for c in cols) + " |")
    return "\n".join(out)


_TUNERS = {}


def get_trained_tuner(space_name: str, *, fast: bool = True, seed: int = 0):
    """Train (once per process) an InputAwareTuner for a space."""
    from repro.core.backend import SimulatedTPUBackend
    from repro.core.space import SPACES
    from repro.core.tuner import InputAwareTuner
    key = (space_name, fast, seed)
    if key not in _TUNERS:
        n = 8000 if fast else 50000
        epochs = 25 if fast else 60
        hidden = (64, 128, 64) if fast else (64, 128, 256, 128, 64)
        t0 = time.time()
        _TUNERS[key] = InputAwareTuner.train(
            SPACES[space_name], n_samples=n, hidden=hidden, epochs=epochs,
            backend=SimulatedTPUBackend(noise=0.03), seed=seed)
        print(f"[tuner:{space_name}] trained on {n} samples "
              f"in {time.time()-t0:.1f}s")
    return _TUNERS[key]
