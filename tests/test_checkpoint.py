"""Checkpointing: atomic roundtrip, gc, async, resume integration."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import latest_step, load_checkpoint, save_checkpoint


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "layers": {"pos0": {"wq": jnp.ones((2, 4, 4))}}},
            "step_rng": jax.random.PRNGKey(seed + 1)}


def test_roundtrip(tmp_path):
    s = _state()
    save_checkpoint(str(tmp_path), 10, s, data_step=10)
    template = jax.tree_util.tree_map(lambda x: np.zeros(x.shape, x.dtype), s)
    s2, step, dstep = load_checkpoint(str(tmp_path), template)
    assert step == 10 and dstep == 10
    np.testing.assert_array_equal(np.asarray(s["params"]["w"]),
                                  s2["params"]["w"])


def test_latest_and_gc(tmp_path):
    s = _state()
    for step in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), step, s, keep=3)
    assert latest_step(str(tmp_path)) == 5
    # only 3 kept
    kept = sorted(int(p.name.split("-")[1])
                  for p in tmp_path.glob("step-*"))
    assert kept == [3, 4, 5]


def test_async_save(tmp_path):
    s = _state()
    t = save_checkpoint(str(tmp_path), 7, s, async_save=True)
    t.join()
    assert latest_step(str(tmp_path)) == 7


def test_elastic_reshard_shapes(tmp_path):
    """Loading places arrays against provided shardings (1-device mesh here;
    the mechanism is mesh-size agnostic)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    s = _state()
    save_checkpoint(str(tmp_path), 1, s)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    template = jax.tree_util.tree_map(lambda x: np.zeros(x.shape, x.dtype), s)
    shardings = jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, P()), template)
    s2, _, _ = load_checkpoint(str(tmp_path), template, shardings=shardings)
    assert s2["params"]["w"].sharding.mesh.shape == {"data": 1, "model": 1}
