"""Generative model (paper §4): categorical sampler vs uniform baseline."""

import numpy as np

from repro.core.generative import CategoricalSampler, workload_inputs
from repro.core.space import GEMM_SPACE, CONV_SPACE


def test_acceptance_beats_uniform(rng):
    """Paper Table 1 analogue: the fitted categorical model accepts several
    times more often than uniform sampling.  (The paper reports 200x on its
    GPU space whose uniform acceptance is 0.1%; our TPU space accepts ~6%
    uniformly, so the attainable ratio is bounded by ~17x — the benchmark
    discusses this difference, the test checks the mechanism.)"""
    inputs = workload_inputs(GEMM_SPACE, 64, rng)
    sampler = CategoricalSampler(space=GEMM_SPACE).fit(inputs, 30000, rng)
    acc_cat = sampler.acceptance_rate(inputs, 1500, rng)
    acc_uni = sampler.acceptance_rate(inputs, 1500, rng, uniform=True)
    assert acc_cat > 2.5 * max(acc_uni, 1e-4), (acc_cat, acc_uni)


def test_dirichlet_prior_no_zero_probability(rng):
    inputs = workload_inputs(GEMM_SPACE, 16, rng)
    sampler = CategoricalSampler(space=GEMM_SPACE, alpha=100.0)
    sampler.fit(inputs, 500, rng)
    for name in GEMM_SPACE.param_names:
        assert (sampler.probs(name) > 0).all()     # alpha > 0 => no zeros


def test_sample_legal_terminates(rng):
    inputs = workload_inputs(GEMM_SPACE, 8, rng)
    sampler = CategoricalSampler(space=GEMM_SPACE).fit(inputs, 1000, rng)
    cfg = sampler.sample_legal(inputs[0], rng)
    assert cfg is not None and GEMM_SPACE.is_legal(cfg, inputs[0])


def test_persistence_roundtrip(rng):
    inputs = workload_inputs(CONV_SPACE, 16, rng)
    sampler = CategoricalSampler(space=CONV_SPACE).fit(inputs, 500, rng)
    clone = CategoricalSampler.from_json(CONV_SPACE, sampler.to_json())
    for name in CONV_SPACE.param_names:
        np.testing.assert_allclose(sampler.probs(name), clone.probs(name))
