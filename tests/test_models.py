"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
config, runs one forward + one train step on CPU, asserts output shapes and
finiteness (spec deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, smoke_config, get_config
from repro.configs.shapes import SHAPES, applicable
from repro.models import (ModelConfig, decode_step, forward, init_cache,
                          init_params, loss_fn, prefill)
from repro.optim import AdamWConfig, adamw_init, adamw_update


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_frontend_tokens, cfg.d_model)),
            cfg.dtype)
    if cfg.is_encdec:
        batch["encoder_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_len, cfg.d_model)), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    B, S = batch["tokens"].shape

    x, aux = forward(params, cfg, batch)
    exp_S = S + (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    assert x.shape == (B, exp_S, cfg.d_model)
    assert np.isfinite(np.asarray(x, np.float32)).all()

    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    (loss, metrics), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, cfg, batch)
    assert np.isfinite(float(loss))
    new_params, opt, om = adamw_update(params, grads, opt, opt_cfg)
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(new_params),
        jax.tree_util.tree_leaves(params)))
    assert delta > 0 and np.isfinite(float(om["grad_norm"]))


@pytest.mark.parametrize("arch", ["glm4-9b", "jamba-v0.1-52b",
                                  "mamba2-1.3b", "dbrx-132b"])
def test_smoke_decode_matches_forward(arch):
    """prefill + decode == teacher-forced forward, per family."""
    import dataclasses
    # capacity_factor high enough that the training path drops no tokens:
    # MoE inference (decode path) is dropless by construction, so exact
    # train/decode agreement only holds in the no-drop regime.
    cfg = dataclasses.replace(smoke_config(arch), remat=False,
                              capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 12)), jnp.int32)
    x, _ = forward(params, cfg, {"tokens": toks})
    logits_fwd = jnp.einsum("bsd,vd->bsv", x, params["embed"]
                            )[..., : cfg.vocab]
    cache = init_cache(cfg, 2, 16)
    lg, cache = prefill(params, cfg, {"tokens": toks[:, :8]}, cache)
    np.testing.assert_allclose(np.asarray(lg[:, :cfg.vocab]),
                               np.asarray(logits_fwd[:, 7]),
                               rtol=5e-2, atol=5e-2)
    for t in range(8, 12):
        lg, cache = decode_step(params, cfg, toks[:, t:t + 1], cache,
                                jnp.asarray(t))
        np.testing.assert_allclose(np.asarray(lg[:, :cfg.vocab]),
                                   np.asarray(logits_fwd[:, t]),
                                   rtol=5e-2, atol=5e-2)


def test_whisper_decode_with_memory():
    cfg = smoke_config("whisper-base")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    from repro.models import encode
    memory = encode(cfg, params, batch["encoder_embeds"])
    cache = init_cache(cfg, 2, 16)
    lg, cache = prefill(params, cfg, batch | {"tokens": batch["tokens"][:, :8]},
                        cache)
    lg2, _ = decode_step(params, cfg, batch["tokens"][:, 8:9], cache,
                         jnp.asarray(8), memory=memory)
    assert lg2.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(lg2)).all()


def test_param_count_matches_init():
    """ModelConfig.param_count (the 6ND accounting) must agree with the
    actual initialized tree."""
    for arch in ["smollm-135m", "dbrx-132b", "mamba2-1.3b",
                 "jamba-v0.1-52b", "whisper-base"]:
        cfg = smoke_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree_util.tree_leaves(params))
        predicted = cfg.param_count
        assert abs(actual - predicted) / actual < 0.05, (
            arch, actual, predicted)


def test_full_config_dims_are_exact():
    """The full (dry-run) configs carry exactly the assigned dimensions."""
    spec = {
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v), arch


def test_applicability_rules():
    runnable = {a: [s for s in SHAPES
                    if applicable(get_config(a), s)[0]]
                for a in ARCH_NAMES}
    # long_500k only for SSM/hybrid
    assert "long_500k" in runnable["mamba2-1.3b"]
    assert "long_500k" in runnable["jamba-v0.1-52b"]
    assert "long_500k" not in runnable["llama3-405b"]
    # every arch runs the other three
    for a in ARCH_NAMES:
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(runnable[a])
    total = sum(len(v) for v in runnable.values())
    assert total == 32          # 40 cells - 8 rule-skipped
