"""Pipeline parallelism (gpipe over shard_map+ppermute).

Needs multiple devices, so the actual check runs in a subprocess with forced
host devices — the main test process must keep seeing ONE device.
"""

import subprocess
import sys

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import auto_axis_types, make_mesh
from repro.parallel.pipeline import pipeline_apply, stage_split

mesh = make_mesh((4,), ("stage",), axis_types=auto_axis_types(1))
n_layers, d = 8, 16
ws = jax.random.normal(jax.random.PRNGKey(0), (n_layers, d, d)) * 0.2

def layer(w, x):
    return jnp.tanh(x @ w)

def stage_fn(params, x):       # params: (layers_per_stage, d, d)
    for i in range(params.shape[0]):
        x = layer(params[i], x)
    return x

x = jax.random.normal(jax.random.PRNGKey(1), (6, 4, d))   # 6 microbatches
stage_params = stage_split(ws, 4)
got = pipeline_apply(stage_fn, stage_params, x, mesh=mesh)

ref = x
for i in range(n_layers):
    ref = layer(ws[i], ref)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=1e-5, atol=1e-5)
print("PP-OK")
"""


def test_gpipe_matches_sequential():
    r = subprocess.run([sys.executable, "-c", CHILD], capture_output=True,
                       text=True, timeout=300)
    assert "PP-OK" in r.stdout, r.stdout + r.stderr
