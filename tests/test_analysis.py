"""HLO collective parser + roofline math."""

import pytest

from repro.analysis.hlo import collective_bytes, parse_collectives
from repro.analysis.roofline import model_flops, roofline_from_artifacts
from repro.configs import SHAPES, get_config

HLO = """
HloModule jit_step
%fused (x: f32[8,16]) -> f32[8,16] { ... }
ENTRY %main {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %ag = f32[512,1024]{1,0} all-gather(%p0), channel_id=1, replica_groups={{0,1}}
  %ar = bf16[64,64]{1,0} all-reduce(%ag), channel_id=2
  %rs = f32[32,64]{1,0} reduce-scatter(%ar), channel_id=3
  %cp = bf16[16]{0} collective-permute(%rs), channel_id=4
  %tup = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%cp, %cp), channel_id=5
}
"""


def test_parse_collectives_kinds_and_bytes():
    ops = parse_collectives(HLO)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-to-all",
                     "collective-permute", "reduce-scatter"]
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.bytes == 512 * 1024 * 4


def test_normalization_halves_f32_only():
    raw = collective_bytes(HLO)
    norm = collective_bytes(HLO, normalize_bits=16)
    assert norm["all-gather"] == raw["all-gather"] // 2     # f32 -> bf16
    assert norm["all-reduce"] == raw["all-reduce"]          # already bf16
    assert norm["total"] < raw["total"]


def test_roofline_terms_and_bottleneck():
    art = {
        "arch": "x", "shape": "train_4k", "mesh": "pod", "chips": 256,
        "cost": {"flops": 1e15, "bytes_accessed": 1e11},
        "collectives": {"total": 1e9},
        "model_flops": 1e15 * 256 * 0.5,
    }
    rt = roofline_from_artifacts(art, recompute_model_flops=False)
    assert rt.bottleneck == "compute"
    assert rt.t_compute == pytest.approx(1e15 / 197e12)
    assert rt.useful_ratio == pytest.approx(0.5)
    assert 0 < rt.roofline_fraction <= 1.0


def test_model_flops_train_vs_decode():
    cfg = get_config("glm4-9b")
    t = model_flops(cfg, SHAPES["train_4k"], kind="train")
    d = model_flops(cfg, SHAPES["decode_32k"], kind="decode")
    # train ~ 6ND + attention ~ 7e16; decode ~ one token/seq
    assert 3e16 < t < 3e17 and d < 1e16
    # MoE uses active params
    moe = get_config("dbrx-132b")
    assert moe.active_param_count < 0.45 * moe.param_count
