"""Continuous retuning: telemetry epochs, drift-triggered sessions, atomic
store/model hot-swap — plus the serving-path fixes that ride along.

Pins the PR-3 contracts: ``snapshot``/``diff`` measure hot-shape mass drift
between telemetry epochs; engine tick counters recover true execution
frequencies under jit (not a compile census); a traffic shift drives the
RetuneController through session -> retrain -> ``install_serving`` without a
process restart; the swap is ONE atomic generation (a reader never sees a
torn store/model pair); and every install re-arms the warn-once degradation
latches.
"""

import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backend import SimulatedTPUBackend
from repro.core.space import GEMM_SPACE, gemm_input
from repro.core.tuner import InputAwareTuner, clear_tuners
from repro.kernels import dispatch
from repro.tunedb import (RecordStore, ShapeTelemetry, TuneRecord,
                          clear_store, clear_telemetry, get_store,
                          get_telemetry, install_generation, install_serving,
                          install_store, serving_state)
from repro.tunedb.controller import RetuneConfig, RetuneController
from repro.tunedb.model import ModelSet, clear_models, get_models
from repro.tunedb.session import backend_fingerprint
from repro.tunedb.__main__ import main as tunedb_main

CFG = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
       "order": 0, "acc32": 1, "prefetch": 2}


@pytest.fixture(autouse=True)
def _clean_globals():
    def reset():
        clear_tuners()
        clear_store()
        clear_models()
        clear_telemetry()
        dispatch.reset_fallback_warnings()
    reset()
    yield
    reset()


@pytest.fixture(scope="module")
def tiny_tuner():
    return InputAwareTuner.train(
        GEMM_SPACE, n_samples=600, hidden=(16, 16), epochs=4,
        backend=SimulatedTPUBackend(noise=0.02), seed=0)


def _rec(m, n, k, *, backend="test", bits=16):
    return TuneRecord(space="gemm", inputs=gemm_input(m, n, k, bits),
                      config=dict(CFG), tflops=100.0, backend=backend)


# ---------------------------------------------------------------------------
# telemetry epochs + drift
# ---------------------------------------------------------------------------

def test_snapshot_diff_steady_traffic_is_driftless():
    t = ShapeTelemetry()
    for _ in range(10):
        t.record("gemm", gemm_input(512, 16, 512))
        t.record("gemm", gemm_input(128, 128, 128))
    snap = t.snapshot()
    # the SAME mix keeps flowing: window distribution == baseline
    for _ in range(5):
        t.record("gemm", gemm_input(512, 16, 512))
        t.record("gemm", gemm_input(128, 128, 128))
    d = t.diff(snap)["gemm"]
    assert d.drift == pytest.approx(0.0)
    assert d.window_calls == 10
    assert d.prev_calls == 20
    # an empty window is no signal at all
    assert t.diff(t.snapshot())["gemm"].drift == 0.0


def test_snapshot_diff_detects_hot_mass_shift():
    t = ShapeTelemetry()
    old = gemm_input(512, 16, 512)
    for _ in range(20):
        t.record("gemm", old)
    snap = t.snapshot()
    new = gemm_input(4096, 16, 2560)
    for _ in range(20):
        t.record("gemm", new)
    d = t.diff(snap)["gemm"]
    assert d.drift == pytest.approx(1.0)          # window is 100% novel mass
    assert d.window_shapes[0] == (new, 20)
    # a half-shifted window: TV distance of {1.0 old} vs {.5 old, .5 new}
    for _ in range(20):
        t.record("gemm", old)
    d2 = t.diff(snap)["gemm"]
    assert d2.drift == pytest.approx(0.5)
    # a space born after the snapshot is all drift
    t.record("conv", {"N": 1, "H": 8, "W": 8, "C": 4, "K": 8, "R": 3,
                      "S": 3, "dtype_bits": 16})
    assert t.diff(snap)["conv"].drift == pytest.approx(1.0)


def test_telemetry_count_normalizes_and_locks():
    t = ShapeTelemetry()
    t.record("gemm", {"M": 512, "N": 16, "K": 512, "dtype_bits": 16,
                      "trans_a": 0, "trans_b": 0})
    # float-valued dims (JSON round trips) hit the same bucket
    assert t.count("gemm", {"M": 512.0, "N": 16.0, "K": 512.0,
                            "dtype_bits": 16.0, "trans_a": 0.0,
                            "trans_b": 0.0}) == 1


def test_telemetry_merge_is_safe_under_concurrent_records():
    src, dst = ShapeTelemetry(), ShapeTelemetry()
    shape = gemm_input(256, 256, 256)
    for _ in range(100):
        src.record("gemm", shape)
    stop = threading.Event()

    def hammer():
        i = 0
        while not stop.is_set():
            src.record("gemm", gemm_input(64 + (i % 7), 64, 64))
            i += 1

    w = threading.Thread(target=hammer)
    w.start()
    try:
        for _ in range(50):
            dst.merge(src)                 # must never blow up mid-iteration
    finally:
        stop.set()
        w.join()
    assert dst.count("gemm", shape) == 50 * 100


# ---------------------------------------------------------------------------
# tick counters under jit
# ---------------------------------------------------------------------------

def test_capture_and_record_ticks_recover_jit_frequencies(rng):
    tel = get_telemetry()
    a = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(32, 128)), jnp.float32)
    shape = gemm_input(16, 128, 32, 32)

    f = jax.jit(lambda a, b: dispatch.matmul(a, b) * 2.0)
    with tel.capture() as cap:
        f(a, b)                            # compiling call: trace-time census
    assert ("gemm", shape) in cap.shapes
    assert tel.count("gemm", shape) == 1
    for _ in range(9):                     # later executions record NOTHING…
        f(a, b)
    assert tel.count("gemm", shape) == 1   # …the documented jit census gap
    for _ in range(9):                     # …until the tick hook replays them
        tel.record_ticks(cap.shapes)
    assert tel.count("gemm", shape) == 10  # true execution frequency
    assert tel.stats()["ticks"]["gemm"] == 9


def test_engine_ticks_feed_true_decode_frequencies():
    from repro.models import ModelConfig, init_params
    from repro.serve import Engine, ServeConfig

    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=2, n_kv=1,
                      d_ff=64, vocab=64, dtype=jnp.float32, attn_chunk=16,
                      logit_chunk=16, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, ServeConfig(max_len=64, slots=2))
    engine.generate([np.arange(4), np.arange(6)], max_new=12)
    assert engine._decode_shapes            # capture saw the traced kernels
    tel = get_telemetry()
    space, shape = engine._decode_shapes[0]
    # one census count (the compiling tick) + one tick per later execution:
    # the count tracks engine.ticks, not the number of compilations
    per_trace = sum(1 for s in engine._decode_shapes if s == (space, shape))
    assert tel.count(space, shape) == per_trace * engine.ticks
    assert tel.stats()["ticks"][space] > 0
    # prefill lengths 4 and 6 each compiled once and captured their shapes
    assert set(engine._prefill_shapes) == {4, 6}


# ---------------------------------------------------------------------------
# atomic install: generations, torn views, latch re-arming
# ---------------------------------------------------------------------------

def test_install_serving_swaps_one_generation():
    s1, m1 = RecordStore(), ModelSet()
    g0 = install_generation()
    st = install_serving(store=s1, models=m1, fingerprint="bk-A")
    assert st.generation == g0 + 1
    assert serving_state().store is s1
    assert serving_state().models is m1
    assert serving_state().fingerprint == "bk-A"
    # partial swap keeps the unmentioned fields
    st2 = install_serving(models=None)
    assert st2.store is s1 and st2.fingerprint == "bk-A"
    assert st2.generation == st.generation + 1
    assert get_store() is s1 and get_models() is None


def test_hot_swap_never_shows_torn_store_model_pair():
    """A reader doing ONE serving_state() read always sees a matched
    (store, models) pair, no matter how fast a writer flips generations."""
    pairs = [(RecordStore(), ModelSet()) for _ in range(2)]
    valid = {id(s): id(m) for s, m in pairs}
    install_serving(store=pairs[0][0], models=pairs[0][1])
    stop = threading.Event()
    torn = []

    def writer():
        i = 0
        while not stop.is_set():
            s, m = pairs[i % 2]
            install_serving(store=s, models=m)
            i += 1

    def reader():
        while not stop.is_set():
            st = serving_state()           # the atomic read dispatch does
            if valid.get(id(st.store)) != id(st.models):
                torn.append((st.store, st.models))

    threads = [threading.Thread(target=writer)] + \
        [threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    import time
    time.sleep(0.3)
    stop.set()
    for t in threads:
        t.join()
    assert not torn


def test_install_rearms_warn_once_latches(rng):
    """The docstring contract 'reset_fallback_warnings (tests; store/model
    reinstall)': a degraded process that gets a FRESH store must warn again
    if the fresh store degrades too — the old latch must not swallow it."""
    import warnings as _w

    install_store(RecordStore())                  # empty -> degraded
    a = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 128)) / 8.0, jnp.float32)
    with pytest.warns(RuntimeWarning, match="heuristics"):
        np.asarray(dispatch.matmul(a, b, prefer_kernel=True))
    with _w.catch_warnings():                     # latched: silent now
        _w.simplefilter("error")
        np.asarray(dispatch.matmul(a, b, prefer_kernel=True))

    install_store(RecordStore())                  # reinstall re-arms
    with pytest.warns(RuntimeWarning, match="heuristics"):
        np.asarray(dispatch.matmul(a, b, prefer_kernel=True))


def test_install_invalidates_nearest_memo():
    store = RecordStore()
    store.add(_rec(1024, 16, 2048))
    probe = gemm_input(1152, 16, 2048)
    assert store.nearest("gemm", probe) is not None
    assert store._nearest_memo                    # memoized resolution
    install_store(store)
    assert not store._nearest_memo                # new generation, clean memo


# ---------------------------------------------------------------------------
# the controller loop
# ---------------------------------------------------------------------------

def test_controller_triggers_on_drift_and_hot_swaps(tiny_tuner, tmp_path):
    """The acceptance loop in miniature: shift traffic -> drift trips ->
    session commits -> regressors retrain -> one atomic generation flip —
    all without touching the engine or restarting anything."""
    store = RecordStore.open(tmp_path / "db.jsonl")
    fp = backend_fingerprint(tiny_tuner.backend)
    install_serving(store=store, models=None, fingerprint=None)

    tel = get_telemetry()
    old = gemm_input(512, 16, 512)
    for _ in range(40):
        tel.record("gemm", old)
    controller = RetuneController(
        store, tuners={"gemm": tiny_tuner},
        cfg=RetuneConfig(drift_threshold=0.25, untuned_mass_threshold=0.5,
                         min_calls=16, top_k_shapes=2, workers=1,
                         remeasure=True, retrain=True, train_epochs=3,
                         min_train_samples=5))
    assert controller.maybe_retune() is None      # steady: nothing to do

    new = gemm_input(2560, 16, 2560)
    for _ in range(40):
        tel.record("gemm", new)
    dec = controller.check()["gemm"]
    assert dec.trigger and dec.reason == "drift"
    assert dec.untuned_mass == pytest.approx(1.0)
    assert dec.novel_shapes == [new]

    gen0 = install_generation()
    report = controller.maybe_retune()
    assert report is not None and report.tuned == 1
    assert store.contains("gemm", new, backend=fp)
    rec = store.get("gemm", new, backend=fp)
    assert rec.source == "retune"                 # auditable in the log
    assert report.retrained == [f"gemm/{fp}"]
    assert install_generation() > gen0            # the hot-swap happened
    assert serving_state().store is store
    assert len(get_models()) == 1                 # retrained regressor serves
    # the epoch advanced: the same (already-served) traffic does not re-trip
    assert controller.maybe_retune() is None
    assert controller.retunes == 1


def test_controller_untuned_mass_trigger_without_drift(tiny_tuner):
    """A brand-new process: traffic is steady from tick one, so drift never
    fires — but everything is untuned, and THAT must trigger."""
    store = RecordStore()
    install_store(store)
    tel = get_telemetry()
    controller = RetuneController(
        store, tuners={"gemm": tiny_tuner},
        cfg=RetuneConfig(drift_threshold=1.1,     # drift can never fire
                         untuned_mass_threshold=0.5, min_calls=16,
                         top_k_shapes=1, workers=1, retrain=False))
    for _ in range(20):
        tel.record("gemm", gemm_input(512, 128, 512))
    dec = controller.check()["gemm"]
    assert dec.trigger and dec.reason == "untuned"
    report = controller.maybe_retune()
    assert report is not None and report.tuned == 1
    assert report.retrained == []                 # retrain disabled


def test_controller_below_min_calls_stays_quiet(tiny_tuner):
    store = RecordStore()
    controller = RetuneController(
        store, tuners={"gemm": tiny_tuner},
        cfg=RetuneConfig(min_calls=64, top_k_shapes=1))
    tel = get_telemetry()
    for _ in range(10):                           # loud shift, tiny window
        tel.record("gemm", gemm_input(2560, 16, 2560))
    dec = controller.check()["gemm"]
    assert dec.drift == pytest.approx(1.0) and not dec.trigger


def test_pin_mismatch_warns_and_does_not_livelock(tiny_tuner):
    """Serving pinned to a fingerprint the session backend does not measure
    under: the committed records can never serve from the pinned exact
    tier.  The controller must warn, remember the attempt, and NOT
    re-trigger (and re-flip generations) on every poll forever."""
    store = RecordStore()
    install_serving(store=store, models=None, fingerprint="pinned-other")
    tel = get_telemetry()
    controller = RetuneController(
        store, tuners={"gemm": tiny_tuner},
        cfg=RetuneConfig(min_calls=8, top_k_shapes=1, workers=1,
                         retrain=False))
    for _ in range(20):
        tel.record("gemm", gemm_input(512, 128, 512))
    with pytest.warns(RuntimeWarning, match="fingerprint pin"):
        r1 = controller.maybe_retune()
    assert r1 is not None and r1.tuned == 1       # the session did run
    gen = install_generation()
    # traffic keeps flowing on the same (still pin-unserved) hot shape:
    # it was attempted once — no re-trigger, no generation churn
    for _ in range(20):
        tel.record("gemm", gemm_input(512, 128, 512))
    assert controller.maybe_retune() is None
    assert install_generation() == gen


def test_zero_tuned_epoch_skips_the_hot_swap(tiny_tuner):
    """A triggered epoch where every job is skipped (the shape is already
    tuned under the session backend, just not under the serving pin) must
    not flip the serving generation — there is nothing new to publish."""
    store = RecordStore()
    fp = backend_fingerprint(tiny_tuner.backend)
    shape = gemm_input(512, 128, 512)
    store.add(TuneRecord(space="gemm", inputs=shape, config=dict(CFG),
                         tflops=50.0, backend=fp))
    install_serving(store=store, models=None, fingerprint="pinned-other")
    tel = get_telemetry()
    controller = RetuneController(
        store, tuners={"gemm": tiny_tuner},
        cfg=RetuneConfig(min_calls=8, top_k_shapes=1, workers=1,
                         retrain=True))
    for _ in range(20):
        tel.record("gemm", shape)                 # novel UNDER THE PIN only
    gen0 = install_generation()
    with pytest.warns(RuntimeWarning, match="fingerprint pin"):
        report = controller.maybe_retune()
    assert report is not None and report.tuned == 0
    assert report.sessions["gemm"].skipped == 1
    assert install_generation() == gen0           # no no-op generation flip
    assert controller.retunes == 0                # not a served epoch
    # the epoch still advanced: the spent window does not re-trigger
    assert controller.maybe_retune() is None


def test_retune_does_not_clobber_concurrent_retarget(tiny_tuner,
                                                     monkeypatch):
    """install_serving made DURING a (long) session/retrain — say a new
    Engine retargeting the store — must survive the retune's final swap:
    the controller re-reads the state at swap time and declines to publish
    over a store it no longer owns (and never touches the pin)."""
    from repro.tunedb import active_fingerprint
    import repro.tunedb.session as session_mod

    store = RecordStore()
    install_store(store)
    tel = get_telemetry()
    controller = RetuneController(
        store, tuners={"gemm": tiny_tuner},
        cfg=RetuneConfig(min_calls=8, top_k_shapes=1, workers=1,
                         retrain=False))
    for _ in range(20):
        tel.record("gemm", gemm_input(512, 128, 512))

    other = RecordStore()
    orig_run = session_mod.TuningSession.run

    def run_then_retarget(self, *a, **kw):
        out = orig_run(self, *a, **kw)
        install_store(other, fingerprint="bk-B")   # the concurrent engine
        return out
    monkeypatch.setattr(session_mod.TuningSession, "run", run_then_retarget)

    with pytest.warns(RuntimeWarning, match="retargeted"):
        report = controller.maybe_retune()
    assert report is not None and report.tuned == 1   # the work still landed
    assert get_store() is other                       # retarget preserved
    assert active_fingerprint() == "bk-B"             # pin preserved
    assert controller.retunes == 0                    # swap did not publish


def test_merged_with_keeps_serving_policy():
    """The retrain hot-swap must not reset a configured §6 re-measure width
    or drop the measurer: fresh sets carry defaults, not serving policy."""
    measure = lambda space, cfg, inputs: 1.0
    old = ModelSet(measurer=measure, remeasure_top_k=24)
    out = old.merged_with(ModelSet())                 # freshly trained set
    assert out.remeasure_top_k == 24
    assert out.measurer is measure


def test_engine_retunes_in_the_generate_loop(tiny_tuner):
    """End-to-end: a serving engine with the controller enabled notices its
    own (novel) decode shapes and retunes mid-generate — no restart."""
    from repro.models import ModelConfig, init_params
    from repro.serve import Engine, ServeConfig

    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=2, n_kv=1,
                      d_ff=64, vocab=64, dtype=jnp.float32, attn_chunk=16,
                      logit_chunk=16, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(
        cfg, params,
        ServeConfig(max_len=64, slots=2, retune=True, retune_interval=8,
                    retune_min_calls=8, retune_top_k=2, retune_train=False),
        retune_tuners={"gemm": tiny_tuner})
    assert engine.controller is not None
    store = get_store()
    assert store is engine.tunedb_store and len(store) == 0
    gen0 = install_generation()

    outs = engine.generate([np.arange(4), np.arange(6)], max_new=24)
    assert all(len(o) == 24 for o in outs)        # serving never stopped
    assert engine.controller.retunes >= 1         # the loop closed in-band
    assert install_generation() > gen0
    assert len(store) >= 1                        # its own hot shapes, tuned
    rec = store.records()[0]
    assert rec.source == "retune"


# ---------------------------------------------------------------------------
# serving-path fix: models-only engine config must honor the backend pin
# ---------------------------------------------------------------------------

def test_models_only_engine_config_pins_fingerprint(tmp_path):
    from repro.models import ModelConfig, init_params
    from repro.serve import Engine, ServeConfig
    from repro.tunedb import active_fingerprint

    # a prior engine pinned bk-A via a store config
    db = tmp_path / "a.jsonl"
    RecordStore.open(db).add(_rec(512, 16, 2048, backend="bk-A"))
    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=2, n_kv=1,
                      d_ff=64, vocab=64, dtype=jnp.float32, attn_chunk=16,
                      logit_chunk=16, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    Engine(cfg, params, ServeConfig(max_len=32, slots=1, tunedb=str(db),
                                    tunedb_backend="bk-A"))
    assert active_fingerprint() == "bk-A"

    # a models-only engine (no store path) with an explicit bk-B pin: the
    # pin must take effect even though install_store never runs
    Engine(cfg, params, ServeConfig(max_len=32, slots=1,
                                    tunedb_models=str(tmp_path / "none"),
                                    tunedb_backend="bk-B"))
    assert active_fingerprint() == "bk-B"
    # and a models-only engine with NO pin retargets to any-backend
    Engine(cfg, params, ServeConfig(max_len=32, slots=1,
                                    tunedb_models=str(tmp_path / "none")))
    assert active_fingerprint() is None


# ---------------------------------------------------------------------------
# CLI: retune / watch
# ---------------------------------------------------------------------------

def _dump_telemetry(path, shapes_counts):
    t = ShapeTelemetry()
    for inputs, n in shapes_counts:
        t.record("gemm", inputs, n=n)
    t.save(path)


def test_cli_retune_pass_and_epoch_baseline(tmp_path, capsys):
    db = tmp_path / "db.jsonl"
    tel_path = tmp_path / "tel.json"
    _dump_telemetry(tel_path, [(gemm_input(512, 16, 512), 40)])

    # first epoch: everything is new -> untuned mass trips, store fills
    rc = tunedb_main([
        "retune", "--store", str(db), "--telemetry", str(tel_path),
        "--min-calls", "16", "--top-k", "1", "--workers", "1",
        "--no-train", "--train-samples", "400", "--epochs", "2"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "retuned 1 shape(s)" in out
    assert RecordStore.open(db).contains("gemm", gemm_input(512, 16, 512),
                                         backend=None)
    assert (tmp_path / "tel.json.epoch").exists()    # baseline advanced

    # same telemetry again: zero drift against the saved baseline -> no-op
    rc = tunedb_main([
        "retune", "--store", str(db), "--telemetry", str(tel_path),
        "--min-calls", "16", "--top-k", "1", "--workers", "1", "--no-train",
        "--train-samples", "400", "--epochs", "2"])
    assert rc == 0
    assert "no retune" in capsys.readouterr().out


def test_cli_watch_polls_and_stops(tmp_path, capsys):
    db = tmp_path / "db.jsonl"
    tel_path = tmp_path / "tel.json"
    _dump_telemetry(tel_path, [(gemm_input(512, 16, 512), 40)])
    rc = tunedb_main([
        "watch", "--store", str(db), "--telemetry", str(tel_path),
        "--interval", "0", "--max-polls", "2", "--min-calls", "16",
        "--top-k", "1", "--workers", "1", "--no-train",
        "--train-samples", "400", "--epochs", "2"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "watch poll 1/2" in out and "watch poll 2/2" in out
    # poll 1 retuned; poll 2 saw the advanced baseline and declined
    assert "retuned 1 shape(s)" in out and "no retune" in out


def test_cli_retune_missing_telemetry_fails_cleanly(tmp_path, capsys):
    rc = tunedb_main(["retune", "--store", str(tmp_path / "db.jsonl"),
                      "--telemetry", str(tmp_path / "nope.json")])
    assert rc == 1
    assert "not found" in capsys.readouterr().err


def test_retune_report_in_stats(tiny_tuner):
    store = RecordStore()
    controller = RetuneController(store, tuners={"gemm": tiny_tuner},
                                  cfg=RetuneConfig(min_calls=1, workers=1,
                                                   retrain=False))
    tel = get_telemetry()
    for _ in range(8):
        tel.record("gemm", gemm_input(512, 128, 512))
    controller.maybe_retune()
    st = controller.stats()
    assert st["retunes"] == 1 and st["last"]["tuned"] == 1
    assert json.dumps(st)                          # JSON-serializable